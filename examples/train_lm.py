"""End-to-end training driver with fault tolerance: trains an LM on the
synthetic pipeline with checkpointing, then simulates a crash and proves
byte-exact resume. `--scale 100m` trains a ~100M-parameter model (slow on
1 CPU core; default `10m` finishes in minutes).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import shutil
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import RunConfig, run
from repro.models.config import AttnSpec, FfnSpec, ModelConfig

SCALES = {
    # name: (d_model, layers, d_ff, vocab)  ~params
    "1m": (128, 4, 512, 2048),          # ~1.3M
    "10m": (320, 6, 1280, 8192),        # ~13M
    "100m": (640, 12, 2560, 32000),     # ~105M
}


def lm_config(scale: str) -> ModelConfig:
    d, L, f, v = SCALES[scale]
    return ModelConfig(
        name=f"lm-{scale}", d_model=d, vocab=v, n_groups=L,
        pattern=((AttnSpec(n_heads=d // 64, n_kv=max(d // 128, 1),
                           head_dim=64), FfnSpec(d_ff=f)),),
        max_seq=1024, rope_theta=1e4, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="10m", choices=list(SCALES))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    import repro.launch.train as T
    cfg = lm_config(args.scale)
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps of batch {args.batch} x seq {args.seq}")

    # monkey-patch the registry hook: run() accepts any arch via get_config,
    # so register ours
    import repro.configs as C
    C._MOD[cfg.name] = None
    orig = C.get_config
    C.get_config = lambda name, reduced=False: (
        cfg if name == cfg.name else orig(name, reduced))
    T.get_config = C.get_config

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    rc = RunConfig(arch=cfg.name, reduced=True, steps=args.steps,
                   batch=args.batch, seq=args.seq, lr=1e-3,
                   ckpt_dir=args.ckpt_dir, ckpt_every=args.steps // 4)
    out = run(rc)
    print(f"[example] phase 1 final loss {out['final_loss']:.4f}")

    # simulate a crash at 100%: re-run — must resume, not restart
    print("[example] simulating preemption: relaunching the driver ...")
    rc2 = RunConfig(arch=cfg.name, reduced=True, steps=args.steps + 40,
                    batch=args.batch, seq=args.seq, lr=1e-3,
                    ckpt_dir=args.ckpt_dir, ckpt_every=20)
    out2 = run(rc2)
    print(f"[example] resumed + {len(out2['losses'])} more steps, "
          f"final loss {out2['final_loss']:.4f} "
          f"(started from checkpointed step, not 0)")
    assert len(out2["losses"]) <= 40 + 1, "resume failed: retrained"


if __name__ == "__main__":
    main()
