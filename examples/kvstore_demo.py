"""SSD-resident blocked-Cuckoo KV store (case study 1), runnable.

Fills a table to the paper's 0.7 load factor, serves GETs through the
scalar-prefetch probe kernel, exercises the WAL/coalescing write path,
and prints the modeled Fig. 8 platform throughput.

  PYTHONPATH=src python examples/kvstore_demo.py
"""
import sys
import time
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.kvstore.cuckoo import BlockedCuckooStore
from repro.kvstore.model import (KvWorkload, achievable_throughput,
                                 cpu_sn_platform, gpu_nr_platform,
                                 gpu_sn_platform)


def main():
    nb, slots = 8192, 8
    st = BlockedCuckooStore(n_buckets=nb, slots=slots,
                            dram_cache_items=1024, wal_limit=128)
    rng = np.random.default_rng(0)
    n = int(nb * slots * 0.7)
    keys = rng.choice(np.arange(1, 10**8), size=n, replace=False)
    t0 = time.time()
    for k in keys:
        st.put(int(k), int(k) % 99991)
    st.flush()
    print(f"[store] {n} items inserted at load {st.load_factor():.3f} "
          f"in {time.time()-t0:.1f}s; E[chain]={st.expected_chain_len():.4f}"
          f" observed relocations={st.stats.relocations}")

    # batched GETs through the Pallas probe kernel
    probe = keys[rng.integers(0, n, 4096)].astype(np.int32)
    t0 = time.time()
    found, vals = st.get_batch(probe)
    dt = time.time() - t0
    ok = int((vals[found.astype(bool)]
              == probe[found.astype(bool)] % 99991).sum())
    print(f"[store] batched GET x{len(probe)}: {found.sum()} found, "
          f"{ok} values correct, {dt*1e3:.0f}ms "
          f"(interpret-mode kernel; ~1.5 block reads/GET)")
    print(f"[store] stats: {st.stats}")

    print("\n[model] paper Fig. 8 (5TB store, 80B items, 4 SSDs):")
    wl = KvWorkload(get_frac=0.9, sigma=1.2)
    for plat in (gpu_sn_platform(), cpu_sn_platform(), gpu_nr_platform()):
        r = achievable_throughput(plat, wl, 256e9)
        print(f"  {plat.name:7s}: {r['throughput']/1e6:7.1f} Mops/s "
              f"(limiter: {r['limiter']}, cache hit {r['hit_rate']:.2f})")
    print("  -> GPU+Storage-Next reaches in-memory-class throughput "
          "(FASTER-level) from flash")


if __name__ == "__main__":
    main()
