"""Quickstart: the five-minute rule, recalibrated — in 60 seconds.

Computes the classical and calibrated break-even intervals, applies
feasibility constraints, runs the workload-aware platform advisor,
derives a live TieringPolicy, and finishes with the declarative API:
one `HierarchySpec` compiling into a running multi-host platform whose
economics are inputs, not plumbing — the complete RQ1->RQ4 pipeline.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (CPU_DDR, GPU_GDDR, CPU_PLATFORM, GPU_PLATFORM,
                        LatencyTargets, LogNormalWorkload, SLC,
                        analyze_platform, break_even_components,
                        classical_break_even, iops_ssd_peak,
                        storage_next_ssd, TieringPolicy)


def main():
    ssd = storage_next_ssd(SLC)
    l_blk = 512

    print("=" * 72)
    print("1. Classical (economics-only) five-minute rule, 2025 params")
    print("=" * 72)
    iops = float(iops_ssd_peak(ssd, l_blk, 9.0, 3.0))
    # DRAM $/byte normalized to NAND-die cost: 1 die / 3GB
    tau_classical = float(classical_break_even(
        l_blk, ssd.cost, iops, dram_cost_per_byte=1.0 / 3e9))
    print(f"  Storage-Next SSD: {iops/1e6:.1f}M IOPS @512B, "
          f"cost {ssd.cost:.0f} NAND-die-units")
    print(f"  classical break-even: {tau_classical:.1f}s "
          f"(Gray's 1987 answer was ~300s)")

    print()
    print("=" * 72)
    print("2. Calibrated break-even (host costs included, Eq. 1)")
    print("=" * 72)
    for host in (CPU_DDR, GPU_GDDR):
        comp = break_even_components(host, l_blk, ssd.cost, iops)
        total = float(sum(comp.values()))
        print(f"  {host.name:9s}: tau_be = {total:5.1f}s "
              f"(host {float(comp['host']):5.2f}s + dram "
              f"{float(comp['dram_bw']):5.2f}s + ssd "
              f"{float(comp['ssd']):5.2f}s)")
    print("  -> minutes (HDD era) -> tens of seconds (CPU) -> ~5s (GPU)")

    print()
    print("=" * 72)
    print("3. Workload-aware platform advisor (RQ3)")
    print("=" * 72)
    wl = LogNormalWorkload.from_total_throughput(
        throughput=200e9, sigma=1.0, n_blk=1e9, l_blk=l_blk)
    for plat in (CPU_PLATFORM, GPU_PLATFORM):
        rep = analyze_platform(plat, wl, l_blk,
                               LatencyTargets(tail=13e-6))
        print(f"  {rep.summary()}")

    print()
    print("=" * 72)
    print("4. Live tiering policy (drives KV-cache/expert/checkpoint tiers)")
    print("=" * 72)
    pol = TieringPolicy.from_platform(GPU_PLATFORM, l_blk,
                                      LatencyTargets(tail=13e-6))
    print(f"  HBM if reuse < {pol.tau_hot:.3f}s; DRAM if < "
          f"{pol.tau_be:.2f}s; else FLASH")
    for iv in (0.01, 1.0, 30.0):
        print(f"  object reused every {iv:5.2f}s -> "
              f"{pol.tier_for_interval(iv).name}")

    print()
    print("=" * 72)
    print("5. Declare the whole hierarchy (HierarchySpec -> Platform)")
    print("=" * 72)
    import numpy as np
    from repro.platform import (HierarchySpec, HostDecl, Platform,
                                PolicyDecl, TierDecl)
    spec = HierarchySpec(
        # heterogeneous fleet: one big-DRAM host + three standard ones;
        # the compiled ring weights key ownership by DRAM capacity (2:1)
        hosts=(HostDecl(tiers={"dram": TierDecl(256e9, 45e9, 5e-7)}),
               HostDecl(count=3)),
        policy=PolicyDecl.economic(l_blk=128 << 10),
        class_priors={"kv": 2.0},       # sessions assumed ~2s reuse
    )
    platform = Platform.compile(spec)
    print(f"  compiled {platform.n_hosts} hosts, ring weights "
          f"{spec.resolved_weights()}, "
          f"tau_be={platform.policy(0).tau_be:.1f}s per-host gate")
    sess = platform.kv_session("user-42")
    sess.save(np.zeros(1 << 16, np.float32))        # gate picks the tier
    handle = sess.prefetch()                        # uniform async handle
    platform.clock.advance(0.01)
    handle.result()
    print(f"  kv_session save -> {sess.tier().name}, prefetch overlapped "
          f"-> done={handle.done()}")
    print(f"  spec round-trips: "
          f"{HierarchySpec.from_json(spec.to_json()) == spec}")
    advice = platform.advise()
    print(f"  advisor: hot set {advice.hot_bytes/2**20:.2f}MiB -> "
          f"{advice.recommended_hosts} host(s); platform.autoscale() "
          f"closes the loop")

    print()
    print("=" * 72)
    print("6. Observability: the Eq. 1 stall ledger + a Perfetto trace")
    print("=" * 72)
    import dataclasses
    from repro.platform import ObservabilityDecl
    traced = Platform.compile(dataclasses.replace(
        spec, observability=ObservabilityDecl(trace=True)))
    sess = traced.kv_session("user-42")
    sess.save(np.zeros(1 << 16, np.float32))
    traced.clock.advance(5.0)               # think gap: reuse looks cold
    sess.resume()                           # synchronous restore stalls
    led = traced.ledger.as_dict()
    top = max((c for c in led if c not in ("total", "tenants")),
              key=lambda c: led[c])
    print(f"  every stalled second attributed: total "
          f"{led['total']*1e6:.1f}us, dominated by '{top}'")
    trace_path = pathlib.Path("quickstart_trace.json")
    trace_path.write_text(traced.tracer.to_chrome_json() + "\n")
    print(f"  causal trace: {trace_path} ({len(traced.tracer)} events) "
          f"-> open at https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
