"""End-to-end serving driver: batched requests through the decode engine
with five-minute-rule KV-cache tiering.

Serves a reduced LM with continuous batching, then pauses sessions and
shows the tiering policy placing their KV blocks across DRAM/flash by
observed reuse interval, and resumes them transparently — including the
async-prefetch restore path overlapping the flash fetch with decode on
the platform's deterministic virtual clock.

The whole hierarchy is *declared*: a `HierarchySpec` (one host, static
seconds-scale thresholds, virtual clock, 5ms modeled decode step)
compiles into the platform, and the engine is a capability from its
facade — no clock/policy/store threading.

  PYTHONPATH=src python examples/serve_tiered_kv.py [--arch gemma-2b]
"""
import argparse
import sys
import pathlib
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import model as M
from repro.parallel.sharding import single_device_rules
from repro.platform import HierarchySpec, HostDecl, Platform, PolicyDecl
from repro.serving.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ARCHS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)

    # the hierarchy, declared: one host, seconds-scale static
    # thresholds, deterministic virtual clock, 5ms modeled decode step
    spec = HierarchySpec(
        hosts=(HostDecl(),),
        policy=PolicyDecl.static(tau_hot=0.05, tau_be=1.0,
                                 ema_alpha=1.0),
        step_time=5e-3)
    platform = Platform.compile(spec)
    clock = platform.clock
    eng = platform.engine(cfg, params, rules, max_slots=4, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=f"session-{i}",
                    prompt=rng.integers(1, cfg.vocab, 8).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]

    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)}/{len(reqs)} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s on 1 CPU core), "
          f"{eng.steps} batched decode steps")
    for r in done[:3]:
        print(f"  {r.rid}: {r.generated}")

    # --- session pause/resume through the tiered store -------------------
    print("\n[tiering] pausing two sessions; hot one re-accessed quickly,"
          " cold one left idle")
    r0, r1 = done[0], done[1]
    eng.lengths[:] = 0
    eng.live[:] = False
    eng.slot_req.clear()
    eng.admit(r0)
    eng.admit(r1)
    tier_a = eng.pause(r0.rid)
    tier_b = eng.pause(r1.rid)
    print(f"  paused {r0.rid} -> {tier_a.name}, {r1.rid} -> {tier_b.name}")
    # hot session comes back fast: promote on reuse
    eng.resume(r0.rid)
    eng.pause(r0.rid)
    clock.advance(1.2)                # cold session crosses tau_be
    # async restore: issue the prefetch, let modeled decode compute
    # overlap the flash fetch, then resume without stalling
    eng.prefetch(r1.rid)
    clock.advance(3 * 5e-3)           # three decode steps elsewhere
    eng.resume(r1.rid)
    tier_hot = eng.store.tier_of(("kv", r0.rid))
    print(f"  after reuse pattern: {r0.rid} KV on "
          f"{tier_hot.name if tier_hot else 'engine'}, "
          f"{r1.rid} resumed with {eng.kv_stall_time*1e3:.2f}ms total "
          f"restore stall (prefetch overlapped)")
    print("\n[tier stats]")
    print(platform.report())
    print("\n[runtime queues]")
    print(eng.store.runtime.report())


if __name__ == "__main__":
    main()
