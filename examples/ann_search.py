"""Two-stage progressive ANN search (case study 2), runnable.

Builds an MRL-like corpus (full 4KB / reduced 512B vectors), runs the
two-stage search through the fused Pallas distance+top-k kernel, measures
recall vs exact brute force, and prints the modeled platform KQPS.

  PYTHONPATH=src python examples/ann_search.py [--n 20000]
"""
import argparse
import sys
import time
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.ann.corpus import make_corpus, make_queries
from repro.ann.model import AnnWorkload, cpu_sn, gpu_nr, gpu_sn, \
    throughput_kqps
from repro.ann.progressive import exact_topk, recall_at_k, search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--promote", type=int, default=64)
    args = ap.parse_args()

    print(f"[corpus] {args.n} vectors: full 1024-d (4KB), "
          f"reduced 128-d (512B) — MRL-style nested embeddings")
    full, red, _ = make_corpus(args.n, 1024, 128)
    qs = make_queries(full, args.queries)

    t0 = time.time()
    truth = exact_topk(qs, full, 10)
    t_exact = time.time() - t0

    t0 = time.time()
    pred, stats = search(qs, red, full, k=10, promote=args.promote)
    t_two = time.time() - t0
    rec = recall_at_k(pred, truth)

    print(f"[search] recall@10 = {rec:.4f} (paper claims >98%)")
    print(f"[search] stage-2 re-ranks {args.promote} of {args.n} "
          f"candidates ({100*args.promote/args.n:.2f}%) — "
          f"{stats.stage2_reads} full-vector reads vs "
          f"{stats.stage1_reads} reduced reads")
    print(f"[search] wall: exact {t_exact:.2f}s vs two-stage {t_two:.2f}s "
          f"(CPU-interpret kernel)")

    print("\n[model] 8B-vector corpus, 4 SSDs (paper Fig. 10 geometry):")
    for plat in (gpu_sn(), cpu_sn(), gpu_nr()):
        row = [f"{throughput_kqps(plat, AnnWorkload(), d)['kqps']:6.1f}"
               for d in (64e9, 256e9, 512e9)]
        print(f"  {plat.name:7s} KQPS @ 64/256/512GB DRAM: "
              + " / ".join(row))


if __name__ == "__main__":
    main()
