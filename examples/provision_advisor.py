"""Provisioning advisor — the paper's §V framework as a CLI.

Given a workload (size, throughput, locality, block size, latency SLO)
and a platform, reports viability (T_B/T_S/T_C), the economics-optimal
DRAM capacity, and a concrete upgrade recommendation.

  PYTHONPATH=src python examples/provision_advisor.py \\
      --platform gpu --l-blk 512 --throughput-gbs 200 --tail-us 13
"""
import argparse
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (CPU_PLATFORM, GPU_PLATFORM, LatencyTargets,
                        LogNormalWorkload, analyze_platform)
from repro.core import units


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", choices=("cpu", "gpu"), default="gpu")
    ap.add_argument("--l-blk", type=int, default=512)
    ap.add_argument("--throughput-gbs", type=float, default=200.0)
    ap.add_argument("--n-blocks", type=float, default=1e9)
    ap.add_argument("--sigma", type=float, default=1.0,
                    help="access-interval lognormal spread (locality)")
    ap.add_argument("--tail-us", type=float, default=13.0)
    ap.add_argument("--dram-gb", type=float, default=0.0,
                    help="fixed DRAM capacity (0 = provision freely)")
    args = ap.parse_args()

    plat = GPU_PLATFORM if args.platform == "gpu" else CPU_PLATFORM
    if args.dram_gb:
        import dataclasses
        plat = dataclasses.replace(plat, c_dram_total=args.dram_gb * 1e9)
    wl = LogNormalWorkload.from_total_throughput(
        throughput=args.throughput_gbs * 1e9, sigma=args.sigma,
        n_blk=args.n_blocks, l_blk=args.l_blk)
    rep = analyze_platform(plat, wl, args.l_blk,
                           LatencyTargets(tail=args.tail_us * 1e-6))

    print(f"workload: {units.human_bytes(wl.total_bytes)} across "
          f"{args.n_blocks:.0e} x {args.l_blk}B blocks, "
          f"{args.throughput_gbs:.0f} GB/s aggregate, sigma={args.sigma}")
    print(f"platform: {plat.name}, {plat.n_ssd} SSDs, host budget "
          f"{units.human_rate(plat.iops_proc)}, DRAM BW "
          f"{units.human_bytes(plat.b_dram_total)}/s")
    print()
    print(f"  usable SSD IOPS : {units.human_rate(rep.iops_ssd_usable)}"
          f"/SSD (rho_max={rep.rho_max:.2f}"
          + (", host-limited" if rep.host_limited else "") + ")")
    print(f"  break-even tau  : {units.human_time(rep.tau_break_even)}")
    print(f"  T_B / T_S / T_C : {units.human_time(rep.th.t_b)} / "
          f"{units.human_time(rep.th.t_s)} / "
          f"{units.human_time(rep.th.t_c)}")
    print(f"  DRAM for viable : {units.human_bytes(rep.c_dram_viable)}")
    print(f"  DRAM for optimal: {units.human_bytes(rep.c_dram_optimal)}")
    print(f"  DRAM BW at opt  : "
          f"{units.human_bytes(rep.dram_bw_use_optimal)}/s")
    print()
    print(f"  VERDICT: {rep.verdict}")
    print(f"  ADVICE : {rep.recommendation}")


if __name__ == "__main__":
    main()
