"""Provisioning advisor — the paper's §V framework as a CLI.

Three modes:

* **analytic** (default): given an *assumed* log-normal workload (size,
  throughput, locality, block size, latency SLO) and a platform, report
  viability (T_B/T_S/T_C), the economics-optimal DRAM capacity, and an
  upgrade recommendation.
* **live** (`--trace <scenario>`): replay one of the autopilot trace
  scenarios (zipf, scan_flood, diurnal, multi_tenant) through a
  break-even-gated TieredStore and run the `autopilot.ProvisionAdvisor`
  on what the runtime *measured* — per-class reuse histograms, tier
  stats — instead of an assumed distribution.
* **four-arm tiers** (`--advise-tiers`, composes with `--trace`): feed
  the trace's reuse intervals to `advise_tiers` and print the Eq. 1
  four-arm comparison — 3-tier baseline vs `+gpu_flash` (BaM-style
  GPU-direct flash: no host-CPU per-IO rent) vs `+pool` (fleet
  far-memory at `--rent-factor` x DRAM rent for the
  `[tau_be, tau_pool)` band) vs both — and the cheapest shape.

  PYTHONPATH=src python examples/provision_advisor.py \\
      --platform gpu --l-blk 512 --throughput-gbs 200 --tail-us 13
  PYTHONPATH=src python examples/provision_advisor.py --trace scan_flood
  PYTHONPATH=src python examples/provision_advisor.py --advise-tiers \\
      --trace diurnal --rent-factor 0.25
"""
import argparse
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (CPU_PLATFORM, GPU_PLATFORM, LatencyTargets,
                        LogNormalWorkload, analyze_platform)
from repro.core import units


def run_live(args):
    from repro.autopilot.bench import run_scenario
    from repro.autopilot.traces import SCENARIOS
    if args.trace not in SCENARIOS:
        sys.exit(f"--trace must be one of {SCENARIOS}")
    rec = run_scenario(args.trace, "economic", n_steps=args.steps,
                       l_blk=int(args.obj_kib * 1024))
    print(f"scenario: {args.trace} ({int(rec['accesses'])} accesses, "
          f"{rec['horizon']:.1f}s modeled)")
    print(f"served at {rec['per_token_stall']*1e6:.1f}us/token stall, "
          f"modeled ${rec['cost_per_token']:.6f}/token "
          f"(normalized units)\n")
    adv = rec["advice"]
    print(f"  break-even tau  : {adv['tau_be']:.3f}s")
    print(f"  resident        : "
          f"{units.human_bytes(adv['resident_bytes'])}")
    print(f"  measured hot set: {units.human_bytes(adv['hot_bytes'])} "
          f"({adv['hot_fraction']*100:.0f}% of resident)")
    print(f"  provision DRAM  : "
          f"{units.human_bytes(adv['recommended_dram_bytes'])} across "
          f"{adv['recommended_hosts']} host(s)")
    print(f"  limit           : {adv['limit']}")
    for cls, row in adv["classes"].items():
        med = row["median_interval"]
        med = f"{med:.3f}s" if isinstance(med, float) else "unmeasured"
        print(f"    class {cls:12s} keys={int(row['keys']):5d} "
              f"median={med:>10s} hot={row['hot_fraction']*100:5.1f}%")
    print(f"\n  VERDICT: {adv['verdict']}")


def run_advise_tiers(args):
    from repro.autopilot.advisor import ProvisionAdvisor
    from repro.autopilot.gate import default_classify
    from repro.autopilot.reuse import ReuseTracker
    from repro.autopilot.traces import SCENARIOS, generate
    from repro.core import CPU_DDR, GPU_GDDR, storage_next_ssd

    scenario = args.trace or "diurnal"
    if scenario not in SCENARIOS:
        sys.exit(f"--trace must be one of {SCENARIOS}")
    l_blk = int(args.obj_kib * 1024)
    trace = generate(scenario, n_steps=args.steps, seed=0)
    tracker = ReuseTracker()
    now = 0.0
    for step in trace.steps:
        for key in step:
            tracker.observe(key, default_classify(key), now)
        now += trace.step_time
    horizon = max(now, 1e-9)
    host = GPU_GDDR if args.platform == "gpu" else CPU_DDR
    advisor = ProvisionAdvisor(host, storage_next_ssd(), l_blk)
    advice = advisor.advise_tiers(
        tracker,
        access_rate=trace.accesses / horizon,
        resident_bytes=len(trace.distinct_keys()) * l_blk,
        pool_bw=args.pool_bw, pool_rtt=args.pool_rtt,
        rent_factor=args.rent_factor)
    print(f"scenario: {scenario} ({trace.accesses} accesses, "
          f"{horizon:.1f}s modeled) — four-arm hierarchy comparison")
    print(advice.report())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--platform", choices=("cpu", "gpu"), default="gpu")
    ap.add_argument("--l-blk", type=int, default=512)
    ap.add_argument("--throughput-gbs", type=float, default=200.0)
    ap.add_argument("--n-blocks", type=float, default=1e9)
    ap.add_argument("--sigma", type=float, default=1.0,
                    help="access-interval lognormal spread (locality)")
    ap.add_argument("--tail-us", type=float, default=13.0)
    ap.add_argument("--dram-gb", type=float, default=0.0,
                    help="fixed DRAM capacity (0 = provision freely)")
    ap.add_argument("--trace", default=None,
                    help="live mode: replay this autopilot trace "
                         "scenario and advise from measured telemetry")
    ap.add_argument("--steps", type=int, default=240,
                    help="live mode: trace length in decode steps")
    ap.add_argument("--obj-kib", type=float, default=128.0,
                    help="live mode: object size in KiB (distinct from "
                         "--l-blk, which is the analytic mode's block "
                         "size in bytes)")
    ap.add_argument("--advise-tiers", action="store_true",
                    help="four-arm mode: price baseline / +gpu_flash / "
                         "+pool / both against the trace's measured "
                         "reuse intervals (composes with --trace; "
                         "default scenario: diurnal)")
    ap.add_argument("--pool-bw", type=float, default=40e9,
                    help="four-arm mode: pool fabric bandwidth, B/s")
    ap.add_argument("--pool-rtt", type=float, default=2e-6,
                    help="four-arm mode: pool fabric round-trip, s")
    ap.add_argument("--rent-factor", type=float, default=0.25,
                    help="four-arm mode: pool rent as a fraction of "
                         "local DRAM rent")
    args = ap.parse_args()

    if args.advise_tiers:
        return run_advise_tiers(args)
    if args.trace:
        return run_live(args)

    plat = GPU_PLATFORM if args.platform == "gpu" else CPU_PLATFORM
    if args.dram_gb:
        import dataclasses
        plat = dataclasses.replace(plat, c_dram_total=args.dram_gb * 1e9)
    wl = LogNormalWorkload.from_total_throughput(
        throughput=args.throughput_gbs * 1e9, sigma=args.sigma,
        n_blk=args.n_blocks, l_blk=args.l_blk)
    rep = analyze_platform(plat, wl, args.l_blk,
                           LatencyTargets(tail=args.tail_us * 1e-6))

    print(f"workload: {units.human_bytes(wl.total_bytes)} across "
          f"{args.n_blocks:.0e} x {args.l_blk}B blocks, "
          f"{args.throughput_gbs:.0f} GB/s aggregate, sigma={args.sigma}")
    print(f"platform: {plat.name}, {plat.n_ssd} SSDs, host budget "
          f"{units.human_rate(plat.iops_proc)}, DRAM BW "
          f"{units.human_bytes(plat.b_dram_total)}/s")
    print()
    print(f"  usable SSD IOPS : {units.human_rate(rep.iops_ssd_usable)}"
          f"/SSD (rho_max={rep.rho_max:.2f}"
          + (", host-limited" if rep.host_limited else "") + ")")
    print(f"  break-even tau  : {units.human_time(rep.tau_break_even)}")
    print(f"  T_B / T_S / T_C : {units.human_time(rep.th.t_b)} / "
          f"{units.human_time(rep.th.t_s)} / "
          f"{units.human_time(rep.th.t_c)}")
    print(f"  DRAM for viable : {units.human_bytes(rep.c_dram_viable)}")
    print(f"  DRAM for optimal: {units.human_bytes(rep.c_dram_optimal)}")
    print(f"  DRAM BW at opt  : "
          f"{units.human_bytes(rep.dram_bw_use_optimal)}/s")
    print()
    print(f"  VERDICT: {rep.verdict}")
    print(f"  ADVICE : {rep.recommendation}")


if __name__ == "__main__":
    main()
