"""Jit'd wrapper for the fused ANN distance+top-k kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import ann_topk_fwd


@functools.partial(jax.jit, static_argnames=("k", "block_q", "tile",
                                             "interpret"))
def ann_topk(queries, corpus, *, k: int = 16, block_q: int = 128,
             tile: int = 512, interpret: bool = True):
    return ann_topk_fwd(queries, corpus, k=k, block_q=block_q, tile=tile,
                        interpret=interpret)
