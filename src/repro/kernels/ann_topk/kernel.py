"""Fused L2-distance + running top-k kernel (stage 1 of the paper's
two-stage progressive ANN search, §VII-B).

Grid = (n_query_blocks, n_corpus_tiles) with the corpus axis sequential.
Each step computes the [bq, tile] squared-L2 distances to one corpus tile
entirely in VMEM (matmul on the MXU + norm terms) and folds them into a
running top-k scratch via K rounds of masked arg-min extraction — the full
[Q, N] distance matrix never touches HBM, which is the point: at
N = 8B vectors (the paper's corpus) that matrix is unmaterializable.

K is small (<= 64); extraction cost K * bq * (tile + K) flops is noise
next to the bq x tile x D matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params

BIG = 1e30


def _ann_kernel(q_ref, c_ref, od_ref, oi_ref, d_scr, i_scr, *, k: int,
                tile: int, n_tiles: int, n_corpus: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        d_scr[...] = jnp.full_like(d_scr, BIG)
        i_scr[...] = jnp.full_like(i_scr, -1)

    q = q_ref[...].astype(jnp.float32)              # [bq, D]
    c = c_ref[...].astype(jnp.float32)              # [tile, D]
    # squared L2 = |q|^2 - 2 q.c + |c|^2 ; |q|^2 is rank-constant, dropped
    dots = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    d = jnp.sum(c * c, axis=1)[None, :] - 2.0 * dots     # [bq, tile]
    ids = ti * tile + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(ids < n_corpus, d, BIG)

    # merge into running top-k: concat candidates then extract k minima
    # via masked arg-min rounds (no scatter -> Mosaic-lowerable)
    cand_d = jnp.concatenate([d_scr[...], d], axis=1)       # [bq, k+tile]
    cand_i = jnp.concatenate([i_scr[...], ids], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, cand_d.shape, 1)
    new_d, new_i = [], []
    for _ in range(k):
        am = jnp.argmin(cand_d, axis=1)                     # [bq]
        sel = col == am[:, None]
        new_d.append(jnp.min(cand_d, axis=1))
        new_i.append(jnp.sum(jnp.where(sel, cand_i, 0), axis=1))
        cand_d = jnp.where(sel, BIG, cand_d)
    d_scr[...] = jnp.stack(new_d, axis=1)
    i_scr[...] = jnp.stack(new_i, axis=1).astype(jnp.int32)

    @pl.when(ti == n_tiles - 1)
    def _finish():
        od_ref[...] = d_scr[...]
        oi_ref[...] = i_scr[...]


def ann_topk_fwd(queries, corpus, *, k: int = 16, block_q: int = 128,
                 tile: int = 512, interpret: bool = True):
    """queries [Q, D]; corpus [N, D] -> (dists [Q, k], ids [Q, k]).

    Distances omit the constant |q|^2 term (rank-preserving)."""
    Q, D = queries.shape
    N = corpus.shape[0]
    block_q = min(block_q, Q)
    tile = min(tile, N)
    nq = pl.cdiv(Q, block_q)
    nt = pl.cdiv(N, tile)
    kern = functools.partial(_ann_kernel, k=k, tile=tile, n_tiles=nt,
                             n_corpus=N)
    return pl.pallas_call(
        kern,
        grid=(nq, nt),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda qi, ti: (qi, 0)),
            pl.BlockSpec((tile, D), lambda qi, ti: (ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, ti: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, ti: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(queries, corpus)
