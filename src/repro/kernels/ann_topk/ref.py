"""Pure-jnp oracle for the fused distance+top-k kernel."""
import jax
import jax.numpy as jnp


def reference_ann_topk(queries, corpus, k: int = 16):
    """Same rank-preserving distance (no |q|^2 term)."""
    qf = queries.astype(jnp.float32)
    cf = corpus.astype(jnp.float32)
    d = jnp.sum(cf * cf, axis=1)[None, :] - 2.0 * qf @ cf.T
    neg_d, ids = jax.lax.top_k(-d, k)
    return -neg_d, ids.astype(jnp.int32)
