"""Fused RMSNorm(+scale) kernel: one HBM read, one write per row block.

Rows (tokens) are tiled in blocks of `block_rows`; the feature dim stays
whole in VMEM (d_model <= 8192 for every assigned arch = 32KB/row in f32,
well inside the ~16MB VMEM budget at the default 128-row block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-6, block_rows: int = 128,
                interpret: bool = True):
    """x [N, D]; scale [D] -> [N, D]."""
    N, D = x.shape
    block_rows = min(block_rows, N)
    n_blocks = pl.cdiv(N, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, scale)
