"""Jit'd wrapper for the fused RMSNorm kernel (arbitrary leading dims)."""
from __future__ import annotations

import functools

import jax

from .kernel import rmsnorm_fwd


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 128,
            interpret: bool = True):
    shape = x.shape
    y = rmsnorm_fwd(x.reshape(-1, shape[-1]), scale, eps=eps,
                    block_rows=block_rows, interpret=interpret)
    return y.reshape(shape)
