"""Pallas-TPU API compat helpers shared by the kernel wrappers.

Newer jax renamed `pltpu.TPUCompilerParams` to `pltpu.CompilerParams`;
resolve whichever exists so the kernels lower on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(dimension_semantics):
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=tuple(dimension_semantics))
