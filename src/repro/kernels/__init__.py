"""Pallas TPU kernels for the compute hot spots, each with a jit'd wrapper
(ops.py) and a pure-jnp oracle (ref.py). Kernels target TPU BlockSpec/VMEM
tiling and are validated on CPU in interpret mode."""
