"""Flash-attention forward kernel (causal GQA) for TPU.

Tiling: grid = (batch, q_heads, n_q_blocks, n_kv_blocks) with the kv axis
innermost and *sequential*; VMEM scratch carries the online-softmax state
(m, l, acc) across kv iterations, so the [S, T] score matrix never exists
in HBM. GQA is handled in the BlockSpec index maps (kv blocks are indexed
by h // q_per_kv), so no repeated-KV materialization either.

Block sizes default to (128, 512) — multiples of the 128-lane MXU tiling;
head_dim is padded to 128 by ops.py when needed (zamba2's hd=112).
Validated in interpret mode against ref.reference_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, block_q: int,
                      block_k: int, n_kv_blocks: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_idx = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_idx = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    run = True
    if causal:
        # whole block above the diagonal contributes nothing
        run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        # zero padded tail rows (0 * garbage would propagate NaN via p@v)
        rows = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, k.shape, 0)
        k = jnp.where(rows < seq_k, k, 0.0)
        v = jnp.where(rows < seq_k, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]
        mask = k_idx < seq_k
        if causal:
            mask &= q_idx >= k_idx
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, scale: float,
                        block_q: int = 128, block_k: int = 512,
                        interpret: bool = True):
    """q [B,H,S,hd]; k,v [B,KV,T,hd] -> o [B,H,S,hd]."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    qr = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    n_q = pl.cdiv(S, block_q)
    n_k = pl.cdiv(T, block_k)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_kv_blocks=n_k, seq_k=T)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // qr, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // qr, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
