"""Pure-jnp oracle for flash_attention (GQA, optional causal)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal: bool = True, scale: float):
    """q [B,H,S,hd]; k,v [B,KV,T,hd] -> [B,H,S,hd] (f32 math)."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    qr = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, qr, S, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bgqsd,bgtd->bgqst", qf, kf) * scale
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqst,bgtd->bgqsd", w, vf)
    return o.reshape(B, H, S, D).astype(q.dtype)
