"""Jit'd public wrapper for the flash-attention kernel.

Handles head_dim padding to the 128-lane boundary, dtype plumbing, and a
custom_vjp whose backward pass recomputes through the jnp oracle (the
forward kernel is the serving hot spot; training backward goes through
XLA — documented trade-off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import reference_attention


def _pad_head(x, target):
    d = x.shape[-1]
    if d == target:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, target - d)])


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, scale: float = None,
                    interpret: bool = True):
    """q [B,H,S,hd]; k,v [B,KV,T,hd] -> [B,H,S,hd]."""
    return _fwd_impl(q, k, v, causal, scale, interpret)


def _fwd_impl(q, k, v, causal, scale, interpret):
    D = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    Dp = max(128, -(-D // 128) * 128) if not interpret else D
    qp, kp, vp = (_pad_head(t, Dp) for t in (q, k, v))
    o = flash_attention_fwd(qp, kp, vp, causal=causal, scale=scale,
                            interpret=interpret)
    return o[..., :D]


def _fwd_vjp(q, k, v, causal, scale, interpret):
    return _fwd_impl(q, k, v, causal, scale, interpret), (q, k, v)


def _bwd_vjp(causal, scale, interpret, res, g):
    q, k, v = res
    D = q.shape[-1]
    s = scale if scale is not None else 1.0 / (D ** 0.5)
    _, vjp = jax.vjp(
        lambda q_, k_, v_: reference_attention(q_, k_, v_, causal=causal,
                                               scale=s), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd_vjp, _bwd_vjp)
