"""Single-token (decode) attention kernel over a paged/filled KV cache.

This is the IOPS-analog of the paper's fine-grained random reads: one new
query per sequence attends over a long cached context. Tiling: grid =
(batch, n_kv_blocks) with the kv axis sequential; every head of a batch
row is processed together (q is [H, hd] — small enough for VMEM at any
assigned config), so the kernel streams the cache exactly once per step.

The `length` operand masks the un-filled cache tail (per-batch fill
levels), supporting continuous batching where sequences fill at different
rates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_k: int, n_kv_blocks: int,
                   q_per_kv: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)               # [H, hd]
    k = k_ref[0].astype(jnp.float32)               # [KV, bk, hd]
    v = v_ref[0].astype(jnp.float32)
    H, hd = q.shape
    KV = k.shape[0]
    # zero the un-filled tail: padded cache blocks may hold garbage and
    # 0 * garbage propagates NaN through the p @ v accumulation
    cols = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (KV, block_k, hd), 1)
    live = cols < len_ref[0]
    k = jnp.where(live, k, 0.0)
    v = jnp.where(live, v, 0.0)
    qg = q.reshape(KV, q_per_kv, hd)
    # scores [KV, q_per_kv, bk]
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale
    pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (KV, q_per_kv, block_k), 2)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=2))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=2)
    # acc [KV, q_per_kv, hd] += p @ v
    upd = jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[..., None] + upd
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(H, hd).astype(o_ref.dtype)


def decode_attention_fwd(q, k, v, lengths, *, scale: float,
                         block_k: int = 512, interpret: bool = True):
    """q [B,H,hd]; k,v [B,KV,T,hd]; lengths [B] int32 -> o [B,H,hd]."""
    B, H, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    qr = H // KV
    block_k = min(block_k, T)
    n_k = pl.cdiv(T, block_k)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, n_kv_blocks=n_k,
        q_per_kv=qr)

    return pl.pallas_call(
        kernel,
        grid=(B, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, ki: (b,)),
            pl.BlockSpec((1, H, hd), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, KV, block_k, hd), lambda b, ki: (b, 0, ki, 0)),
            pl.BlockSpec((1, KV, block_k, hd), lambda b, ki: (b, 0, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((KV, qr), jnp.float32),
            pltpu.VMEM((KV, qr), jnp.float32),
            pltpu.VMEM((KV, qr, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(lengths, q, k, v)
