"""Pure-jnp oracle for decode attention with per-batch fill lengths."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_decode_attention(q, k, v, lengths, *, scale: float):
    """q [B,H,hd]; k,v [B,KV,T,hd]; lengths [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    qr = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, qr, hd)
    s = jnp.einsum("bgqd,bgtd->bgqt", qf, k.astype(jnp.float32)) * scale
    valid = jnp.arange(T)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqt,bgtd->bgqd", w, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
