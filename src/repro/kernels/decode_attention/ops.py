"""Jit'd wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import decode_attention_fwd


@functools.partial(jax.jit, static_argnames=("scale", "block_k",
                                             "interpret"))
def decode_attention(q, k, v, lengths, *, scale: float = None,
                     block_k: int = 512, interpret: bool = True):
    """One-token attention over a filled KV cache.

    q [B,H,hd]; k,v [B,KV,T,hd]; lengths [B] int32."""
    hd = q.shape[-1]
    s = scale if scale is not None else 1.0 / (hd ** 0.5)
    return decode_attention_fwd(q, k, v, lengths.astype(jnp.int32),
                                scale=s, block_k=block_k,
                                interpret=interpret)
