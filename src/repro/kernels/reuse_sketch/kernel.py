"""Batched decayed log-bucket reuse-interval sketch update (autopilot).

The autopilot's `ReuseTracker` keeps, per key class (KV sessions, MoE
experts, scan tenants, ...), a histogram over log2-spaced reuse-interval
buckets: bucket b covers [tau0 * 2^b, tau0 * 2^(b+1)). Every decode step
contributes one batch of measured intervals (now - last_seen for each
key the step touched), and the whole sketch ages by a multiplicative
`decay` so the estimate tracks workload drift (diurnal shifts, bursts).

TPU adaptation: a step touches thousands of keys (full slot grids, MoE
routings), so the update is one Pallas launch instead of a host-side
scatter loop. Grid = (C,): program c reduces the whole batch against
its class row — bucketization is a vectorized log2/floor on the VPU and
the scatter-add becomes a dense one-hot [N, B] reduction (B is small,
so the dense form is cheaper than a serialized scatter and has no
write conflicts by construction). The batch is padded to a fixed N by
the wrapper; padding slots carry interval <= 0 and are masked out, the
same convention the numpy oracle uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sketch_kernel(iv_ref, cls_ref, hist_ref, out_ref, *, tau0: float,
                   decay: float, n_buckets: int):
    c = pl.program_id(0)
    iv = iv_ref[...]                              # [N] float32
    cls = cls_ref[...]                            # [N] int32
    valid = (iv > 0) & (cls == c)
    safe = jnp.maximum(iv, jnp.float32(1e-30))
    b = jnp.floor(jnp.log2(safe / jnp.float32(tau0)))
    b = jnp.clip(b, 0, n_buckets - 1).astype(jnp.int32)
    onehot = b[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, n_buckets), 1)             # [N, B]
    counts = jnp.sum(
        jnp.where(onehot & valid[:, None], jnp.float32(1.0),
                  jnp.float32(0.0)), axis=0)
    out_ref[0, :] = jnp.float32(decay) * hist_ref[0, :] + counts


def reuse_sketch_fwd(hist, intervals, class_ids, *, tau0: float,
                     decay: float, interpret: bool = True):
    """hist [C, B] f32; intervals [N] f32 (<=0 skipped); class_ids [N]
    i32 (rows outside [0, C) skipped). Returns the updated [C, B] hist."""
    C, B = hist.shape
    N = intervals.shape[0]
    kern = functools.partial(_sketch_kernel, tau0=float(tau0),
                             decay=float(decay), n_buckets=B)
    return pl.pallas_call(
        kern,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((N,), lambda c: (0,)),
            pl.BlockSpec((N,), lambda c: (0,)),
            pl.BlockSpec((1, B), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((C, B), jnp.float32),
        interpret=interpret,
    )(intervals, class_ids, hist)
