"""Jit'd wrapper: pads the interval batch to a fixed width (stable jit
cache across steps) and runs the sketch-update kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import reuse_sketch_fwd


@functools.partial(jax.jit,
                   static_argnames=("tau0", "decay", "interpret"))
def _update(hist, intervals, class_ids, *, tau0, decay, interpret):
    return reuse_sketch_fwd(hist, intervals, class_ids, tau0=tau0,
                            decay=decay, interpret=interpret)


def reuse_sketch_update(hist, intervals, class_ids, *, tau0: float,
                        decay: float, batch_pad: int = 256,
                        interpret: bool = True):
    """Decayed sketch update for one step's batch.

    hist [C, B] float32; intervals [N] float32 (<= 0 slots skipped);
    class_ids [N] int32. The batch is padded (interval 0, class -1) to
    `batch_pad` rounded up to a power of two of it, so a control plane
    whose per-step batch wanders from 300 to 300k keys compiles
    O(log(max_n / batch_pad)) programs total instead of one per
    multiple of `batch_pad` — pad slots carry class -1 and are skipped,
    so the result is width-independent."""
    hist = jnp.asarray(hist, jnp.float32)
    iv = np.asarray(intervals, np.float32).ravel()
    cls = np.asarray(class_ids, np.int32).ravel()
    if iv.shape != cls.shape:
        raise ValueError("intervals and class_ids must match in length")
    n = int(iv.size)
    if not batch_pad:
        width = max(n, 1)
    else:
        width = int(batch_pad)
        while width < n:
            width *= 2
    pad = width - n
    iv = np.concatenate([iv, np.zeros(pad, np.float32)])
    cls = np.concatenate([cls, np.full(pad, -1, np.int32)])
    return _update(hist, jnp.asarray(iv), jnp.asarray(cls),
                   tau0=float(tau0), decay=float(decay),
                   interpret=interpret)
