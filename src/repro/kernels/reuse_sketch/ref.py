"""Pure-numpy oracle for the decayed reuse-interval sketch update.

All arithmetic is float32 to match the kernel bit-for-bit: the bucket of
an interval is floor(log2(interval / tau0)) clipped to [0, B), computed
in float32 in both implementations, so bucket counts are tolerance-exact
(identical) between kernel and oracle.
"""
from __future__ import annotations

import numpy as np


def reference_reuse_sketch(hist, intervals, class_ids, *, tau0: float,
                           decay: float):
    """hist [C, B] float32; intervals [N] float32 (<= 0 marks an invalid
    slot: first touch or padding — skipped); class_ids [N] int32 (out of
    range also skipped). Returns decay * hist + per-(class, bucket)
    counts of this batch."""
    hist = np.asarray(hist, np.float32)
    intervals = np.asarray(intervals, np.float32)
    class_ids = np.asarray(class_ids, np.int32)
    C, B = hist.shape
    valid = (intervals > 0) & (class_ids >= 0) & (class_ids < C)
    safe = np.maximum(intervals, np.float32(1e-30))
    b = np.floor(np.log2(safe / np.float32(tau0), dtype=np.float32))
    b = np.clip(b, 0, B - 1).astype(np.int32)
    counts = np.zeros((C, B), np.float32)
    np.add.at(counts, (class_ids[valid], b[valid]), np.float32(1.0))
    return np.float32(decay) * hist + counts
