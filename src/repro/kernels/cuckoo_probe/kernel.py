"""Batched blocked-Cuckoo bucket probe kernel (case study 1, §VII-A).

The SSD-resident table is modeled as an HBM-resident array of buckets
(one bucket == one 512B flash block == `bucket_size` key/value slots).
Each lookup touches exactly two buckets (h1, h2) — the paper's "one or
two SSD block reads per GET".

TPU adaptation of the random-access pattern: bucket indices are computed
on the host side of the kernel (cheap hash) and passed as a *scalar-
prefetched* operand; the grid walks lookups in blocks and the BlockSpec
index_map uses the prefetched ids to DMA exactly the two candidate
buckets per lookup into VMEM — the TPU analogue of the paper's
fine-grained 512B random reads (gather-via-scalar-prefetch, the same
mechanism paged attention kernels use).

Grid = (n_lookups,): lookup i compares its key against both candidate
buckets' key slots and emits (found flag, value).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _probe_kernel(b1_idx, b2_idx, keys_ref, bk1_ref, bv1_ref, bk2_ref,
                  bv2_ref, found_ref, val_ref):
    key = keys_ref[0]
    k1, v1 = bk1_ref[0], bv1_ref[0]          # [slots]
    k2, v2 = bk2_ref[0], bv2_ref[0]
    hit1 = k1 == key
    hit2 = k2 == key
    any1 = jnp.any(hit1)
    any2 = jnp.any(hit2)
    # pin the accumulator dtype: some jax versions promote integer sums
    # to int64 inside kernel tracing, which cannot store to an i32 ref
    val1 = jnp.sum(jnp.where(hit1, v1, 0), dtype=jnp.int32)
    val2 = jnp.sum(jnp.where(hit2, v2, 0), dtype=jnp.int32)
    found_ref[0] = (any1 | any2).astype(jnp.int32)
    val_ref[0] = jnp.where(any1, val1, val2).astype(jnp.int32)


def cuckoo_probe_fwd(keys, b1, b2, bucket_keys, bucket_vals, *,
                     interpret: bool = True):
    """keys [N] int32 (0 = empty sentinel); b1,b2 [N] int32 bucket ids;
    bucket_keys/vals [n_buckets, slots] int32.

    Returns (found [N] int32, values [N] int32)."""
    N = keys.shape[0]
    nb, slots = bucket_keys.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # b1, b2 feed the index maps
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1,), lambda i, b1, b2: (i,)),
            pl.BlockSpec((1, slots), lambda i, b1, b2: (b1[i], 0)),
            pl.BlockSpec((1, slots), lambda i, b1, b2: (b1[i], 0)),
            pl.BlockSpec((1, slots), lambda i, b1, b2: (b2[i], 0)),
            pl.BlockSpec((1, slots), lambda i, b1, b2: (b2[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, b1, b2: (i,)),
            pl.BlockSpec((1,), lambda i, b1, b2: (i,)),
        ],
    )
    return pl.pallas_call(
        _probe_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ],
        interpret=interpret,
    )(b1, b2, keys, bucket_keys, bucket_vals, bucket_keys, bucket_vals)
