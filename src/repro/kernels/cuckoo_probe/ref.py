"""Pure-jnp oracle for the cuckoo bucket probe."""
import jax.numpy as jnp


def reference_cuckoo_probe(keys, b1, b2, bucket_keys, bucket_vals):
    k1 = bucket_keys[b1]                  # [N, slots]
    v1 = bucket_vals[b1]
    k2 = bucket_keys[b2]
    v2 = bucket_vals[b2]
    hit1 = k1 == keys[:, None]
    hit2 = k2 == keys[:, None]
    any1 = jnp.any(hit1, axis=1)
    any2 = jnp.any(hit2, axis=1)
    val1 = jnp.sum(jnp.where(hit1, v1, 0), axis=1)
    val2 = jnp.sum(jnp.where(hit2, v2, 0), axis=1)
    found = (any1 | any2).astype(jnp.int32)
    return found, jnp.where(any1, val1, val2)
