"""Jit'd wrapper: hashes keys to candidate buckets, runs the probe kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cuckoo_probe_fwd


def hash_pair(keys, n_buckets: int):
    """Two independent 32-bit multiplicative hashes -> bucket ids."""
    k = keys.astype(jnp.uint32)
    h1 = (k * jnp.uint32(0x9E3779B1)) ^ (k >> 16)
    h2 = (k * jnp.uint32(0x85EBCA77)) ^ (k >> 13)
    return ((h1 % jnp.uint32(n_buckets)).astype(jnp.int32),
            (h2 % jnp.uint32(n_buckets)).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def cuckoo_probe(keys, bucket_keys, bucket_vals, *, interpret: bool = True):
    """Batched GET. keys [N] int32; table [n_buckets, slots].

    Returns (found [N] int32, values [N] int32)."""
    b1, b2 = hash_pair(keys, bucket_keys.shape[0])
    return cuckoo_probe_fwd(keys, b1, b2, bucket_keys, bucket_vals,
                            interpret=interpret)
