from . import attention, config, ffn, layers, model, moe, ssm, xlstm  # noqa
from .config import (AttnSpec, EncoderConfig, FfnSpec, MLstmSpec,  # noqa
                     Mamba2Spec, ModelConfig, MoeSpec, SLstmSpec)
