"""Dense feed-forward sublayer (SwiGLU / GeGLU / GELU / ReLU^2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import FfnSpec, ModelConfig
from .layers import Ctx, activation, dense_init


def init(key, cfg: ModelConfig, spec: FfnSpec):
    d, f = cfg.d_model, spec.d_ff
    gated = spec.act in ("swiglu", "geglu")
    k1, k2, k3 = jax.random.split(key, 3)
    params = {"w_in": dense_init(k1, (d, f), fan_in=d),
              "w_out": dense_init(k2, (f, d), fan_in=f)}
    if gated:
        params["w_gate"] = dense_init(k3, (d, f), fan_in=d)
    return params, logical(cfg, spec)


def logical(cfg: ModelConfig, spec: FfnSpec):
    out = {"w_in": ("embed", "ffn"), "w_out": ("ffn", "embed")}
    if spec.act in ("swiglu", "geglu"):
        out["w_gate"] = ("embed", "ffn")
    return out


def apply(params, x, spec: FfnSpec, cfg: ModelConfig, ctx: Ctx):
    dt = ctx.compute_dtype
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dt))
    if spec.act in ("swiglu", "geglu"):
        act = jax.nn.silu if spec.act == "swiglu" else jax.nn.gelu
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
        h = act(g) * h
    else:
        h = activation(spec.act)(h)
    h = ctx.rules.constrain(h, "batch", None, "act_ffn")
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(dt))
