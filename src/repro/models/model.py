"""Model assembly: embedding -> scanned group stack (+tail) -> norm -> logits.

Three entry points share one stack implementation:

  forward(...)   train-mode forward, full-sequence logits (via loss_and_aux)
  prefill(...)   fills KV/state caches, returns last-position logits
  decode(...)    one-token step against the caches

The layer stack lowers as a single `lax.scan` over stacked group params, so
HLO size / compile time are depth-independent. Shared sublayers (zamba2's
shared attention) live outside the scan and are closed over — XLA hoists
them as loop invariants. Heterogeneous remainders go in `cfg.tail`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import Rules
from . import attention, ffn, moe, ssm, xlstm
from .config import (AttnSpec, FfnSpec, MLstmSpec, Mamba2Spec, ModelConfig,
                     MoeSpec, SLstmSpec)
from .layers import Ctx, apply_norm, embed_init, norm_init, \
    sinusoidal_positions


# ---------------------------------------------------------------------------
# Sublayer dispatch
# ---------------------------------------------------------------------------

_INIT = {
    "attn": attention.init,
    "ffn": ffn.init,
    "moe": moe.init,
    "mamba2": ssm.init,
    "mlstm": xlstm.init_mlstm,
    "slstm": xlstm.init_slstm,
}

_LOGICAL = {
    "attn": attention.logical,
    "ffn": ffn.logical,
    "moe": moe.logical,
    "mamba2": ssm.logical,
    "mlstm": xlstm.logical_mlstm,
    "slstm": xlstm.logical_slstm,
}

_HAS_CACHE = {"attn", "mamba2", "mlstm", "slstm"}


def _sub_init(key, cfg: ModelConfig, spec):
    k1, k2 = jax.random.split(key)
    mixer, _ = _INIT[spec.kind](k1, cfg, spec)
    nrm, _ = norm_init(cfg.d_model, cfg.norm)
    return {"norm": nrm, "mixer": mixer}


def _sub_logical(cfg: ModelConfig, spec):
    _, nrm_log = norm_init(cfg.d_model, cfg.norm)
    return {"norm": nrm_log, "mixer": _LOGICAL[spec.kind](cfg, spec)}


def _sub_apply(params, x, spec, cfg: ModelConfig, ctx: Ctx, cache=None):
    h = apply_norm(params["norm"], x, cfg.norm, cfg.norm_eps)
    # explicit TP gather point on the bf16 norm output: without this, SPMD
    # is free to hoist the layer-input all-gather above the f32->bf16
    # convert and move the activations at twice the wire bytes
    h = ctx.rules.constrain(h, "batch", None, "act_embed")
    kind = spec.kind
    if kind == "attn":
        out, nc = attention.apply(params["mixer"], h, spec, cfg, ctx, cache)
    elif kind == "ffn":
        out, nc = ffn.apply(params["mixer"], h, spec, cfg, ctx), None
    elif kind == "moe":
        out, nc = moe.apply(params["mixer"], h, spec, cfg, ctx), None
    elif kind == "mamba2":
        out, nc = ssm.apply(params["mixer"], h, spec, cfg, ctx, cache)
    elif kind == "mlstm":
        out, nc = xlstm.apply_mlstm(params["mixer"], h, spec, cfg, ctx, cache)
    elif kind == "slstm":
        out, nc = xlstm.apply_slstm(params["mixer"], h, spec, cfg, ctx, cache)
    else:
        raise ValueError(kind)
    # constrain the sublayer output to the residual layout BEFORE the add:
    # the out-projections contract TP-sharded dims (heads/ffn), so this
    # lets SPMD emit a reduce-scatter straight into the res_embed sharding
    # instead of a full all-reduce followed by a re-slice
    out = ctx.rules.constrain(out, "batch", None, "res_embed")
    return x + out, nc


def _sub_cache(cfg, spec, batch, max_len, dtype, enc_len):
    if spec.kind == "attn":
        return attention.init_cache(cfg, spec, batch, max_len, dtype, enc_len)
    # recurrent states stay in their native dtypes (int8 applies to KV only)
    state_dtype = jnp.bfloat16 if dtype == jnp.int8 else dtype
    if spec.kind == "mamba2":
        return ssm.init_cache(cfg, spec, batch, state_dtype)
    if spec.kind == "mlstm":
        return xlstm.init_mlstm_cache(cfg, spec, batch, state_dtype)
    if spec.kind == "slstm":
        return xlstm.init_slstm_cache(cfg, spec, batch, state_dtype)
    return None


def _sub_cache_logical(spec, kv_quant=False):
    if spec.kind == "attn":
        return attention.cache_logical(spec, quantized=kv_quant)
    if spec.kind == "mamba2":
        return ssm.cache_logical(spec)
    if spec.kind == "mlstm":
        return xlstm.mlstm_cache_logical(spec)
    if spec.kind == "slstm":
        return xlstm.slstm_cache_logical(spec)
    return None


def _key(li: int, si: int) -> str:
    return f"L{li}S{si}"


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    """Returns (params, logical) pytrees. Group params are stacked [G, ...]."""
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    logical: Dict[str, Any] = {}

    params["embed"] = embed_init(keys[0], (cfg.vocab, cfg.d_model))
    logical["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(keys[6], (cfg.vocab, cfg.d_model))
        logical["unembed"] = ("vocab", "embed")

    def _is_names(v):
        return isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v)

    def _stacked_logical(spec):
        return jax.tree.map(lambda names: ("layers",) + tuple(names),
                            _sub_logical(cfg, spec), is_leaf=_is_names)

    shared_specs = [(li, si, s) for li, layer in enumerate(cfg.pattern)
                    for si, s in enumerate(layer)
                    if getattr(s, "shared", False)]
    if shared_specs:
        params["shared"], logical["shared"] = {}, {}
        for (li, si, s), k in zip(
                shared_specs, jax.random.split(keys[1], len(shared_specs))):
            params["shared"][_key(li, si)] = _sub_init(k, cfg, s)
            logical["shared"][_key(li, si)] = _sub_logical(cfg, s)

    def init_group(k):
        out = {}
        n_sub = sum(len(layer) for layer in cfg.pattern)
        ks = jax.random.split(k, n_sub)
        i = 0
        for li, layer in enumerate(cfg.pattern):
            for si, s in enumerate(layer):
                if not getattr(s, "shared", False):
                    out[_key(li, si)] = _sub_init(ks[i], cfg, s)
                i += 1
        return out

    params["groups"] = jax.vmap(init_group)(
        jax.random.split(keys[2], cfg.n_groups))
    logical["groups"] = {
        _key(li, si): _stacked_logical(s)
        for li, layer in enumerate(cfg.pattern)
        for si, s in enumerate(layer) if not getattr(s, "shared", False)}

    if cfg.tail:
        params["tail"], logical["tail"] = {}, {}
        flat_tail = [(li, si, s) for li, layer in enumerate(cfg.tail)
                     for si, s in enumerate(layer)]
        for (li, si, s), k in zip(
                flat_tail, jax.random.split(keys[3], len(flat_tail))):
            params["tail"][_key(li, si)] = _sub_init(k, cfg, s)
            logical["tail"][_key(li, si)] = _sub_logical(cfg, s)

    params["final_norm"], logical["final_norm"] = norm_init(
        cfg.d_model, cfg.norm)

    if cfg.encoder is not None:
        enc = cfg.encoder

        def init_enc_group(k):
            out = {}
            flat = [(li, si, s) for li, layer in enumerate(enc.pattern)
                    for si, s in enumerate(layer)]
            for (li, si, s), kk in zip(flat,
                                       jax.random.split(k, len(flat))):
                out[_key(li, si)] = _sub_init(kk, cfg, s)
            return out

        egp = jax.vmap(init_enc_group)(
            jax.random.split(keys[4], enc.n_groups))
        elog = {_key(li, si): _stacked_logical(s)
                for li, layer in enumerate(enc.pattern)
                for si, s in enumerate(layer)}
        fn, fnl = norm_init(cfg.d_model, cfg.norm)
        params["encoder"] = {"groups": egp, "final_norm": fn}
        logical["encoder"] = {"groups": elog, "final_norm": fnl}

    return params, logical


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, enc_len: Optional[int] = None):
    """Zero caches, grouped like params: {"groups": {key: [G,...]}, "tail"}."""
    enc_len = enc_len if enc_len is not None else (
        cfg.encoder.n_frames if cfg.encoder else 0)
    groups = {}
    for li, layer in enumerate(cfg.pattern):
        for si, s in enumerate(layer):
            c = _sub_cache(cfg, s, batch, max_len, dtype, enc_len)
            if c is not None:
                groups[_key(li, si)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a, (cfg.n_groups,) + a.shape).copy(), c)
    tail = {}
    for li, layer in enumerate(cfg.tail):
        for si, s in enumerate(layer):
            c = _sub_cache(cfg, s, batch, max_len, dtype, enc_len)
            if c is not None:
                tail[_key(li, si)] = c
    return {"groups": groups, "tail": tail}


def cache_logical_tree(cfg: ModelConfig, kv_quant: bool = False):
    groups, tail = {}, {}
    for li, layer in enumerate(cfg.pattern):
        for si, s in enumerate(layer):
            lg = _sub_cache_logical(s, kv_quant)
            if lg is not None:
                groups[_key(li, si)] = jax.tree.map(
                    lambda names: ("layers",) + tuple(names), lg,
                    is_leaf=lambda v: isinstance(v, tuple) and all(
                        isinstance(e, (str, type(None))) for e in v))
    for li, layer in enumerate(cfg.tail):
        for si, s in enumerate(layer):
            lg = _sub_cache_logical(s, kv_quant)
            if lg is not None:
                tail[_key(li, si)] = lg
    return {"groups": groups, "tail": tail}


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------

def _apply_group(pattern, gparams, shared, x, cfg, ctx: Ctx, gcache):
    new_cache = {}
    ctx = dataclasses.replace(ctx, aux={})
    for li, layer in enumerate(pattern):
        for si, spec in enumerate(layer):
            k = _key(li, si)
            p = shared[k] if getattr(spec, "shared", False) else gparams[k]
            c = gcache.get(k) if gcache else None
            x, nc = _sub_apply(p, x, spec, cfg, ctx, c)
            if nc is not None:
                new_cache[k] = nc
    x = ctx.rules.constrain(x, "batch", None, "res_embed")
    aux = functools.reduce(jnp.add, ctx.aux.values(), jnp.zeros((), jnp.float32))
    return x, new_cache, aux


def run_stack(params, x, cfg: ModelConfig, ctx: Ctx, caches=None,
              remat: bool = False, remat_policy=None,
              unroll: bool = False):
    """Returns (x, new_caches, aux_loss).

    `unroll=True` replaces the group scan with a python loop — used by the
    roofline cost probes (HLO cost analysis counts a scan body once, so
    probes compile unrolled G=1 and G=2 stacks and take the marginal)."""
    shared = params.get("shared", {})
    gcaches = caches["groups"] if caches else None

    def group_fn(gp, h, gc):
        return _apply_group(cfg.pattern, gp, shared, h, cfg, ctx, gc)

    wrapped = jax.checkpoint(group_fn, policy=remat_policy) if remat \
        else group_fn

    if unroll:
        aux = jnp.zeros((), jnp.float32)
        ncs = []
        for i in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[i], params["groups"])
            gc = (jax.tree.map(lambda a: a[i], gcaches)
                  if gcaches is not None else None)
            x, nc, aux_d = wrapped(gp, x, gc)
            aux = aux + aux_d
            ncs.append(nc)
        new_gcaches = (jax.tree.map(lambda *a: jnp.stack(a), *ncs)
                       if gcaches is not None else None)
    else:
        def body(carry, xs):
            h, aux = carry
            gp = xs[0] if gcaches is not None else xs
            gc = xs[1] if gcaches is not None else None
            h, nc, aux_d = wrapped(gp, h, gc)
            return (h, aux + aux_d), nc

        xs = (params["groups"], gcaches) if gcaches is not None \
            else params["groups"]
        (x, aux), new_gcaches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs)

    new_tail = {}
    tcaches = caches["tail"] if caches else None
    for li, layer in enumerate(cfg.tail):
        for si, spec in enumerate(layer):
            k = _key(li, si)
            p = params["tail"][k]
            c = tcaches.get(k) if tcaches else None
            ctx2 = dataclasses.replace(ctx, aux={})
            x, nc = _sub_apply(p, x, spec, cfg, ctx2, c)
            aux = aux + functools.reduce(
                jnp.add, ctx2.aux.values(), jnp.zeros((), jnp.float32))
            if nc is not None:
                new_tail[k] = nc

    new_caches = ({"groups": new_gcaches, "tail": new_tail}
                  if caches is not None else None)
    return x, new_caches, aux


def run_encoder(params, frames, cfg: ModelConfig, ctx: Ctx):
    """Whisper-style encoder over precomputed frame embeddings [B,F,D]."""
    enc = cfg.encoder
    B, F, D = frames.shape
    x = frames + sinusoidal_positions(F, D).astype(frames.dtype)[None]
    x = ctx.rules.constrain(x, "batch", None, "res_embed")
    ectx = dataclasses.replace(
        ctx, positions=jnp.broadcast_to(jnp.arange(F)[None], (B, F)),
        aux={})

    def body(h, gp):
        h, _, _ = _apply_group(enc.pattern, gp, {}, h, cfg, ectx, None)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["groups"])
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm,
                      cfg.norm_eps)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _embed_tokens(params, cfg: ModelConfig, tokens, dtype):
    x = params["embed"].astype(dtype)[tokens]
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model)
    return x


def _default_positions(cfg: ModelConfig, B: int, S: int, offset=0):
    pos = jnp.broadcast_to(jnp.arange(S)[None] + offset, (B, S))
    if any(s.kind == "attn" and s.rope == "mrope"
           for _, _, _, s in cfg.sublayers()):
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def _logits(params, cfg: ModelConfig, x, ctx: Ctx):
    table = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) \
            * cfg.final_logit_softcap
    return ctx.rules.constrain(logits, "batch", None, "act_vocab")


def forward(params, cfg: ModelConfig, rules: Rules, batch: Dict[str, Any],
            compute_dtype=jnp.bfloat16, remat: bool = True,
            remat_policy=None, cost_exact: bool = False,
            unroll: bool = False):
    """Train-mode forward. Returns (logits [B,S,V], aux_loss)."""
    tokens = batch["tokens"]
    B, S_tok = tokens.shape
    x = _embed_tokens(params, cfg, tokens, compute_dtype)
    if cfg.modality == "vlm" and "vision_embeds" in batch:
        x = jnp.concatenate(
            [batch["vision_embeds"].astype(compute_dtype), x], axis=1)
    B, S, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    ctx = Ctx(rules=rules, mode="train", positions=positions,
              compute_dtype=compute_dtype, cost_exact=cost_exact)
    if cfg.encoder is not None:
        ctx.enc_out = run_encoder(params, batch["frames"].astype(
            compute_dtype), cfg, ctx)
    x = rules.constrain(x, "batch", None, "res_embed")
    x, _, aux = run_stack(params, x, cfg, ctx, caches=None, remat=remat,
                          remat_policy=remat_policy, unroll=unroll)
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return _logits(params, cfg, x, ctx), aux


def loss_and_aux(params, cfg: ModelConfig, rules: Rules, batch,
                 compute_dtype=jnp.bfloat16, remat: bool = True,
                 remat_policy=None, z_loss: float = 1e-4,
                 cost_exact: bool = False, unroll: bool = False):
    """Next-token CE (+z-loss, +MoE aux). Returns (loss, metrics)."""
    logits, aux = forward(params, cfg, rules, batch, compute_dtype,
                          remat, remat_policy, cost_exact, unroll)
    tokens = batch["tokens"]
    B, S_tok = tokens.shape
    S = logits.shape[1]
    off = S - S_tok                      # vision prefix (loss on text only)
    logits_t = logits[:, off:off + S_tok - 1]
    targets = tokens[:, 1:]
    lf = logits_t.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(gold) if mask is None else \
        mask[:, 1:].astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (((lse - gold) * mask).sum() / denom)
    zl = z_loss * (((lse ** 2) * mask).sum() / denom)
    loss = ce + zl + aux
    return loss, {"ce": ce, "z_loss": zl, "aux": aux,
                  "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}


def prefill(params, cfg: ModelConfig, rules: Rules, batch, cache,
            compute_dtype=jnp.bfloat16, cost_exact: bool = False,
            unroll: bool = False, last_index=None):
    """Fill caches from a prompt. Returns (new_cache, last_logits [B,V]).

    `last_index` (traced scalar) selects which position's logits to
    return instead of the final one — the serving engine right-pads
    prompts to power-of-two buckets (one compile per bucket instead of
    one per exact length) and still needs the logits of the last *real*
    token; causality keeps positions < last_index unaffected by pads."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, cfg, tokens, compute_dtype)
    if cfg.modality == "vlm" and "vision_embeds" in batch:
        x = jnp.concatenate(
            [batch["vision_embeds"].astype(compute_dtype), x], axis=1)
    B, S, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, B, S)
    ctx = Ctx(rules=rules, mode="prefill", positions=positions,
              cache_index=jnp.zeros((), jnp.int32),
              compute_dtype=compute_dtype, cost_exact=cost_exact)
    if cfg.encoder is not None:
        ctx.enc_out = run_encoder(params, batch["frames"].astype(
            compute_dtype), cfg, ctx)
    x = rules.constrain(x, "batch", None, "res_embed")
    x, new_cache, _ = run_stack(params, x, cfg, ctx, caches=cache,
                                unroll=unroll)
    if last_index is None:
        x_last = x[:, -1:]
    else:
        x_last = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(last_index, jnp.int32), 1, axis=1)
    x_last = apply_norm(params["final_norm"], x_last, cfg.norm,
                        cfg.norm_eps)
    logits = _logits(params, cfg, x_last, ctx)[:, 0]
    return new_cache, logits


def decode_step(params, cfg: ModelConfig, rules: Rules, token, cache,
                index, compute_dtype=jnp.bfloat16,
                cost_exact: bool = False, unroll: bool = False):
    """One decode step. token [B,1] int32; index scalar int32 (fill point).
    Returns (new_cache, logits [B,V])."""
    B = token.shape[0]
    x = _embed_tokens(params, cfg, token, compute_dtype)
    idx = jnp.asarray(index)
    pos = (idx[:, None] if idx.ndim == 1
           else jnp.broadcast_to(idx[None, None], (B, 1)))
    if any(s.kind == "attn" and s.rope == "mrope"
           for _, _, _, s in cfg.sublayers()):
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    ctx = Ctx(rules=rules, mode="decode", positions=pos, cache_index=index,
              compute_dtype=compute_dtype)
    x = rules.constrain(x, "batch", None, "res_embed")
    x, new_cache, _ = run_stack(params, x, cfg, ctx, caches=cache,
                                unroll=unroll)
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = _logits(params, cfg, x, ctx)[:, 0]
    return new_cache, logits
