"""Mixture-of-experts sublayer with true expert parallelism.

Routing (top-k over n_experts) happens globally under GSPMD; dispatch,
expert FFN, and combine run inside a `shard_map` over the "model" axis:

  * experts are sharded over "model" (E_loc = E / TP per rank),
  * activations enter replicated over "model" and sharded over the data
    axes, so *dispatch needs no collective at all* — every model rank
    already holds the tokens of its data shard and simply selects the
    choices that route to its local experts,
  * combine is a single psum over "model" (each rank contributes the
    outputs of its experts, zeros elsewhere).

This replaces the classic all_to_all dispatch: with model-replicated
activations the all_to_all is provably redundant (its input is already
resident). The trade is the combine all-reduce of one [T_loc, D] tensor
per layer — measured in the roofline as the MoE collective term.

Capacity is static: C = ceil(capacity_factor * T_loc * top_k / E) per
expert per data shard; overflow tokens are dropped from that expert (the
gate mass renormalizes through the residual stream, GShard-style).

When parameters are FSDP-sharded over "data" (training), expert weights
are all-gathered over the fsdp axis inside the shard_map — the standard
ZeRO-3 gather-at-use, visible as the fsdp collective term.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard_map_compat
from .config import MoeSpec, ModelConfig
from .layers import Ctx, dense_init
from . import ffn as ffn_mod
from .config import FfnSpec


def init(key, cfg: ModelConfig, spec: MoeSpec):
    d, f, e = cfg.d_model, spec.d_ff, spec.n_experts
    gated = spec.act in ("swiglu", "geglu")
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, e), fan_in=d),
        "w_in": dense_init(ks[1], (e, d, f), fan_in=d),
        "w_out": dense_init(ks[2], (e, f, d), fan_in=f),
    }
    if gated:
        params["w_gate"] = dense_init(ks[3], (e, d, f), fan_in=d)
    if spec.shared_d_ff:
        params["shared"], _ = ffn_mod.init(
            ks[4], cfg, FfnSpec(d_ff=spec.shared_d_ff, act=spec.act))
    return params, logical(cfg, spec)


def logical(cfg: ModelConfig, spec: MoeSpec):
    out = {
        "router": ("embed", None),
        "w_in": ("experts", "expert_ffn", "moe_ffn"),
        "w_out": ("experts", "moe_ffn", "expert_ffn"),
    }
    if spec.act in ("swiglu", "geglu"):
        out["w_gate"] = ("experts", "expert_ffn", "moe_ffn")
    if spec.shared_d_ff:
        out["shared"] = ffn_mod.logical(
            cfg, FfnSpec(d_ff=spec.shared_d_ff, act=spec.act))
    return out


def _route(params, x, spec: MoeSpec, ctx: Ctx):
    """Global routing. x [B,S,D] -> gates [B,S,K], idx [B,S,K], aux loss."""
    logits = jnp.einsum("bsd,de->bse", x,
                        params["router"].astype(ctx.compute_dtype)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, spec.top_k)
    if spec.top_k > 1:                              # renormalize kept mass
        gates = gates / jnp.maximum(
            gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = spec.n_experts
    sel = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)
    f_e = sel.mean(axis=(0, 1))
    p_e = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f_e * p_e)
    # router z-loss (stabilizes logits)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    ctx.add_aux("moe_aux_loss", spec.aux_loss_weight * aux + 1e-4 * z)
    return gates.astype(ctx.compute_dtype), idx


def _expert_ffn(buf, w_in, w_gate, w_out, act: str):
    """buf [E_loc, C, D] -> [E_loc, C, D]; weights [E_loc, D, F]/[E_loc, F, D]."""
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    if w_gate is not None:
        a = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = a(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def apply(params, x, spec: MoeSpec, cfg: ModelConfig, ctx: Ctx):
    """x [B,S,D] (normed); returns MoE output [B,S,D]."""
    rules = ctx.rules
    mesh = rules.mesh
    B, S, D = x.shape
    dt = ctx.compute_dtype
    tp = mesh.shape["model"]
    e = spec.n_experts
    assert e % tp == 0, f"{e} experts not divisible by TP={tp}"
    e_loc = e // tp
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")

    x = rules.constrain(x, "batch", None, None)     # gather D, replicate TP
    gates, idx = _route(params, x, spec, ctx)

    t_loc = (B // rules.axis_size(dp_axes)) * S
    cap = max(int(math.ceil(spec.capacity_factor * t_loc * spec.top_k / e)), 4)

    fsdp_ax = rules.table.get("expert_ffn")
    gated = "w_gate" in params

    tokens_gather = rules.table.get("moe_strategy") == "tokens"
    P = jax.sharding.PartitionSpec
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    tok_spec = P(dp, None, None)
    res_spec = rules.spec_for_shape((B, S, D),
                                    ("batch", None, "res_embed"))

    def _dispatch(xt, gt, it, capacity, e_lo):
        """Shared dispatch: tokens [T,D] -> expert buffer [E_loc,cap,D].
        Returns (buf, ef, pf, keep)."""
        tl_ = xt.shape[0]
        local = (it >= e_lo) & (it < e_lo + e_loc)
        le = jnp.where(local, it - e_lo, 0)
        onehot = (jax.nn.one_hot(le, e_loc, dtype=jnp.int32)
                  * local.astype(jnp.int32)[..., None])       # [T,K,E_loc]
        pos = jnp.cumsum(onehot.reshape(tl_ * spec.top_k, e_loc),
                         axis=0) - 1
        pos = (pos.reshape(tl_, spec.top_k, e_loc) * onehot).sum(-1)
        keep = local & (pos < capacity)
        ef = jnp.where(keep, le, e_loc).reshape(-1)
        pf = jnp.where(keep, pos, capacity).reshape(-1)
        src = jnp.broadcast_to(xt[:, None, :], (tl_, spec.top_k, D))
        buf = jnp.zeros((e_loc, capacity, D), dt).at[ef, pf].add(
            src.reshape(-1, D), mode="drop")
        return buf, ef, pf, keep

    def local_moe(xb, gb, ib, w_in, w_out, w_gate=None):
        # xb [B_loc,S,D]; gb/ib [B_loc,S,K]; weights [E_loc, D(/fsdp), F]
        # cast to the compute dtype BEFORE the fsdp gather: gathering f32
        # master weights would double the wire bytes for no benefit
        w_in, w_out = w_in.astype(dt), w_out.astype(dt)
        w_gate = w_gate.astype(dt) if gated else None
        if fsdp_ax is not None:
            w_in = jax.lax.all_gather(w_in, fsdp_ax, axis=1, tiled=True)
            w_out = jax.lax.all_gather(w_out, fsdp_ax, axis=2, tiled=True)
            if gated:
                w_gate = jax.lax.all_gather(w_gate, fsdp_ax, axis=1,
                                            tiled=True)
        r = jax.lax.axis_index("model")
        tl = xb.shape[0] * xb.shape[1]
        buf, ef, pf, keep = _dispatch(
            xb.reshape(tl, D), None, ib.reshape(tl, spec.top_k), cap,
            r * e_loc)
        out = _expert_ffn(buf, w_in, w_gate, w_out, spec.act)
        # gather back, weight by gate, sum over choices
        got = out.at[ef, pf].get(mode="fill", fill_value=0.0)
        got = got.reshape(tl, spec.top_k, D) \
            * gb.reshape(tl, spec.top_k)[..., None]
        y = got.sum(axis=1)
        # combine: reduce-scatter over TP onto the residual's embed
        # sharding (half the wire of an all-reduce, and the next layer
        # consumes exactly this layout)
        if res_spec[2] == "model":
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                     tiled=True)
            return y.reshape(xb.shape[0], S, D // tp)
        y = jax.lax.psum(y, "model")
        return y.reshape(xb.shape)

    def local_moe_tokens(xb, gb, ib, w_in, w_out, w_gate=None):
        """Decode-serving strategy: gather the (few) tokens over the data
        axis instead of gathering expert weights — weights stay resident
        [E/TP, D, F/data]; the expert FFN computes an F-slice and the
        output psums over ("data","model")."""
        w_in, w_out = w_in.astype(dt), w_out.astype(dt)
        w_gate = w_gate.astype(dt) if gated else None
        r = jax.lax.axis_index("model")
        d_rank = jax.lax.axis_index(dp_axes[-1])
        tl = xb.shape[0] * xb.shape[1]
        xg = jax.lax.all_gather(xb.reshape(tl, D), dp_axes[-1],
                                axis=0, tiled=True)
        ig = jax.lax.all_gather(ib.reshape(tl, spec.top_k), dp_axes[-1],
                                axis=0, tiled=True)
        gg = jax.lax.all_gather(gb.reshape(tl, spec.top_k), dp_axes[-1],
                                axis=0, tiled=True)
        tg = xg.shape[0]
        cap_g = max(int(math.ceil(
            spec.capacity_factor * tg * spec.top_k / e)), 4)
        buf, ef, pf, keep = _dispatch(xg, None, ig, cap_g, r * e_loc)
        out = _expert_ffn(buf, w_in, w_gate, w_out, spec.act)  # F-slice
        got = out.at[ef, pf].get(mode="fill", fill_value=0.0)
        got = got.reshape(tg, spec.top_k, D) * gg[..., None]
        y = got.sum(axis=1)                       # partial over F + experts
        y = jax.lax.psum(y, (dp_axes[-1], "model"))
        y = jax.lax.dynamic_slice_in_dim(y, d_rank * tl, tl, axis=0)
        return y.reshape(xb.shape)

    args = [x, gates, idx, params["w_in"], params["w_out"]]
    if tokens_gather:
        w_specs = [P("model", None, dp_axes[-1]),
                   P("model", dp_axes[-1], None)]
        gate_spec = P("model", None, dp_axes[-1])
        body, out_specs = local_moe_tokens, tok_spec
    else:
        w_specs = [P("model", fsdp_ax, None), P("model", None, fsdp_ax)]
        gate_spec = P("model", fsdp_ax, None)
        body = local_moe
        out_specs = P(dp, None, "model") if res_spec[2] == "model" \
            else tok_spec
    in_specs = [tok_spec, tok_spec, tok_spec] + w_specs
    if gated:
        args.append(params["w_gate"])
        in_specs.append(gate_spec)
    y = shard_map_compat(
        body, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs,
        check_vma=False)(*args)
    y = rules.constrain(y, "batch", None, "res_embed")

    if spec.shared_d_ff:
        y = y + ffn_mod.apply(params["shared"], x,
                              FfnSpec(d_ff=spec.shared_d_ff, act=spec.act),
                              cfg, ctx)
    return y
