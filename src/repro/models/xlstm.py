"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel via the shared SSD
core) and sLSTM (scalar memory, sequential recurrence with block-diagonal
recurrent weights).

mLSTM is linear attention with exponential input gates and sigmoid forget
gates; its recurrence maps exactly onto `ssm.chunked_ssd` with
  k-dim N = head_dim, v augmented with a ones-column so the normalizer
  state n is carried in the same pass (h = num / max(|den|, 1)).

sLSTM's gates depend on h_{t-1}, so it is inherently sequential; the input
projections (the FLOP bulk) are computed for all positions up front, and
only the small block-diagonal recurrent matmuls live inside the scan (the
roofline notes this as an undercount of <0.5% for xlstm-350m).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import MLstmSpec, ModelConfig, SLstmSpec
from .layers import Ctx, dense_init
from .ssm import causal_conv1d, chunked_ssd, ssd_decode_step

_IGATE_CLAMP = 10.0   # exp input-gate stabilization (in lieu of m-state)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig, spec: MLstmSpec):
    d_in = int(spec.proj_factor * cfg.d_model)
    H = spec.n_heads
    P = d_in // H
    return d_in, H, P


def init_mlstm(key, cfg: ModelConfig, spec: MLstmSpec):
    d = cfg.d_model
    d_in, H, P = _mlstm_dims(cfg, spec)
    ks = jax.random.split(key, 7)
    params = {
        "w_up": dense_init(ks[0], (d, 2 * d_in), fan_in=d),
        "conv_w": dense_init(ks[1], (spec.d_conv, d_in), fan_in=spec.d_conv),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "wq": dense_init(ks[2], (d_in, d_in), fan_in=d_in),
        "wk": dense_init(ks[3], (d_in, d_in), fan_in=d_in),
        "wv": dense_init(ks[4], (d_in, d_in), fan_in=d_in),
        "w_gates": dense_init(ks[5], (d_in, 2 * H), fan_in=d_in),
        "b_gates": jnp.concatenate(
            [jnp.zeros((H,)), jnp.linspace(3.0, 6.0, H)]).astype(jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "w_down": dense_init(ks[6], (d_in, d), fan_in=d_in),
    }
    return params, logical_mlstm(cfg, spec)


def logical_mlstm(cfg: ModelConfig, spec: MLstmSpec):
    return {
        "w_up": ("embed", "ffn"), "conv_w": ("conv", "ffn"),
        "conv_b": ("ffn",), "wq": ("ffn", "ffn"), "wk": ("ffn", "ffn"),
        "wv": ("ffn", "ffn"), "w_gates": ("ffn", None), "b_gates": (None,),
        "norm_scale": ("ffn",), "w_down": ("ffn", "embed"),
    }


def init_mlstm_cache(cfg: ModelConfig, spec: MLstmSpec, batch: int,
                     dtype=jnp.bfloat16):
    d_in, H, P = _mlstm_dims(cfg, spec)
    return {
        "C": jnp.zeros((batch, H, P, P + 1), jnp.float32),
        "conv": jnp.zeros((batch, spec.d_conv - 1, d_in), dtype),
    }


def mlstm_cache_logical(spec: MLstmSpec):
    return {"C": ("cache_batch", "act_heads", None, None),
            "conv": ("cache_batch", None, "act_ffn")}


def apply_mlstm(params, x, spec: MLstmSpec, cfg: ModelConfig, ctx: Ctx,
                cache=None) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    d_in, H, P = _mlstm_dims(cfg, spec)
    dt = ctx.compute_dtype

    up = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(dt))
    u, og = up[..., :d_in], up[..., d_in:]

    conv_state = cache["conv"] if cache is not None and ctx.mode == "decode" \
        else None
    uc, new_conv = causal_conv1d(u, params["conv_w"], params["conv_b"],
                                 conv_state)
    q = jnp.einsum("bse,ef->bsf", uc, params["wq"].astype(dt))
    k = jnp.einsum("bse,ef->bsf", uc, params["wk"].astype(dt)) / np.sqrt(P)
    v = jnp.einsum("bse,ef->bsf", u, params["wv"].astype(dt))
    q = q.reshape(B, S, H, P)
    k = k.reshape(B, S, H, P)
    v = v.reshape(B, S, H, P)
    # ones column carries the normalizer state through the same recurrence
    v_aug = jnp.concatenate([v, jnp.ones((B, S, H, 1), v.dtype)], axis=-1)

    gates = jnp.einsum("bse,eg->bsg", uc, params["w_gates"].astype(dt)
                       ).astype(jnp.float32) + params["b_gates"]
    i_raw, f_raw = gates[..., :H], gates[..., H:]
    igate = jnp.exp(jnp.minimum(i_raw, _IGATE_CLAMP))
    logf = jax.nn.log_sigmoid(f_raw)

    if ctx.mode == "decode" and cache is not None:
        y_aug, new_C = ssd_decode_step(
            q[:, 0], k[:, 0], v_aug[:, 0], logf[:, 0], igate[:, 0],
            cache["C"])
        y_aug = y_aug[:, None]
    else:
        y_aug, new_C = chunked_ssd(q, k, v_aug, logf, igate, spec.chunk,
                                   cost_exact=ctx.cost_exact)
    num, den = y_aug[..., :P], y_aug[..., P:]
    h = num.astype(jnp.float32) / jnp.maximum(
        jnp.abs(den.astype(jnp.float32)), 1.0)
    h = h.reshape(B, S, d_in)
    # per-block RMSNorm then output gate
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"]
    h = h.astype(dt) * jax.nn.silu(og)
    out = jnp.einsum("bse,ed->bsd", h, params["w_down"].astype(dt))
    new_cache = None
    if cache is not None:
        new_cache = {"C": new_C, "conv": new_conv}
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_dims(cfg: ModelConfig, spec: SLstmSpec):
    H = spec.n_heads
    P = cfg.d_model // H
    d_up = int(spec.proj_factor * cfg.d_model)
    return H, P, d_up


def init_slstm(key, cfg: ModelConfig, spec: SLstmSpec):
    d = cfg.d_model
    H, P, d_up = _slstm_dims(cfg, spec)
    ks = jax.random.split(key, 5)
    params = {
        "conv_w": dense_init(ks[0], (spec.d_conv, d), fan_in=spec.d_conv),
        "conv_b": jnp.zeros((d,), jnp.float32),
        "w_gates": dense_init(ks[1], (d, 4 * d), fan_in=d),     # z i f o
        "r_gates": dense_init(ks[2], (H, 4, P, P), fan_in=P),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.linspace(3.0, 6.0, d),
             jnp.zeros((d,))]).astype(jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "w_up": dense_init(ks[3], (d, 2 * d_up), fan_in=d),
        "w_down": dense_init(ks[4], (d_up, d), fan_in=d_up),
    }
    return params, logical_slstm(cfg, spec)


def logical_slstm(cfg: ModelConfig, spec: SLstmSpec):
    return {
        "conv_w": ("conv", "embed"), "conv_b": ("embed",),
        "w_gates": ("embed", None), "r_gates": ("heads", None, None, None),
        "b_gates": (None,), "gn_scale": ("embed",),
        "w_up": ("embed", "ffn"), "w_down": ("ffn", "embed"),
    }


def init_slstm_cache(cfg: ModelConfig, spec: SLstmSpec, batch: int,
                     dtype=jnp.bfloat16):
    H, P, _ = _slstm_dims(cfg, spec)
    st = lambda: jnp.zeros((batch, H, P), jnp.float32)
    return {"h": st(), "c": st(), "n": st(),
            "m": jnp.zeros((batch, H, P), jnp.float32),
            "conv": jnp.zeros((batch, spec.d_conv - 1, cfg.d_model), dtype)}


def slstm_cache_logical(spec: SLstmSpec):
    names = ("cache_batch", "act_heads", None)
    return {"h": names, "c": names, "n": names, "m": names,
            "conv": ("cache_batch", None, "act_embed")}


def _slstm_cell(wx, h_prev, c_prev, n_prev, m_prev, r_gates):
    """One recurrence step. wx [B,H,4,P] (input projections, f32);
    states [B,H,P]. Returns (h, c, n, m)."""
    rec = jnp.einsum("bhp,hgpq->bhgq", h_prev, r_gates)
    pre = wx + rec
    z = jnp.tanh(pre[:, :, 0])
    i_log = pre[:, :, 1]
    f_log = jax.nn.log_sigmoid(pre[:, :, 2])
    o = jax.nn.sigmoid(pre[:, :, 3])
    m = jnp.maximum(f_log + m_prev, i_log)
    i_s = jnp.exp(i_log - m)
    f_s = jnp.exp(f_log + m_prev - m)
    c = f_s * c_prev + i_s * z
    n = jnp.maximum(f_s * n_prev + i_s, 1e-6)
    h = o * (c / n)
    return h, c, n, m


def apply_slstm(params, x, spec: SLstmSpec, cfg: ModelConfig, ctx: Ctx,
                cache=None) -> Tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    H, P, d_up = _slstm_dims(cfg, spec)
    dt = ctx.compute_dtype

    conv_state = cache["conv"] if cache is not None and ctx.mode == "decode" \
        else None
    xc, new_conv = causal_conv1d(x, params["conv_w"], params["conv_b"],
                                 conv_state)
    # z,o from raw x; i,f from conv path (xLSTM practice)
    wx = jnp.einsum("bsd,dg->bsg", x, params["w_gates"].astype(dt)
                    ).astype(jnp.float32)
    wc = jnp.einsum("bsd,dg->bsg", xc, params["w_gates"].astype(dt)
                    ).astype(jnp.float32)
    pre = jnp.concatenate(
        [wx[..., :D], wc[..., D:2 * D], wc[..., 2 * D:3 * D],
         wx[..., 3 * D:]], axis=-1) + params["b_gates"]
    pre = pre.reshape(B, S, 4, H, P).transpose(0, 1, 3, 2, 4)  # [B,S,H,4,P]

    r = params["r_gates"].astype(jnp.float32)
    if cache is not None and ctx.mode == "decode":
        h, c, n, m = _slstm_cell(pre[:, 0], cache["h"], cache["c"],
                                 cache["n"], cache["m"], r)
        hs = h[:, None]
        new_states = {"h": h, "c": c, "n": n, "m": m}
    else:
        def body(carry, wt):
            h_, c_, n_, m_ = carry
            h_, c_, n_, m_ = _slstm_cell(wt, h_, c_, n_, m_, r)
            return (h_, c_, n_, m_), h_

        z0 = jnp.zeros((B, H, P), jnp.float32)
        (h, c, n, m), hs = jax.lax.scan(
            body, (z0, z0, z0, z0), pre.transpose(1, 0, 2, 3, 4))
        hs = hs.transpose(1, 0, 2, 3)                  # [B,S,H,P]
        new_states = {"h": h, "c": c, "n": n, "m": m}

    hs = hs.reshape(B, S, D)
    # group-norm per head approximated by RMS over full dim with scale
    var = jnp.mean(hs * hs, axis=-1, keepdims=True)
    hs = (hs * jax.lax.rsqrt(var + cfg.norm_eps)
          * params["gn_scale"]).astype(dt)
    # gated up/down projection (GeGLU, factor 4/3)
    up = jnp.einsum("bsd,de->bse", hs, params["w_up"].astype(dt))
    a, b = up[..., :d_up], up[..., d_up:]
    out = jnp.einsum("bse,ed->bsd", jax.nn.gelu(a) * b,
                     params["w_down"].astype(dt))
    new_cache = None
    if cache is not None:
        new_cache = dict(new_states, conv=new_conv)
    return out, new_cache
