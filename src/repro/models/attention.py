"""GQA/MQA/MHA attention with RoPE / M-RoPE, KV cache, chunked long-context
path, and optional cross-attention.

KV caches are laid out [B, n_kv, max_len, head_dim] (kv-heads before seq) so
the sharding rules can claim the "model" axis for kv-heads when divisible
and fall back to sharding the sequence dimension otherwise (MQA/GQA with
few kv heads at TP=16).

The quadratic score matrix is never materialized for long sequences: when
S * kv_len exceeds `ctx.attn_chunk`^2-ish budgets the kv axis is processed
in blocks with an online-softmax accumulator (flash-attention recurrence,
pure jnp — the Pallas kernel in repro.kernels.flash_attention implements
the same recurrence for TPU and is validated against this path).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import AttnSpec, ModelConfig
from .layers import (Ctx, apply_mrope, apply_rope, dense_init,
                     rms_norm_heads)

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig, spec: AttnSpec):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], (d, spec.n_heads, spec.head_dim), fan_in=d),
        "wk": dense_init(ks[1], (d, spec.n_kv, spec.head_dim), fan_in=d),
        "wv": dense_init(ks[2], (d, spec.n_kv, spec.head_dim), fan_in=d),
        "wo": dense_init(ks[3], (spec.n_heads, spec.head_dim, d),
                         fan_in=spec.n_heads * spec.head_dim),
    }
    if spec.qk_norm:
        params["q_scale"] = jnp.ones((spec.head_dim,), jnp.float32)
        params["k_scale"] = jnp.ones((spec.head_dim,), jnp.float32)
    return params, logical(cfg, spec)


def logical(cfg: ModelConfig, spec: AttnSpec):
    out = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if spec.qk_norm:
        out["q_scale"] = ("head_dim",)
        out["k_scale"] = ("head_dim",)
    return out


def init_cache(cfg: ModelConfig, spec: AttnSpec, batch: int, max_len: int,
               dtype=jnp.bfloat16, enc_len: int = 0):
    """Abstract/zero cache for one attention sublayer.

    dtype=jnp.int8 selects the quantized cache: per-(position, kv-head)
    symmetric int8 with a bf16 scale — halves decode's dominant HBM term
    (cache reads) at ~1e-2 relative error on attention outputs."""
    kv_len = enc_len if spec.cross else max_len
    shape = (batch, spec.n_kv, kv_len, spec.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if dtype == jnp.int8:
        sshape = shape[:-1] + (1,)
        cache["k_scale"] = jnp.zeros(sshape, jnp.bfloat16)
        cache["v_scale"] = jnp.zeros(sshape, jnp.bfloat16)
    return cache


def cache_logical(spec: AttnSpec, quantized: bool = False):
    names = ("cache_batch", "cache_kv", "cache_seq", "head_dim")
    out = {"k": names, "v": names}
    if quantized:
        out["k_scale"] = names
        out["v_scale"] = names
    return out


def _quantize_kv(x):
    """x [..., hd] -> (int8 values, bf16 per-row scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _read_cache(cache, dt):
    """Dequantize (if int8) and cast the cache for attention compute."""
    if cache["k"].dtype == jnp.int8:
        k = (cache["k"].astype(jnp.float32)
             * cache["k_scale"].astype(jnp.float32)).astype(dt)
        v = (cache["v"].astype(jnp.float32)
             * cache["v_scale"].astype(jnp.float32)).astype(dt)
        return k, v
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# Core scaled-dot-product (GQA, no kv repeat materialization)
# ---------------------------------------------------------------------------

def _sdpa_full(q, k, v, mask, scale, softcap=0.0):
    """q [B,S,KV,QR,hd]; k,v [B,KV,T,hd]; mask [B?,S,T] bool or None."""
    scores = jnp.einsum("bsgqh,bgth->bgqst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgqst,bgth->bsgqh", w.astype(v.dtype), v)
    return out


def _sdpa_chunked(q, k, v, q_pos, kv_pos, scale, causal, chunk,
                  softcap=0.0, window=0):
    """Online-softmax over kv blocks. q [B,S,KV,QR,hd]; k,v [B,KV,T,hd];
    q_pos [B,S]; kv_pos [T]. Memory O(S * chunk) instead of O(S * T)."""
    B, S, KV, QR, H = q.shape
    T = k.shape[2]
    n_blocks = -(-T // chunk)
    pad = n_blocks * chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=2**30)
    kb = k.reshape(B, KV, n_blocks, chunk, H).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, KV, n_blocks, chunk, H).transpose(2, 0, 1, 3, 4)
    pb = kv_pos.reshape(n_blocks, chunk)

    qf = q.astype(jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk
        s = jnp.einsum("bsgqh,bgth->bgqst", qf, kc.astype(jnp.float32)) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        valid = jnp.broadcast_to(pc[None, None, :] < 2**30, (B, S, chunk))
        if causal:
            ok = q_pos[:, :, None] >= pc[None, None, :]
            if window:
                ok &= q_pos[:, :, None] - pc[None, None, :] < window
            valid = valid & ok
        # valid [B,S,chunk] -> broadcast over (KV, QR): s is [B,KV,QR,S,chunk]
        s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgqst,bgth->bgqsh", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, QR, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, QR, S), jnp.float32)
    a0 = jnp.zeros((B, KV, QR, S, H), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)   # [B,S,KV,QR,hd]


# ---------------------------------------------------------------------------
# Sublayer apply
# ---------------------------------------------------------------------------

def apply(params, x, spec: AttnSpec, cfg: ModelConfig, ctx: Ctx,
          cache=None) -> Tuple[jax.Array, Optional[dict]]:
    """x [B,S,D] (already normed). Returns (attn_out [B,S,D], new_cache)."""
    B, S, D = x.shape
    dt = ctx.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    quant = cache is not None and cache["k"].dtype == jnp.int8
    if spec.cross:
        src = ctx.enc_out
        if cache is not None and ctx.mode == "decode":
            k, v = _read_cache(cache, dt)           # projected at prefill
            new_cache = cache
        else:
            k = jnp.einsum("btd,dgk->bgtk", src, params["wk"].astype(dt))
            v = jnp.einsum("btd,dgk->bgtk", src, params["wv"].astype(dt))
            new_cache = None
            if cache is not None:
                if quant:
                    qk, sk = _quantize_kv(k)
                    qv, sv = _quantize_kv(v)
                    new_cache = {"k": qk, "v": qv, "k_scale": sk,
                                 "v_scale": sv}
                else:
                    new_cache = {"k": k, "v": v}
        kv_pos = jnp.arange(k.shape[2])
        q_pos = None
        causal = False
    else:
        k = jnp.einsum("bsd,dgk->bgsk", x, params["wk"].astype(dt))
        v = jnp.einsum("bsd,dgk->bgsk", x, params["wv"].astype(dt))
        if spec.qk_norm:
            q = rms_norm_heads(q, params["q_scale"], cfg.norm_eps)
            k = rms_norm_heads(
                k.transpose(0, 2, 1, 3), params["k_scale"],
                cfg.norm_eps).transpose(0, 2, 1, 3)
        pos = ctx.positions
        if spec.rope == "rope":
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k.transpose(0, 2, 1, 3), pos,
                           cfg.rope_theta).transpose(0, 2, 1, 3)
        elif spec.rope == "mrope":
            q = apply_mrope(q, pos, cfg.rope_theta, spec.mrope_sections)
            k = apply_mrope(k.transpose(0, 2, 1, 3), pos, cfg.rope_theta,
                            spec.mrope_sections).transpose(0, 2, 1, 3)
        q_pos = pos if pos.ndim == 2 else pos[0]

        if cache is not None:
            if quant:
                k_w, sk_w = _quantize_kv(k)
                v_w, sv_w = _quantize_kv(v)
                writes = {"k": k_w, "v": v_w, "k_scale": sk_w,
                          "v_scale": sv_w}
            else:
                writes = {"k": k.astype(cache["k"].dtype),
                          "v": v.astype(cache["v"].dtype)}
            if ctx.mode == "prefill":
                # static offset 0: plain slice-update keeps sharding
                new_cache = {
                    key: jax.lax.dynamic_update_slice(
                        cache[key], w, (0, 0, 0, 0))
                    for key, w in writes.items()}
            else:
                # decode: select-based write — a dynamic-index
                # dynamic_update_slice on the (possibly seq-sharded) cache
                # would force GSPMD to gather the whole cache per step;
                # where(iota==idx, ...) is elementwise and stays sharded.
                # cache_index may be scalar or per-slot [B] (continuous
                # batching).
                iota = jnp.arange(cache["k"].shape[2])[None, None, :, None]
                idx_ = jnp.asarray(ctx.cache_index)
                if idx_.ndim == 1:
                    idx_ = idx_[:, None, None, None]
                sel = iota == idx_
                new_cache = {key: jnp.where(sel, w, cache[key])
                             for key, w in writes.items()}
            logi = cache_logical(spec, quantized=quant)
            new_cache = {key: ctx.rules.constrain(c, *logi[key])
                         for key, c in new_cache.items()}
            k, v = _read_cache(new_cache, dt)
            kv_pos = jnp.arange(k.shape[2])
        else:
            new_cache = None
            kv_pos = q_pos[0] if q_pos.ndim == 2 else q_pos
        causal = spec.causal

    # reshape q to grouped layout [B,S,KV,QR,hd]
    QR = spec.n_heads // spec.n_kv
    q = q.reshape(B, S, spec.n_kv, QR, spec.head_dim)
    # kv-heads claim the TP axis when divisible; otherwise the query-repeat
    # dim takes it (a fully-specified constraint with None here would FORCE
    # replication and materialize unsharded score tensors)
    q = ctx.rules.constrain(q, "batch", None, "act_kv", "act_qr", None)
    scale = 1.0 / np.sqrt(spec.head_dim)
    T = k.shape[2]

    use_chunked = (not ctx.cost_exact) and S > 1 and S * T > 1024 * 1024 \
        and not spec.cross
    if use_chunked:
        out = _sdpa_chunked(q, k, v, q_pos, kv_pos, scale, causal,
                            ctx.attn_chunk, spec.logit_softcap,
                            spec.sliding_window)
    else:
        mask = None
        if causal:
            if S == 1 and ctx.cache_index is not None:
                # decode: attend to the filled prefix (incl. current slot)
                cur = jnp.asarray(ctx.cache_index)
                if cur.ndim == 1:
                    cur = cur[:, None, None]
                mask = jnp.broadcast_to(
                    kv_pos[None, None, :] <= cur, (B, 1, T))
                if spec.sliding_window:
                    mask &= jnp.broadcast_to(
                        cur - kv_pos[None, None, :] < spec.sliding_window,
                        (B, 1, T))
            else:
                mask = (q_pos[:, :, None] >= kv_pos[None, None, :])
                if spec.sliding_window:
                    mask &= (q_pos[:, :, None] - kv_pos[None, None, :]
                             < spec.sliding_window)
        out = _sdpa_full(q, k, v, mask, scale, spec.logit_softcap)

    out = out.reshape(B, S, spec.n_heads, spec.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache
