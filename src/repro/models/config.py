"""Composable decoder-stack IR.

A model is a stack of *groups*; each group is a tuple of *layers*; each
layer is a tuple of *sublayers* (pre-norm residual units). Groups are
homogeneous so the whole stack lowers as one `lax.scan` over stacked group
parameters — this keeps HLO size and compile time independent of depth (94
layers compile as fast as 2) while remaining exactly equivalent to the
unrolled stack.

Sublayer kinds:
  AttnSpec    multi-head attention (GQA/MQA/MHA, RoPE or M-RoPE, optional
              cross-attention and cross-stack weight sharing)
  FfnSpec     dense gated/plain MLP
  MoeSpec     mixture-of-experts with top-k routing + static capacity
  Mamba2Spec  Mamba-2 state-space duality block (chunked scan)
  MLstmSpec   xLSTM matrix-memory block (chunked parallel form)
  SLstmSpec   xLSTM scalar-memory block (sequential recurrence)

Heterogeneous stacks (llama4 alternating dense/MoE, zamba2 mamba+shared
attention, xLSTM mLSTM/sLSTM interleave) are expressed inside the repeated
group; a non-repeating `tail` covers remainders (e.g. zamba2's 81 = 13*6+3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv: int
    head_dim: int
    rope: str = "rope"               # "rope" | "mrope" | "none"
    causal: bool = True
    cross: bool = False              # K/V from encoder stream
    shared: bool = False             # weights shared across all occurrences
    qk_norm: bool = False            # per-head RMSNorm on q,k (qwen3)
    sliding_window: int = 0          # 0 = full attention
    mrope_sections: Tuple[int, ...] = (16, 24, 24)   # head_dim/2 split
    logit_softcap: float = 0.0

    @property
    def kind(self) -> str:
        return "attn"


@dataclasses.dataclass(frozen=True)
class FfnSpec:
    d_ff: int
    act: str = "swiglu"              # "swiglu" | "geglu" | "gelu" | "relu2"
    shared: bool = False

    @property
    def kind(self) -> str:
        return "ffn"


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    n_experts: int
    top_k: int
    d_ff: int
    act: str = "swiglu"
    capacity_factor: float = 1.25
    shared_d_ff: int = 0             # always-on shared expert (llama4)
    aux_loss_weight: float = 0.01
    router_dtype: str = "float32"

    @property
    def kind(self) -> str:
        return "moe"


@dataclasses.dataclass(frozen=True)
class Mamba2Spec:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1                # B/C parameter groups

    @property
    def kind(self) -> str:
        return "mamba2"


@dataclasses.dataclass(frozen=True)
class MLstmSpec:
    n_heads: int
    proj_factor: float = 2.0
    d_conv: int = 4
    chunk: int = 128

    @property
    def kind(self) -> str:
        return "mlstm"


@dataclasses.dataclass(frozen=True)
class SLstmSpec:
    n_heads: int
    proj_factor: float = 4.0 / 3.0
    d_conv: int = 4

    @property
    def kind(self) -> str:
        return "slstm"


Layer = Tuple[object, ...]           # sequence of sublayer specs
Group = Tuple[Layer, ...]            # layers scanned together


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). The modality frontend is
    a stub: inputs are precomputed frame embeddings (B, n_frames, d_model)."""

    n_groups: int
    pattern: Group
    n_frames: int = 1500
    pos: str = "sinusoidal"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    vocab: int
    n_groups: int
    pattern: Group
    tail: Group = ()
    max_seq: int = 4096
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    rope_theta: float = 1e6
    embed_scale: bool = False        # gemma multiplies embeds by sqrt(d)
    final_logit_softcap: float = 0.0
    encoder: Optional[EncoderConfig] = None
    modality: str = "text"           # "text" | "audio" | "vlm"
    vision_frac: float = 0.25        # VLM: fraction of seq that is patches

    # ---- derived -----------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return self.n_groups * len(self.pattern) + len(self.tail)

    def sublayers(self):
        """Iterate (where, layer_idx, sub_idx, spec): where in {pattern,tail}."""
        for li, layer in enumerate(self.pattern):
            for si, spec in enumerate(layer):
                yield "pattern", li, si, spec
        for li, layer in enumerate(self.tail):
            for si, spec in enumerate(layer):
                yield "tail", li, si, spec

    @property
    def has_attention(self) -> bool:
        return any(s.kind == "attn" for _, _, _, s in self.sublayers())

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state does not grow O(seq) per full-attn layer —
        SSM / linear-attention families. Determines long_500k eligibility."""
        kinds = {s.kind for _, _, _, s in self.sublayers()}
        full_attn_layers = sum(
            1 for _, _, _, s in self.sublayers()
            if s.kind == "attn" and s.sliding_window == 0 and not s.cross)
        recurrent = kinds & {"mamba2", "mlstm", "slstm"}
        # hybrid archs qualify if recurrence dominates (zamba2: 13 shared-attn
        # applications vs 81 mamba layers)
        return bool(recurrent) and full_attn_layers <= max(
            1, self.n_layers // 4)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + stack), for 6ND roofline."""
        d = self.d_model
        n = self.vocab * d * (1 if self.tie_embeddings else 2)

        def sub_params(s) -> int:
            if s.kind == "attn":
                qo = d * s.n_heads * s.head_dim * 2
                kv = d * s.n_kv * s.head_dim * 2
                return qo + kv + (2 * s.head_dim if s.qk_norm else 0)
            if s.kind == "ffn":
                mult = 3 if s.act in ("swiglu", "geglu") else 2
                return mult * d * s.d_ff
            if s.kind == "moe":
                mult = 3 if s.act in ("swiglu", "geglu") else 2
                n_ = s.n_experts * mult * d * s.d_ff + d * s.n_experts
                if s.shared_d_ff:
                    n_ += mult * d * s.shared_d_ff
                return n_
            if s.kind == "mamba2":
                d_in = s.expand * d
                nh = d_in // s.head_dim
                return (d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
                        + d_in * d + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                        + 2 * nh + d_in)
            if s.kind == "mlstm":
                d_in = int(s.proj_factor * d)
                return (2 * d * d_in            # up-proj (u + out gate)
                        + 3 * d_in * d_in       # wq, wk, wv
                        + d_in * d              # down-proj
                        + d_in * (s.d_conv + 2)  # conv + biases + norm
                        + 4 * s.n_heads)        # i/f gate proj + bias
            if s.kind == "slstm":
                P = d // s.n_heads
                d_up = int(s.proj_factor * d)
                return (4 * d * d               # w_gates (z i f o)
                        + 4 * s.n_heads * P * P  # block-diag recurrent
                        + 3 * d * d_up          # gated up/down proj
                        + d * (s.d_conv + 6))   # conv + biases + gn
            return 0

        shared_seen = set()
        for where, li, si, s in self.sublayers():
            reps = self.n_groups if where == "pattern" else 1
            if getattr(s, "shared", False):
                if s not in shared_seen:
                    shared_seen.add(s)
                    n += sub_params(s) + 2 * d  # + its norm
                continue
            n += reps * (sub_params(s) + d)    # + pre-norm scale
        n += d                                  # final norm
        if self.encoder is not None:
            for layer in self.encoder.pattern:
                for s in layer:
                    n += self.encoder.n_groups * (sub_params(s) + d)
            n += d
        return int(n)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        d = self.d_model
        n = self.param_count()
        for where, li, si, s in self.sublayers():
            if s.kind != "moe":
                continue
            reps = self.n_groups if where == "pattern" else 1
            mult = 3 if s.act in ("swiglu", "geglu") else 2
            inactive = (s.n_experts - s.top_k) * mult * d * s.d_ff
            n -= reps * inactive
        return int(n)
