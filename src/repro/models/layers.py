"""Shared building blocks: norms, RoPE / M-RoPE, initializers, context."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.sharding import Rules


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through every sublayer."""

    rules: Rules
    mode: str                         # "train" | "prefill" | "decode"
    positions: Optional[jax.Array]    # [B,S] int32, or [3,B,S] for M-RoPE
    cache_index: Optional[jax.Array] = None  # scalar int32 fill pointer
    enc_out: Optional[jax.Array] = None      # encoder stream for cross-attn
    attn_chunk: int = 1024            # kv-block size for chunked attention
    compute_dtype: Any = jnp.bfloat16
    cost_exact: bool = False          # unroll inner loops for cost probes
    aux: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)

    def add_aux(self, name: str, value):
        self.aux[name] = self.aux.get(name, 0.0) + value


# ---------------------------------------------------------------------------
# Initializers (all take concrete shapes; fan-in scaled normal)
# ---------------------------------------------------------------------------

def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32, std=0.02):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d_model: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d_model,), jnp.float32)}, {"scale": (None,)}
    return ({"scale": jnp.ones((d_model,), jnp.float32),
             "bias": jnp.zeros((d_model,), jnp.float32)},
            {"scale": (None,), "bias": (None,)})


def apply_norm(params, x, kind: str, eps: float):
    """Norm in f32, output in x.dtype (standard mixed-precision practice)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] \
            + params["bias"]
    return y.astype(dtype)


def rms_norm_heads(x, scale, eps=1e-6):
    """Per-head q/k RMSNorm (qwen3): x [..., head_dim], scale [head_dim]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def _rope_angles(positions, dim: int, theta: float):
    """positions [...]; returns (sin, cos) each [..., dim/2] in f32."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, positions, theta: float):
    """x [B,S,H,D]; positions [B,S]. Rotates pairs (x_i, x_{i+half})."""
    d = x.shape[-1]
    sin, cos = _rope_angles(positions, d, theta)       # [B,S,half]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions, theta: float, sections):
    """M-RoPE (qwen2-vl): positions [3,B,S] (t,h,w); head_dim/2 split into
    `sections` frequency bands, each rotated by its own position stream."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # pick the position stream per frequency band
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=half)
    pos_sel = jnp.take(positions.astype(jnp.float32), sec_id, axis=0)
    ang = jnp.moveaxis(pos_sel, 0, -1) * freqs          # [B,S,half]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    """Absolute sinusoidal table [n, d] (whisper encoder)."""
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "gelu": jax.nn.gelu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
        "silu": jax.nn.silu,
    }[name]
