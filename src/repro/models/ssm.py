"""Mamba-2 (state-space duality) block, plus the shared chunked linear-
recurrence core also used by the xLSTM mLSTM block.

The SSD recurrence  S_t = a_t * S_t-1 + g_t * (k_t ⊗ v_t),  y_t = q_t · S_t
is evaluated in the chunked dual form: within a chunk (length Q) the output
is a masked quadratic form (pure matmuls, MXU-friendly, fully counted by
HLO cost analysis); across chunks only the [N,P] states are passed through
a short `lax.scan` (elementwise decay+add, negligible FLOPs — noted in the
roofline methodology).

All recurrence math runs in f32 regardless of the compute dtype.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import Mamba2Spec, ModelConfig
from .layers import Ctx, dense_init


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (shared by mamba2 / xlstm blocks)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b, state=None):
    """x [B,S,C]; w [W,C]; b [C]; state [B,W-1,C] or None.

    Returns (y [B,S,C], new_state [B,W-1,C]).
    """
    B, S, C = x.shape
    W = w.shape[0]
    xf = x.astype(jnp.float32)
    if state is None:
        ctx_in = jnp.pad(xf, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        ctx_in = jnp.concatenate([state.astype(jnp.float32), xf], axis=1)
    y = jax.lax.conv_general_dilated(
        ctx_in, w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)
    y = jax.nn.silu(y + b.astype(jnp.float32))
    new_state = ctx_in[:, -(W - 1):, :] if W > 1 else ctx_in[:, :0, :]
    return y.astype(x.dtype), new_state.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked SSD core
# ---------------------------------------------------------------------------

def chunked_ssd(q, k, v, logf, gate, chunk: int,
                init_state: Optional[jax.Array] = None,
                cost_exact: bool = False):
    """Linear recurrence in chunked dual form.

    q, k  [B,S,H,N]; v [B,S,H,P]; logf, gate [B,S,H] (logf <= 0).
    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    f32 = jnp.float32
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        q, k, v, logf, gate = map(zpad, (q, k, v, logf, gate))
    rs = lambda a: a.reshape(B, nc, Q, *a.shape[2:])
    qc, kc, vc = rs(q).astype(f32), rs(k).astype(f32), rs(v).astype(f32)
    fc, gc = rs(logf).astype(f32), rs(gate).astype(f32)

    cum = jnp.cumsum(fc, axis=2)                       # [B,NC,Q,H]
    total = cum[:, :, -1]                              # [B,NC,H]
    # decay from j to i (i >= j): exp(cum_i - cum_j). Mask BEFORE the exp:
    # above-diagonal diffs are positive and can overflow to inf, and
    # where(mask, inf, 0) produces inf*0 = NaN in the backward pass.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,NC,Qi,Qj,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(tri, diff, -1e30))

    # intra-chunk: y_i = sum_{j<=i} (q_i . k_j) L_ij g_j v_j
    s = jnp.einsum("bcihn,bcjhn->bcijh", qc, kc) * L \
        * gc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", s, vc)

    # chunk states: S_c = sum_j exp(total - cum_j) g_j k_j (x) v_j
    w = jnp.exp(total[:, :, None, :] - cum) * gc       # [B,NC,Q,H]
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", w, kc, vc)

    # pass states across chunks (sequential, elementwise)
    decay = jnp.exp(total)                             # [B,NC,H]
    s0 = (jnp.zeros((B, H, N, P), f32) if init_state is None
          else init_state.astype(f32))

    def body(carry, xs):
        st, dc = xs
        prev = carry
        new = dc[:, :, None, None] * prev + st
        return new, prev

    final, prevs = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4),
                   decay.transpose(1, 0, 2)),
        unroll=nc if cost_exact else 1)
    prevs = prevs.transpose(1, 0, 2, 3, 4)             # [B,NC,H,N,P]

    # inter-chunk contribution: y_i += exp(cum_i) q_i . S_prev
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         qc * jnp.exp(cum)[..., None], prevs)
    y = (y_intra + y_inter).reshape(B, nc * Q, H, P)
    if pad:
        y = y[:, :S]
    return y.astype(v.dtype), final


def ssd_decode_step(q, k, v, logf, gate, state):
    """Single-token recurrence. q,k [B,H,N]; v [B,H,P]; logf,gate [B,H];
    state [B,H,N,P] f32. Returns (y [B,H,P], new_state)."""
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    a = jnp.exp(logf.astype(f32))[:, :, None, None]
    new_state = a * state + (gate.astype(f32)[:, :, None, None]
                             * k[..., None] * v[:, :, None, :])
    y = jnp.einsum("bhn,bhnp->bhp", q, new_state)
    return y.astype(v.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba-2 sublayer
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig, spec: Mamba2Spec):
    d_in = spec.expand * cfg.d_model
    n_heads = d_in // spec.head_dim
    conv_dim = d_in + 2 * spec.n_groups * spec.d_state
    return d_in, n_heads, conv_dim


def init(key, cfg: ModelConfig, spec: Mamba2Spec):
    d = cfg.d_model
    d_in, H, conv_dim = _dims(cfg, spec)
    G, N = spec.n_groups, spec.d_state
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * G * N + H    # z, x, B, C, dt
    params = {
        "w_in": dense_init(ks[0], (d, proj_out), fan_in=d),
        "conv_w": dense_init(ks[1], (spec.d_conv, conv_dim),
                             fan_in=spec.d_conv),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "w_out": dense_init(ks[2], (d_in, d), fan_in=d_in),
    }
    return params, logical(cfg, spec)


def logical(cfg: ModelConfig, spec: Mamba2Spec):
    return {
        "w_in": ("embed", "ffn"), "conv_w": ("conv", "ffn"),
        "conv_b": ("ffn",), "a_log": (None,), "dt_bias": (None,),
        "d_skip": (None,), "norm_scale": ("ffn",),
        "w_out": ("ffn", "embed"),
    }


def init_cache(cfg: ModelConfig, spec: Mamba2Spec, batch: int,
               dtype=jnp.bfloat16):
    d_in, H, conv_dim = _dims(cfg, spec)
    return {
        "ssm": jnp.zeros((batch, H, spec.d_state, spec.head_dim),
                         jnp.float32),
        "conv": jnp.zeros((batch, spec.d_conv - 1, conv_dim), dtype),
    }


def cache_logical(spec: Mamba2Spec):
    return {"ssm": ("cache_batch", "act_heads", None, None),
            "conv": ("cache_batch", None, "act_ffn")}


def apply(params, x, spec: Mamba2Spec, cfg: ModelConfig, ctx: Ctx,
          cache=None) -> Tuple[jax.Array, Optional[dict]]:
    """x [B,S,D] (normed). Returns (out [B,S,D], new_cache)."""
    B, S, D = x.shape
    d_in, H, conv_dim = _dims(cfg, spec)
    G, N, P = spec.n_groups, spec.d_state, spec.head_dim
    dt_ = ctx.compute_dtype

    proj = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(dt_))
    # split: z [d_in] | conv block [conv_dim] = x + B + C | dt [H]
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + conv_dim]
    dt_raw = proj[..., d_in + conv_dim:]

    conv_state = cache["conv"] if cache is not None and ctx.mode == "decode" \
        else None
    xbc, new_conv = causal_conv1d(xbc, params["conv_w"], params["conv_b"],
                                  conv_state)
    xs = xbc[..., :d_in].reshape(B, S, H, P)
    Bm = xbc[..., d_in:d_in + G * N].reshape(B, S, G, N)
    Cm = xbc[..., d_in + G * N:].reshape(B, S, G, N)
    # broadcast groups to heads
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    logf = -jnp.exp(params["a_log"]) * dt               # [B,S,H]

    if ctx.mode == "decode" and cache is not None:
        y, new_ssm = ssd_decode_step(
            Ch[:, 0], Bh[:, 0], xs[:, 0], logf[:, 0], dt[:, 0],
            cache["ssm"])
        y = y[:, None]
    else:
        y, final = chunked_ssd(Ch, Bh, xs, logf, dt, spec.chunk,
                               init_state=None, cost_exact=ctx.cost_exact)
        new_ssm = final

    y = y + xs * params["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (mamba2 style)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm_scale"]
    out = jnp.einsum("bse,ed->bsd", yf.astype(dt_),
                     params["w_out"].astype(dt_))
    new_cache = None
    if cache is not None:
        new_cache = {"ssm": new_ssm, "conv": new_conv}
    return out, new_cache
