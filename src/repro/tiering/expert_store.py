"""MoE expert-weight tiering — the textbook instance of the paper's rule.

Expert weights have wildly skewed reuse intervals at inference (router
popularity is long-tailed); the five-second rule says: keep an expert in
fast memory iff its observed reuse interval is below the calibrated
break-even threshold. Cold experts live on the flash tier and are
streamed on demand.

`ExpertStore` tracks per-expert selection counts from router outputs,
converts them to reuse intervals, and maintains residency through the
shared TieredStore. `residency_plan` also answers the provisioning
question: how much HBM/DRAM do we need for a target hit rate.

Expert streaming rides the same async movement engine as serving KV:
`prefetch_experts` issues non-blocking fetches for the experts the
router just selected for the *next* layer/step, and `fetch_expert`
blocks only on the unfinished remainder — cold-expert flash reads
overlap with the current layer's compute, with queueing-aware service
times from the calibrated ssdsim model. `decode_step` wires the two
into the MoE decode path: layer L's router output triggers layer L+1's
prefetch one layer of compute ahead, and every routing feeds the
placement policy — with an `autopilot.gate.EconomicGate` that is the
break-even admission loop for expert weights.

Fleet mode: pass `store=fabric.host_view(host, replicas=r)` (what
`repro.platform.Platform.expert_store` does) to shard replicated cold
experts over the multi-host fabric — each expert lives on its
`replicas` consistent-hash owner hosts, a selection served by a
co-resident replica is a local flash read, and the rest stream over the
NIC transfer tier composed with the remote host's flash. The old
`fabric=`/`host=`/`replicas=` constructor dialect still works as a thin
deprecated shim.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

import numpy as np

from ..core.policy import Tier, TieringPolicy
from ..runtime.tiers import PendingFetch, TieredStore


class ExpertStore:
    def __init__(self, n_layers: int, n_experts: int,
                 policy: TieringPolicy, store: Optional[TieredStore] = None,
                 fabric=None, host: int = 0, replicas: int = 1,
                 expert_bytes: float = 0.0, clock=None):
        self.n_layers = n_layers
        self.n_experts = n_experts
        self.policy = policy
        if store is None and fabric is not None:
            # legacy constructor dialect — the declarative path is
            # Platform.expert_store(...) / a fabric host view
            warnings.warn(
                "ExpertStore(fabric=..., host=..., replicas=...) is "
                "deprecated; compile a repro.platform.HierarchySpec and "
                "use Platform.expert_store(...), or pass "
                "store=fabric.host_view(host, replicas=...)",
                DeprecationWarning, stacklevel=2)
            store = fabric.host_view(host, replicas=replicas)
        elif store is not None:
            # a fabric host view carries its own host identity
            host = getattr(store, "host", host)
        self.host = host
        self.store = store or TieredStore(policy, clock=clock)
        self.clock = self.store.clock
        self._pending: Dict[tuple, PendingFetch] = {}
        self.expert_bytes = expert_bytes
        self.counts = np.zeros((n_layers, n_experts), np.int64)
        self.steps = 0
        self.tokens_per_step = 0

    # ------------------------------------------------------------- tracking
    def observe_routing(self, layer: int, expert_ids: np.ndarray,
                        now: float):
        """Feed one layer's router output (any shape of int expert ids)."""
        ids, cnt = np.unique(np.asarray(expert_ids).ravel(),
                             return_counts=True)
        self.counts[layer, ids] += cnt
        for e in ids:
            self.policy.observe((layer, int(e)), now=now)

    def observe_step(self, routings: Dict[int, np.ndarray], now: float,
                     tokens: int):
        self.steps += 1
        self.tokens_per_step = tokens
        for layer, ids in routings.items():
            self.observe_routing(layer, ids, now)

    # ------------------------------------------------------------ decisions
    def reuse_intervals(self, step_time: float) -> np.ndarray:
        """Expected per-expert reuse interval from empirical popularity:
        tau_e = step_time / P(expert selected in a step)."""
        total = max(self.steps, 1)
        p = np.clip(self.counts / max(
            total * max(self.tokens_per_step, 1), 1), 1e-12, 1.0)
        p_step = 1.0 - np.power(1.0 - p, max(self.tokens_per_step, 1))
        return step_time / np.clip(p_step, 1e-12, 1.0)

    def residency_plan(self, step_time: float) -> Dict[str, object]:
        """Tier per expert via the stateless rule + capacity summary."""
        tau = self.reuse_intervals(step_time)
        tiers = np.asarray(self.policy.tiers_for_intervals(tau))
        plan = {
            "hbm_experts": int((tiers == Tier.HBM).sum()),
            "dram_experts": int((tiers == Tier.DRAM).sum()),
            "flash_experts": int((tiers == Tier.FLASH).sum()),
            "tiers": tiers,
        }
        if self.expert_bytes:
            plan["hbm_bytes"] = plan["hbm_experts"] * self.expert_bytes
            plan["dram_bytes"] = plan["dram_experts"] * self.expert_bytes
        return plan

    def apply_plan(self, weights: Dict, step_time: float):
        """Move actual expert weight blobs between tiers per the plan
        (movement is queued on the async runtime — it streams behind
        compute rather than blocking the step)."""
        plan = self.residency_plan(step_time)
        tiers = plan["tiers"]
        for (layer, e), blob in weights.items():
            want = Tier(int(tiers[layer, e]))
            cur = self.store.tier_of((layer, e))
            if cur is None:
                self.store.put((layer, e), blob, tier=want)
            elif cur != want:
                self.store.move((layer, e), want)
        return plan

    # ------------------------------------------------------------ routing
    def locality_host(self, layer: int, expert: int) -> int:
        """Host a selection of this expert should be routed to: one
        already holding a replica (the stream becomes a local flash
        read), else this store's host. Single-host stores are their own
        locality."""
        fab = getattr(self.store, "fabric", None)
        if fab is None:
            return self.host
        return fab.preferred_host((layer, int(expert)), default=self.host)

    def prefetch_lead_steps(self, layer: int, expert: int,
                            step_time: float) -> int:
        """p99-sized prefetch lead for this expert in decode steps (how
        early `prefetch_experts` should run so the tail-aware fetch
        estimate is covered); 1 when the store predates lead sizing."""
        lead_fn = getattr(self.store, "prefetch_lead_steps", None)
        if lead_fn is None or step_time <= 0:
            return 1
        return lead_fn((layer, int(expert)), step_time)

    # ------------------------------------------------------------ streaming
    def prefetch_experts(self, layer: int, expert_ids) -> int:
        """Issue async fetches for `expert_ids` of `layer`; returns how
        many fetches were actually started (resident-pending ones skip)."""
        started = 0
        for e in np.unique(np.asarray(expert_ids).ravel()):
            key = (layer, int(e))
            if key in self._pending or self.store.tier_of(key) is None:
                continue
            self._pending[key] = self.store.get_async(key)
            started += 1
        return started

    def fetch_expert(self, layer: int, expert: int) -> np.ndarray:
        """Blocking access to one expert's weights; only the unfinished
        part of a prior prefetch stalls."""
        key = (layer, int(expert))
        pf = self._pending.pop(key, None)
        if pf is None:
            pf = self.store.get_async(key)
        return pf.wait()

    # ----------------------------------------------------- decode pipeline
    def decode_step(self, routings: Dict[int, np.ndarray], *,
                    layer_time: float, tokens: int = 1) -> Dict[str, float]:
        """One modeled MoE decode step with layer-pipelined expert
        streaming: when layer L's router output lands, the experts layer
        L+1 selects are prefetched *before* L's own (blocking) fetches
        and L's compute, so each cold-expert flash read overlaps a full
        layer of compute instead of stalling its own layer.

        `routings` maps layer -> router-selected expert ids for this
        step (from the model's routers, a router trace, or a lookahead
        predictor). Every routing is also observed by the policy — with
        an `EconomicGate` this is what feeds the reuse sketch, so cold
        experts earn DRAM residency exactly when their measured reuse
        clears break-even. The first layer has no upstream to hide
        behind; its unprefetched fetches stall (unless a previous step
        left them resident in a fast tier).

        Returns modeled totals: decode-visible stall, fetches issued,
        prefetches started."""
        self.steps += 1
        self.tokens_per_step = tokens
        stall = 0.0
        fetched = 0
        prefetched = 0
        layers = sorted(routings)
        for i, layer in enumerate(layers):
            # raw routing keeps per-token multiplicity for the
            # popularity counts; the fetch loop below dedups itself
            self.observe_routing(layer, routings[layer],
                                 now=self.clock.now())
            ids = np.unique(np.asarray(routings[layer]).ravel())
            if i + 1 < len(layers):
                prefetched += self.prefetch_experts(
                    layers[i + 1], routings[layers[i + 1]])
            for e in ids:
                if self.store.tier_of((layer, int(e))) is None:
                    continue            # expert not materialized here
                t0 = self.clock.now()
                self.fetch_expert(layer, int(e))
                stall += self.clock.now() - t0
                fetched += 1
            self.store.runtime.advance(layer_time)
        return {"stall": stall, "fetched": float(fetched),
                "prefetched": float(prefetched)}
