from .expert_store import ExpertStore  # noqa
