"""Deterministic synthetic data pipeline with per-host sharding, resumable
iterator state, and background prefetch.

Production semantics on an offline container: the "dataset" is a
deterministic PRNG token stream (seeded per shard x step), so any host can
regenerate any batch — which makes the pipeline trivially elastic
(restore at step k on a different host count reproduces the same global
batch) and makes checkpoint-resume byte-exact.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    structure: float = 0.7     # token self-correlation (learnable signal)


class SyntheticLM:
    """Markov-ish token stream: next token = f(prev) with noise, so CE can
    actually decrease during the example training runs."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        B, S = self.host_batch, cfg.seq_len
        noise = rng.integers(0, cfg.vocab, (B, S), np.int64)
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = noise[:, 0]
        keep = rng.random((B, S)) < cfg.structure
        mult = 6364136223846793005
        for t in range(1, S):
            nxt = (toks[:, t - 1] * mult + 1442695040888963407) % cfg.vocab
            toks[:, t] = np.where(keep[:, t], nxt, noise[:, t])
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch with explicit, checkpointable position."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.ds = ds
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next_to_produce = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.ds.batch_at(self._next_to_produce)
            self._q.put((self._next_to_produce, batch))
            self._next_to_produce += 1

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1          # resume point
        return batch

    def state(self) -> dict:
        return {"step": self.step}

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
