from .pipeline import DataConfig, PrefetchIterator, SyntheticLM  # noqa
