from .sharding import Rules, serve_rules, single_device_rules, train_rules  # noqa
