"""GPipe-style pipeline parallelism over a mesh axis via shard_map +
collective_permute.

The layer stack is split into `n_stages` contiguous stages laid out along
a mesh axis; microbatches stream through with the classic GPipe schedule
(n_micro + n_stages - 1 ticks). Activations hop stage->stage+1 with
`jax.lax.ppermute` each tick, so the wire cost is exactly one microbatch
activation per tick per boundary — the schedule the assignment's PP
bullet asks for, and the third axis option (DP x TP x PP) for depth-
dominated models on narrow meshes.

This is the composable primitive (`pipeline_apply`) + a reference
equivalence oracle; the 40-cell grid itself uses DP x TP (+pod) which is
the v5e-native choice at 256 chips/pod, so PP stays an opt-in config —
see DESIGN.md §Parallelism.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding import shard_map_compat


def pipeline_apply(stage_fn: Callable, stage_params, x, mesh,
                   axis: str = "stage", n_micro: int = None):
    """Run `x` through `n_stages` chained applications of `stage_fn`.

    stage_fn(params, x) -> y must be shape-preserving (a layer block).
    stage_params: pytree with leading axis n_stages (stage i's params).
    x: [B, ...] global batch; B must divide into n_micro microbatches.
    The mesh axis `axis` (size n_stages) hosts one stage per rank.
    """
    n_stages = mesh.shape[axis]
    n_micro = n_micro or n_stages
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    ticks = n_micro + n_stages - 1

    def run(params, xs):
        # params block keeps a leading length-1 stage dim — squeeze it;
        # xs [n_micro, mb, ...] resident on every rank (replicated in;
        # only stage outputs are permuted)
        params = jax.tree.map(lambda a: a[0], params)
        rank = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])              # incoming activation
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (while t < n_micro)
            feed_idx = jnp.clip(t, 0, n_micro - 1)
            feed = xs[feed_idx]
            inp = jnp.where(rank == 0, feed, buf)
            # every stage computes each tick; results only matter inside
            # the valid window (GPipe bubble elsewhere)
            y = stage_fn(params, inp)
            # last stage emits microbatch t-(n_stages-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (rank == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y, outs[out_idx]), out_idx, 0)
            # hop activations forward one stage
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; share them along the axis
        outs = jax.lax.psum(
            jnp.where(rank == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    xs = x.reshape(n_micro, mb, *x.shape[1:])
    in_specs = (P(axis), P())        # params split by stage; data replicated
    out_specs = P()
    y = shard_map_compat(run, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)(
        stage_params, xs)
    return y.reshape(B, *x.shape[1:])


def reference_apply(stage_fn: Callable, stage_params, x):
    """Sequential oracle: fold every stage over the whole batch."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]
    for i in range(n_stages):
        p = jax.tree.map(lambda a: a[i], stage_params)
        x = stage_fn(p, x)
    return x
