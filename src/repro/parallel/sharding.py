"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Every parameter and activation in the model stack is annotated with
*logical* dimension names ("embed", "heads", "experts", ...). A `Rules`
table maps logical names to mesh axes; `spec_for` resolves a concrete
`PartitionSpec`, silently dropping assignments that do not divide the
dimension or that would reuse a mesh axis twice within one spec (XLA
requires both).

This keeps the model code mesh-agnostic: the same definitions lower on a
single host device (smoke tests), the 16x16 single-pod mesh, and the
2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...]]


def mesh_axis_types_kwargs(n_axes: int) -> dict:
    """Version-compat shim for `jax.make_mesh(..., axis_types=...)`.

    `jax.sharding.AxisType` (explicit-sharding API) only exists on newer
    jax; on older releases `jax.make_mesh` neither has nor needs the
    kwarg — every axis is implicitly Auto. Returns the kwargs dict to
    splat into `jax.make_mesh`.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_compat_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types on any supported jax version."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **mesh_axis_types_kwargs(len(axes)))


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """`jax.shard_map(..., check_vma=)` on newer jax; falls back to
    `jax.experimental.shard_map.shard_map(..., check_rep=)` (the same
    replication check under its earlier name) on older releases."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_sm
    return legacy_sm(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=check_vma)


def make_abstract_mesh(shape, axes):
    """Device-free mesh across the AbstractMesh signature change:
    newer jax takes (axis_sizes, axis_names); older takes one
    ((name, size), ...) shape tuple."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-name -> mesh-axis mapping plus the mesh itself.

    `table` values may be a mesh axis name, a tuple of axis names (e.g.
    batch over ("pod", "data")), or None (replicate).
    """

    mesh: Mesh
    table: Mapping[str, Optional[AxisName]]

    def axis_size(self, axis: AxisName) -> int:
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[axis]

    def spec_for(self, logical: Sequence[Optional[str]]) -> P:
        """Resolve logical dim names to a PartitionSpec.

        Rules:
          * unknown / None names replicate,
          * an assignment is dropped if the mesh axis is already used by an
            earlier dim of this spec,
          * divisibility is NOT checked here (shapes unknown); use
            `spec_for_shape` when the shape is available.
        """
        used: set = set()
        out = []
        for name in logical:
            ax = self.table.get(name) if name else None
            if ax is None:
                out.append(None)
                continue
            parts = ax if isinstance(ax, tuple) else (ax,)
            parts = tuple(a for a in parts if a not in used)
            if not parts:
                out.append(None)
                continue
            used.update(parts)
            out.append(parts if len(parts) > 1 else parts[0])
        return P(*out)

    def spec_for_shape(self, shape: Sequence[int],
                       logical: Sequence[Optional[str]]) -> P:
        """Like spec_for but drops axes that do not divide the dim size."""
        assert len(shape) == len(logical), (shape, logical)
        used: set = set()
        out = []
        for dim, name in zip(shape, logical):
            ax = self.table.get(name) if name else None
            if ax is None:
                out.append(None)
                continue
            parts = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                          if a not in used)
            # greedily keep the longest prefix of axes that divides dim
            while parts and dim % self.axis_size(parts) != 0:
                parts = parts[:-1]
            if not parts:
                out.append(None)
                continue
            used.update(parts)
            out.append(parts if len(parts) > 1 else parts[0])
        return P(*out)

    def sharding(self, shape: Sequence[int],
                 logical: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for_shape(shape, logical))

    def constrain(self, x, *logical: Optional[str]):
        """with_sharding_constraint by logical names (checked against shape)."""
        spec = self.spec_for_shape(x.shape, logical)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
# Standard rule tables
# ---------------------------------------------------------------------------

def _dp_axes(mesh: Mesh) -> AxisName:
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def train_rules(mesh: Mesh, *, fsdp: bool = True,
                shard_residual_embed: bool = True) -> Rules:
    """Baseline training rules: TP on "model", DP (+pod) on batch, optional
    FSDP-style parameter sharding over "data".

    `shard_residual_embed` shards the scan-carried residual stream's embed
    dim over "model" — bounds stored activations per layer to 1/TP.
    """
    dp = _dp_axes(mesh)
    table = {
        # activations
        "batch": dp,
        "seq": None,
        "res_embed": "model" if shard_residual_embed else None,
        "act_embed": None,
        "act_heads": "model",
        "act_kv": "model",
        "act_qr": "model",    # query-repeat dim claims TP when kv cannot
        "act_ffn": "model",
        "act_experts": "model",
        "act_vocab": "model",
        # params
        "embed": "data" if fsdp else None,     # fsdp axis
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "experts": "model",
        "expert_ffn": "data" if fsdp else None,
        "moe_ffn": None,
        "state": None,
        "conv": None,
        "layers": None,
    }
    return Rules(mesh=mesh, table=table)


def serve_rules(mesh: Mesh, *, moe_tokens_gather: bool = False) -> Rules:
    """Inference rules: no FSDP (params resident), KV cache batch over DP,
    heads over model when divisible, else seq over model.

    `moe_tokens_gather=True` selects the decode-optimized MoE layout:
    expert weights stay fully resident as [E/TP, D, F/data] and the few
    decode tokens are gathered over "data" instead of gathering weights —
    trades the per-layer ~(3*D*F*E/TP) weight all-gather for a
    ~(tokens*D) token gather + output psum."""
    dp = _dp_axes(mesh)
    table = {
        "batch": dp,
        "seq": None,
        "res_embed": "model",
        "act_embed": None,
        "act_heads": "model",
        "act_kv": "model",
        "act_ffn": "model",
        "act_experts": "model",
        "act_vocab": "model",
        # cache layout is [B, KV, S, hd]: kv-heads claim "model" when
        # divisible (dim order gives them priority); otherwise the seq dim
        # takes it (32k/16 = 2k per shard).
        "cache_batch": dp,
        "cache_kv": "model",
        "cache_seq": "model",
        "embed": None,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "experts": "model",
        # 2D expert sharding at serving: 235B/400B-class MoE weights do
        # not fit at 1/TP per chip. Weight-gather: D over "data", gathered
        # at use. Token-gather (decode): F over "data", weights resident.
        "expert_ffn": None if moe_tokens_gather else "data",
        "moe_ffn": "data" if moe_tokens_gather else None,
        "moe_strategy": "tokens" if moe_tokens_gather else "weights",
        "state": None,
        "conv": None,
        "layers": None,
    }
    return Rules(mesh=mesh, table=table)


def single_device_rules() -> Rules:
    """Rules over a trivial 1-device mesh — used by smoke tests/examples."""
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    return train_rules(mesh, fsdp=False, shard_residual_embed=False)


def params_shardings(rules: Rules, abstract_params, logical_tree):
    """Map a pytree of abstract arrays + parallel logical-name tree to
    NamedShardings."""
    return jax.tree.map(
        lambda a, names: rules.sharding(a.shape, names),
        abstract_params, logical_tree,
        is_leaf=lambda x: isinstance(x, (list, tuple)) and all(
            isinstance(e, (str, type(None))) for e in x))
