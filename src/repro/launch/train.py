"""End-to-end training driver: data pipeline -> jit train_step ->
checkpoint manager -> watchdog, with restart/rollback semantics.

Runs reduced configs on CPU (examples, CI) and the full configs unchanged
on a real mesh — the driver only touches public APIs that are
mesh-agnostic.

  python -m repro.launch.train --arch deepseek-7b --reduced --steps 200
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointConfig, CheckpointManager
from ..configs import ARCHS, get_config
from ..data.pipeline import DataConfig, PrefetchIterator, SyntheticLM
from ..optim.adamw import AdamWConfig
from ..parallel.sharding import single_device_rules, train_rules
from ..train.step import TrainConfig, init_state, train_step
from ..train.watchdog import RollbackSignal, Watchdog
from .mesh import make_host_mesh


@dataclasses.dataclass
class RunConfig:
    arch: str = "deepseek-7b"
    reduced: bool = True
    steps: int = 100
    batch: int = 8
    seq: int = 64
    lr: float = 3e-3
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    resume: bool = True
    max_rollbacks: int = 3
    microbatch: int = 0


def run(rc: RunConfig, rules=None, quiet=False):
    cfg = get_config(rc.arch, reduced=rc.reduced)
    rules = rules or single_device_rules()
    tcfg = TrainConfig(
        optimizer=AdamWConfig(peak_lr=rc.lr, warmup_steps=max(
            rc.steps // 20, 5), total_steps=rc.steps),
        microbatch=rc.microbatch)

    state, _ = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    mgr = CheckpointManager(CheckpointConfig(root=rc.ckpt_dir))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=rc.seq,
                                  global_batch=rc.batch))

    start = 0
    if rc.resume and mgr.latest_step() is not None:
        state, extra = mgr.restore(state)
        start = int(extra.get("data_step", mgr.latest_step()))
        if not quiet:
            print(f"[train] resumed from step {start}")

    step_fn = jax.jit(functools.partial(train_step, cfg=cfg, rules=rules,
                                        tcfg=tcfg), donate_argnums=(0,))
    wd = Watchdog()
    it = PrefetchIterator(data, start_step=start)
    losses = []
    rollbacks = 0
    i = start
    while i < rc.steps:
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        wd.begin_step()
        try:
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            wd.end_step(i, loss)
        except RollbackSignal as sig:
            rollbacks += 1
            if rollbacks > rc.max_rollbacks or mgr.latest_step() is None:
                raise
            state, extra = mgr.restore(state)
            it.close()
            i = int(extra.get("data_step", mgr.latest_step()))
            it = PrefetchIterator(data, start_step=i)
            if not quiet:
                print(f"[train] {sig} -> restored step {i}")
            continue
        losses.append(loss)
        i += 1
        if i % rc.ckpt_every == 0 or i == rc.steps:
            mgr.save(i, state, extra={"data_step": i,
                                      "loss": loss})
        if not quiet and i % rc.log_every == 0:
            print(f"[train] step {i:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
    it.close()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "straggler_events": wd.straggler_events,
            "rollbacks": rollbacks, "state": state}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — real mesh required")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()
    rc = RunConfig(arch=args.arch, reduced=not args.full, steps=args.steps,
                   batch=args.batch, seq=args.seq, lr=args.lr,
                   ckpt_dir=args.ckpt_dir, resume=not args.no_resume)
    out = run(rc)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"({len(out['losses'])} steps, {out['rollbacks']} rollbacks)")


if __name__ == "__main__":
    main()
