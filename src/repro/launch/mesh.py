"""Production meshes.

Single pod: 256 v5e chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16) — model
parallelism stays within a pod (ICI); the "pod" axis carries pure data
parallelism over the inter-pod link (DCI).

Defined as functions (never module-level constants) so importing this
module touches no jax device state.
"""
from __future__ import annotations

import jax

from ..parallel.sharding import make_compat_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (tests / small runs)."""
    return make_compat_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Mesh over whatever devices exist (CPU smoke runs, examples)."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))
