import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""HLO attribution tool for the perf loop: lowers a cell and histograms
output-shape bytes by op kind and by originating source line (metadata),
identifying which model code accounts for the memory/collective terms.

  python -m repro.launch.diagnose --arch qwen3-moe-235b-a22b \\
      --shape train_4k [--groups 1] [--top 25]
"""
import argparse
import collections
import dataclasses
import re

from ..configs import ARCHS, get_config
from ..configs import shapes as shp
from .dryrun import lower_cell
from .mesh import make_production_mesh
from .roofline import _DTYPE_BYTES, _SHAPE_RE

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = (\S+) ([\w\-]+)\(")
_META_RE = re.compile(r'op_name="([^"]*)"')


def _bytes_of(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in (dims.split(",") if dims else []):
            n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def histogram(hlo_text: str):
    by_kind = collections.Counter()
    by_src = collections.Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if kind in ("parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast"):
            continue
        b = _bytes_of(shape_str)
        if b < 2**20:
            continue
        by_kind[kind] += b
        mm = _META_RE.search(line)
        src = mm.group(1)[-90:] if mm else "?"
        by_src[f"{kind:18s} {src}"] += b
    return by_kind, by_src


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--shape", choices=list(shp.SHAPES), required=True)
    ap.add_argument("--groups", type=int, default=0,
                    help=">0: unrolled probe with this many groups")
    ap.add_argument("--cost-exact", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.groups:
        cfg = dataclasses.replace(cfg, n_groups=args.groups)
        if cfg.encoder is not None:
            cfg = dataclasses.replace(cfg, encoder=dataclasses.replace(
                cfg.encoder, n_groups=args.groups))
    shape = shp.SHAPES[args.shape]
    mesh = make_production_mesh()
    compiled = lower_cell(cfg, shape, mesh, step_kind=shape.step,
                          cost_exact=args.cost_exact,
                          unroll=bool(args.groups))
    ca = compiled.cost_analysis()
    print(f"flops={ca.get('flops', 0):.3e}  "
          f"bytes={ca.get('bytes accessed', 0):.3e}")
    by_kind, by_src = histogram(compiled.as_text())
    print("\n-- output bytes by op kind (>=1MiB ops) --")
    for k, v in by_kind.most_common(args.top):
        print(f"  {v/2**30:10.2f} GiB  {k}")
    print("\n-- output bytes by source --")
    for k, v in by_src.most_common(args.top):
        print(f"  {v/2**30:10.2f} GiB  {k}")


if __name__ == "__main__":
    main()
