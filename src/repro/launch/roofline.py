"""Roofline-term extraction from compiled XLA artifacts.

Terms per (arch x shape x mesh), all in seconds per step on TPU v5e:

  compute    = HLO_FLOPs / (chips * 197e12)         [bf16 peak]
  memory     = HLO_bytes / (chips * 819e9)          [HBM]
  collective = per-chip wire bytes / 50e9           [ICI per-link]

HLO FLOPs/bytes come from `compiled.cost_analysis()`. Because XLA's cost
analysis counts a `while` (scan) body ONCE regardless of trip count, the
dry-run measures costs with two *unrolled* probe compiles (n_groups=1 and
n_groups=2, cost_exact=True) and extrapolates:

  total(G) = probe(1) + (G - 1) * (probe(2) - probe(1))

which is exact for homogeneous group stacks (all ten assigned archs).
Collective wire bytes are parsed from the post-SPMD HLO text: per-device
shard shapes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, scaled by the standard ring factors.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_wire_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, from post-SPMD HLO.

    Shapes in the partitioned module are per-shard. Ring-algorithm factors:
      all-gather:     result_bytes * (N-1)/N      (result = gathered)
      reduce-scatter: result_bytes * (N-1)        (input = result * N)
      all-reduce:     2 * result_bytes * (N-1)/N
      all-to-all:     result_bytes * (N-1)/N
      collective-permute: result_bytes
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s*((?:\([^)]*\))|(?:\w+\[[0-9,]*\]\S*))\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue                       # counted at -start
        if phase == "-start" and shape_str.startswith("("):
            # async start returns (operand, result[, ...]): count the result
            shapes = _SHAPE_RE.findall(shape_str)
            if len(shapes) >= 2:
                dt, dims = shapes[1]
                shape_str = f"{dt}[{dims}]"
        b = _shape_bytes(shape_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            n = len(gb.group(1).split(",")) if gb else 2
        if n <= 1:
            continue
        if kind == "all-gather":
            wire = b * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = b * (n - 1)
        elif kind == "all-reduce":
            wire = 2.0 * b * (n - 1) / n
        elif kind == "all-to-all":
            wire = b * (n - 1) / n
        else:  # collective-permute
            wire = b
        out[kind] = out.get(kind, 0.0) + wire
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class CostTerms:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    wire_by_kind: Dict[str, float]

    def __sub__(self, o: "CostTerms") -> "CostTerms":
        return CostTerms(
            self.flops - o.flops, self.bytes_accessed - o.bytes_accessed,
            self.wire_bytes - o.wire_bytes,
            {k: self.wire_by_kind.get(k, 0.0) - o.wire_by_kind.get(k, 0.0)
             for k in set(self.wire_by_kind) | set(o.wire_by_kind)})

    def __add__(self, o: "CostTerms") -> "CostTerms":
        return CostTerms(
            self.flops + o.flops, self.bytes_accessed + o.bytes_accessed,
            self.wire_bytes + o.wire_bytes,
            {k: self.wire_by_kind.get(k, 0.0) + o.wire_by_kind.get(k, 0.0)
             for k in set(self.wire_by_kind) | set(o.wire_by_kind)})

    def scale(self, f: float) -> "CostTerms":
        return CostTerms(self.flops * f, self.bytes_accessed * f,
                         self.wire_bytes * f,
                         {k: v * f for k, v in self.wire_by_kind.items()})

    def to_dict(self):
        return {"flops": self.flops, "bytes_accessed": self.bytes_accessed,
                "wire_bytes": self.wire_bytes,
                "wire_by_kind": self.wire_by_kind}


def hlo_cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a per-device dict on newer jax
    and a one-element list of dicts on older releases; normalize."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def cost_terms(compiled) -> CostTerms:
    ca = hlo_cost_analysis(compiled)
    wires = collective_wire_bytes(compiled.as_text())
    return CostTerms(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        wire_bytes=wires["total"],
        wire_by_kind={k: v for k, v in wires.items() if k != "total"})


def extrapolate(probe1: CostTerms, probe2: CostTerms,
                n_groups: int) -> CostTerms:
    """total(G) = probe(1) + (G-1) * marginal."""
    marginal = probe2 - probe1
    return probe1 + marginal.scale(n_groups - 1)


def roofline(total: CostTerms, chips: int, model_flops: float,
             steps_per_call: int = 1) -> Dict[str, float]:
    """The three terms (seconds) + bottleneck + usefulness ratio.

    cost_analysis FLOPs/bytes from a post-SPMD module are PER-DEVICE
    (verified empirically: an 8-way batch-sharded matmul reports 1/8 of the
    logical FLOPs), as are the parsed wire bytes. `model_flops` is global,
    so it is divided by the chip count."""
    t_comp = total.flops / PEAK_FLOPS
    t_mem = total.bytes_accessed / HBM_BW
    t_coll = total.wire_bytes / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_model = model_flops / (chips * PEAK_FLOPS)
    t_bound = max(terms.values())
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_per_device": total.flops,
        "useful_flop_ratio": model_flops / max(total.flops * chips, 1.0),
        "roofline_fraction": (t_model / t_bound) if t_bound > 0 else 0.0,
        "step_time_bound": t_bound,
    }


def model_flops_for(cfg, shape, mesh_chips: int) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D forward-only,
    with N = active params."""
    n_active = cfg.active_param_count()
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch          # one token per sequence
    return 2.0 * n_active * tokens
