# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and is
# meant to be launched as `python -m repro.launch.dryrun`.
from . import mesh, roofline  # noqa
