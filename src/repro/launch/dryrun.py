import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
cell with abstract inputs (ShapeDtypeStruct, zero allocation), record
memory_analysis / cost_analysis / the collective schedule, and emit the
roofline terms.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--no-probes]
  python -m repro.launch.dryrun --list

Results are cached as JSON under results/dryrun/.
"""
import argparse
import dataclasses
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, get_config
from ..configs import shapes as shp
from ..models import model as model_lib
from ..parallel.sharding import Rules, serve_rules, train_rules
from ..train import step as train_step_lib
from . import roofline
from .mesh import make_production_mesh

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

def _is_names(v):
    return isinstance(v, tuple) and all(
        isinstance(e, (str, type(None))) for e in v)


def tree_shardings(rules: Rules, abstract, logical):
    return jax.tree.map(
        lambda a, names: rules.sharding(a.shape, names),
        abstract, logical, is_leaf=lambda x: _is_names(x))


def with_shardings(abstract, shardings):
    """Attach shardings to ShapeDtypeStructs (jit then needs no in_shardings)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


def batch_shardings(rules: Rules, batch):
    out = {}
    for k, v in batch.items():
        if k == "positions":
            names = (None, "batch", None) if len(v.shape) == 3 \
                else ("batch", None)
        elif v.ndim == 3:
            names = ("batch", None, None)
        else:
            names = ("batch",) + (None,) * (v.ndim - 1)
        out[k] = rules.sharding(v.shape, names)
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def abstract_train_state(cfg, tcfg):
    """Abstract TrainState + logical tree without allocating."""
    params_abs = jax.eval_shape(
        lambda k: model_lib.init_params(k, cfg)[0], jax.random.PRNGKey(0))
    logical = model_logical(cfg)
    opt_abs = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "mu": jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params_abs),
        "nu": jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), params_abs),
    }
    state_abs = {"params": params_abs, "opt": opt_abs}
    state_logical = {"params": logical,
                     "opt": {"step": (), "mu": logical, "nu": logical}}
    return state_abs, state_logical


def model_logical(cfg):
    """Logical tree for params, computed without touching arrays."""
    logical = {"embed": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        logical["unembed"] = ("vocab", "embed")

    def stacked(spec):
        return jax.tree.map(
            lambda names: ("layers",) + tuple(names),
            model_lib._sub_logical(cfg, spec), is_leaf=_is_names)

    shared = {}
    groups = {}
    for li, layer in enumerate(cfg.pattern):
        for si, s in enumerate(layer):
            k = model_lib._key(li, si)
            if getattr(s, "shared", False):
                shared[k] = model_lib._sub_logical(cfg, s)
            else:
                groups[k] = stacked(s)
    if shared:
        logical["shared"] = shared
    logical["groups"] = groups
    if cfg.tail:
        logical["tail"] = {
            model_lib._key(li, si): model_lib._sub_logical(cfg, s)
            for li, layer in enumerate(cfg.tail)
            for si, s in enumerate(layer)}
    from ..models.layers import norm_init
    _, fnl = norm_init(cfg.d_model, cfg.norm)
    logical["final_norm"] = fnl
    if cfg.encoder is not None:
        elog = {model_lib._key(li, si): stacked(s)
                for li, layer in enumerate(cfg.encoder.pattern)
                for si, s in enumerate(layer)}
        logical["encoder"] = {"groups": elog, "final_norm": fnl}
    return logical


def lower_cell(cfg, shape, mesh, *, step_kind, cost_exact=False,
               unroll=False, tcfg=None, moe_tokens_gather=False,
               kv_int8=False):
    """Lower+compile one cell; returns the compiled artifact."""
    import jax.numpy as _jnp
    kv_dtype = _jnp.int8 if kv_int8 else _jnp.bfloat16
    tcfg = tcfg or train_step_lib.TrainConfig()
    _serve_rules = functools.partial(serve_rules,
                                     moe_tokens_gather=moe_tokens_gather)
    if step_kind == "train":
        rules = train_rules(mesh)
        state_abs, state_logical = abstract_train_state(cfg, tcfg)
        state_sh = tree_shardings(rules, state_abs, state_logical)
        state_in = with_shardings(state_abs, state_sh)
        batch = shp.token_inputs(cfg, shape)
        batch_in = with_shardings(batch, batch_shardings(rules, batch))
        fn = functools.partial(
            train_step_lib.train_step, cfg=cfg, rules=rules, tcfg=tcfg,
            cost_exact=cost_exact, unroll=unroll)
        # donate the TrainState: optimizer update aliases in-place, exactly
        # as the production step runs
        lowered = jax.jit(fn, donate_argnums=(0,)).lower(state_in, batch_in)
    elif step_kind == "prefill":
        rules = _serve_rules(mesh)
        params_abs = jax.eval_shape(
            lambda k: model_lib.init_params(k, cfg)[0],
            jax.random.PRNGKey(0))
        params_abs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16
                                           if a.dtype == jnp.float32
                                           else a.dtype), params_abs)
        logical = model_logical(cfg)
        p_in = with_shardings(params_abs,
                              tree_shardings(rules, params_abs, logical))
        batch = shp.token_inputs(cfg, shape)
        batch_in = with_shardings(batch, batch_shardings(rules, batch))
        cache_abs = shp.cache_specs(cfg, shape.global_batch, shape.seq_len)
        cache_log = model_lib.cache_logical_tree(cfg)
        cache_in = with_shardings(
            cache_abs, tree_shardings(rules, cache_abs, cache_log))
        fn = functools.partial(model_lib.prefill, cfg=cfg, rules=rules,
                               cost_exact=cost_exact, unroll=unroll)
        lowered = jax.jit(
            lambda p, b, c: fn(p, batch=b, cache=c),
            donate_argnums=(2,)).lower(p_in, batch_in, cache_in)
    elif step_kind == "decode":
        rules = _serve_rules(mesh)
        params_abs = jax.eval_shape(
            lambda k: model_lib.init_params(k, cfg)[0],
            jax.random.PRNGKey(0))
        params_abs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16
                                           if a.dtype == jnp.float32
                                           else a.dtype), params_abs)
        logical = model_logical(cfg)
        p_in = with_shardings(params_abs,
                              tree_shardings(rules, params_abs, logical))
        token, cache_abs, index = shp.decode_inputs(cfg, shape,
                                                     kv_dtype=kv_dtype)
        cache_log = model_lib.cache_logical_tree(cfg, kv_quant=kv_int8)
        cache_in = with_shardings(
            cache_abs, tree_shardings(rules, cache_abs, cache_log))
        tok_in = with_shardings(
            token, rules.sharding(token.shape, ("batch", None)))
        fn = functools.partial(model_lib.decode_step, cfg=cfg, rules=rules,
                               cost_exact=cost_exact, unroll=unroll)
        lowered = jax.jit(
            lambda p, t, c, i: fn(p, token=t, cache=c, index=i),
            donate_argnums=(2,)).lower(p_in, tok_in, cache_in, index)
    else:
        raise ValueError(step_kind)
    return lowered.compile()


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             probes: bool = True, tcfg=None, cfg_override=None,
             tag: str = "", moe_tokens_gather: bool = False,
             kv_int8: bool = False) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = shp.SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    out = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "step": shape.step, "tag": tag}
    skip = shp.skip_reason(cfg, shape)
    if skip:
        out["skipped"] = skip
        return out

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    compiled = lower_cell(cfg, shape, mesh, step_kind=shape.step,
                          tcfg=tcfg, moe_tokens_gather=moe_tokens_gather,
                          kv_int8=kv_int8)
    out["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    out["memory"] = {
        "argument_gib": ma.argument_size_in_bytes / 2**30,
        "output_gib": ma.output_size_in_bytes / 2**30,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "alias_gib": ma.alias_size_in_bytes / 2**30,
        "code_gib": ma.generated_code_size_in_bytes / 2**30,
        "peak_gib": peak / 2**30,
        "hbm_gib": 16.0,
        "fits": peak / 2**30 <= 16.0,
    }
    full = roofline.cost_terms(compiled)
    out["scanned_artifact"] = full.to_dict()
    del compiled

    if probes:
        p1 = _probe(cfg, shape, mesh, 1, tcfg,
                    moe_tokens_gather=moe_tokens_gather, kv_int8=kv_int8)
        p2 = _probe(cfg, shape, mesh, 2, tcfg,
                    moe_tokens_gather=moe_tokens_gather, kv_int8=kv_int8)
        total = roofline.extrapolate(p1, p2, cfg.n_groups)
        # gradient accumulation runs the model as a scan over microbatches
        # (body counted once): scale per-step costs by the slice count
        # (slight optimizer-update overcount, <1% of flops)
        if tcfg is not None and getattr(tcfg, "microbatch", 0):
            n_micro = shape.global_batch // tcfg.microbatch
            if n_micro > 1:
                total = total.scale(n_micro)
                p1, p2 = p1.scale(n_micro), p2.scale(n_micro)
        out["probe1"] = p1.to_dict()
        out["probe2"] = p2.to_dict()
        out["total"] = total.to_dict()
        # exact probes materialize full quadratic scores: correct FLOPs but
        # inflated bytes, and SPMD can insert replicate-reshard collectives
        # the streamed path never executes. For attention cells, re-probe
        # the streamed (chunked/flash) path and take bytes + wire from it.
        if shape.seq_len ** 2 > 1024 * 1024 \
                and shape.step in ("train", "prefill") \
                and cfg.has_attention:
            c1 = _probe(cfg, shape, mesh, 1, tcfg, cost_exact=False,
                        moe_tokens_gather=moe_tokens_gather)
            c2 = _probe(cfg, shape, mesh, 2, tcfg, cost_exact=False,
                        moe_tokens_gather=moe_tokens_gather)
            chunked = roofline.extrapolate(c1, c2, cfg.n_groups)
            if tcfg is not None and getattr(tcfg, "microbatch", 0):
                n_micro = shape.global_batch // tcfg.microbatch
                if n_micro > 1:
                    chunked = chunked.scale(n_micro)
            out["probe1_chunked"] = c1.to_dict()
            out["probe2_chunked"] = c2.to_dict()
            out["total_chunked"] = chunked.to_dict()
            total = roofline.CostTerms(
                total.flops, chunked.bytes_accessed, chunked.wire_bytes,
                chunked.wire_by_kind)
        mf = roofline.model_flops_for(cfg, shape, chips)
        out["model_flops"] = mf
        out["n_groups"] = cfg.n_groups
        out["chips"] = chips
        out["roofline"] = roofline.roofline(total, chips, mf)
    return out


def _probe(cfg, shape, mesh, n_groups, tcfg, cost_exact=True,
           moe_tokens_gather=False, kv_int8=False):
    """Unrolled probe with `n_groups` groups."""
    small = dataclasses.replace(cfg, n_groups=n_groups)
    if cfg.encoder is not None:
        small = dataclasses.replace(
            small, encoder=dataclasses.replace(cfg.encoder,
                                               n_groups=n_groups))
    compiled = lower_cell(small, shape, mesh, step_kind=shape.step,
                          cost_exact=cost_exact, unroll=True, tcfg=tcfg,
                          moe_tokens_gather=moe_tokens_gather,
                          kv_int8=kv_int8)
    terms = roofline.cost_terms(compiled)
    del compiled
    return terms


def cells(include_skipped: bool = False):
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name in shp.SHAPE_ORDER:
            skip = shp.skip_reason(cfg, shp.SHAPES[shape_name])
            if skip and not include_skipped:
                continue
            yield arch, shape_name, skip


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(shp.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--serve-tokens-gather", action="store_true",
                    help="decode-optimized MoE layout (hillclimb variant);"
                         " results tagged __tokens")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="gradient-accumulation microbatch (train cells)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="quantized int8 KV cache (decode cells)")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.list:
        for arch, shape_name, skip in cells(include_skipped=True):
            print(f"{arch:28s} {shape_name:12s}"
                  f"{' SKIP: ' + skip if skip else ''}")
        return

    todo = []
    if args.all:
        todo = [(a, s) for a, s, _ in cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    ok = True
    tag = ""
    tcfg = None
    if args.serve_tokens_gather:
        tag += "__tokens"
    if args.kv_int8:
        tag += "__kvint8"
    if args.microbatch:
        tag += f"__mb{args.microbatch}"
        tcfg = train_step_lib.TrainConfig(
            microbatch=args.microbatch)
    for arch, shape_name in todo:
        mesh_name = "multi" if args.multi_pod else "single"
        path = outdir / f"{arch}__{shape_name}__{mesh_name}{tag}.json"
        if path.exists() and not args.force:
            print(f"[cached] {path.name}")
            continue
        print(f"[run] {arch} x {shape_name} x {mesh_name}{tag}", flush=True)
        try:
            res = run_cell(arch, shape_name, multi_pod=args.multi_pod,
                           probes=not args.no_probes, tag=tag, tcfg=tcfg,
                           moe_tokens_gather=args.serve_tokens_gather,
                           kv_int8=args.kv_int8)
            path.write_text(json.dumps(res, indent=1))
            if "roofline" in res:
                r = res["roofline"]
                print(f"  compile={res['compile_s']}s "
                      f"peak={res['memory']['peak_gib']:.2f}GiB "
                      f"dom={r['dominant']} "
                      f"frac={r['roofline_fraction']:.3f}", flush=True)
            elif "skipped" in res:
                print(f"  skipped: {res['skipped']}")
            else:
                print(f"  compile={res['compile_s']}s "
                      f"peak={res['memory']['peak_gib']:.2f}GiB")
        except Exception as e:
            ok = False
            print(f"  FAILED: {type(e).__name__}: {e}")
            traceback.print_exc(limit=8)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
