"""Continuous batching scheduler: per-step admission over the slot grid.

The seed `DecodeEngine.run` loop is all-or-nothing gang scheduling: a
batch is admitted, decoded until *every* member finishes, and only then
are new requests admitted — a slot going idle stalls the rest of the
batch for the whole gang tail. `ContinuousScheduler` replaces it with a
step-level control loop over the same engine: every tick it

  1. moves newly due session turns into an EDF-ordered admission queue
     (earliest absolute deadline = `due_step + deadline_steps` first),
  2. issues prefetch-led restores for paused sessions whose next turn
     is within the p99-sized prefetch lead,
  3. fills every free slot from the queue (first turns via the bucketed
     prefill + traced-slot splice, later turns via `resume` — the PR 5
     splice-jit cache makes per-step admission compile-free),
  4. runs one decode step (or advances the clock when the grid is idle),
  5. pauses-on-idle at turn boundaries: a session whose next turn is
     further than `pause_idle_steps` away is offloaded through the
     tiered store (the paper's five-minute-rule decision point — the
     policy picks DRAM vs flash from tracked reuse); shorter gaps park
     in place (slot held, no decode, no restore stall). Parked slots
     are preempted (paused) when the queue needs their slot.

Time is discrete: one tick == one decode step == `engine.step_time`
modeled seconds, and `Turn.due_step` is an absolute tick index. All
state transitions are deterministic given the job list, so token output
is byte-identical to the lock-step reference (`run_lockstep`) — greedy
decode makes the tokens a function of the prompt alone, and the
property tests assert the schedulers cannot change them.

Scheduling waste is first-class: `slot_idle_steps` counts slot-ticks
where a slot could have decoded but didn't (free or parked) while work
existed in the system. The comparison metric
`per_token_stall = (kv_stall + step_time * slot_idle_steps) / tokens`
charges gang idling and restore stalls in the same currency, which is
what makes continuous-vs-lockstep an apples-to-apples race
(`compare_scheduling`).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

import numpy as np

from ..obs.ledger import COMPONENTS, StallLedger
from .engine import DecodeEngine, Request


@dataclasses.dataclass(frozen=True)
class Turn:
    """One session turn: becomes runnable at absolute tick `due_step`,
    generates `max_new` tokens, and should be admitted within
    `deadline_steps` ticks of becoming due (0 = as soon as possible;
    the EDF queue orders by `due_step + deadline_steps`)."""
    due_step: int
    max_new: int
    deadline_steps: int = 0


# eq=False for the same reason as Request: the ndarray prompt poisons
# the generated __eq__, and jobs are keyed by sid everywhere
@dataclasses.dataclass(eq=False)
class SessionJob:
    sid: str
    prompt: np.ndarray                  # [S] int32, first-turn prefill
    turns: List[Turn]
    tenant: str = ""                    # SLO accounting class ("" = none)
    # runtime state (owned by the scheduler)
    request: Optional[Request] = None
    turn_idx: int = 0
    state: str = "waiting"  # waiting|ready|running|parked|paused|done
    admitted_step: int = -1
    stall: float = 0.0      # restore (KV fetch) stall attributed here (s)

    def target(self) -> int:
        """Cumulative token count at the end of the current turn."""
        return sum(t.max_new for t in self.turns[:self.turn_idx + 1])

    def total(self) -> int:
        return sum(t.max_new for t in self.turns)

    def due(self) -> int:
        return self.turns[self.turn_idx].due_step

    def deadline(self) -> int:
        t = self.turns[self.turn_idx]
        return t.due_step + t.deadline_steps


class ContinuousScheduler:
    """Step-level admission/eviction controller over one `DecodeEngine`.

    Knobs (also declarable via `HierarchySpec.scheduler`):
      pause_idle_steps: inter-turn gaps <= this many ticks keep the
        session parked in its slot; longer gaps offload through the
        tiered store (0 = always offload).
      prefetch_lead: "p99" sizes each paused session's restore prefetch
        from the serving tier's calibrated tail (`engine.prefetch_lead`);
        an int is a fixed lead in ticks; 0 disables prefetch.
    """

    def __init__(self, engine: DecodeEngine, *,
                 pause_idle_steps: int = 0,
                 prefetch_lead="p99",
                 stall_budgets: Optional[Dict[str, float]] = None):
        self.engine = engine
        self.pause_idle_steps = int(pause_idle_steps)
        self.prefetch_lead = prefetch_lead
        self.obs = getattr(engine, "obs", None)
        # adopt the store's always-on stall ledger (TieredStore and the
        # fabric's HostView both expose one); idle-slot rent lands there
        # under the identical condition `slot_idle_steps` counts, which
        # is what makes the conservation law in report() exact
        ledger = getattr(engine.store, "ledger", None)
        self.ledger = ledger if ledger is not None else StallLedger()
        self._ledger_base = self.ledger.snapshot()
        self._ledger_tenant_base = {
            t: dict(v) for t, v in self.ledger.tenants.items()}
        # tenant -> declared p99 stall budget (sec/token); report()
        # derives each tenant's budget burn from its ledger slice
        self.stall_budgets = dict(stall_budgets) if stall_budgets else {}
        self.now = 0                    # tick index (== decode steps + idle)
        self.jobs: Dict[str, SessionJob] = {}
        self._waiting: List[tuple] = []  # heap of (due, seq, job)
        self._ready: List[tuple] = []    # heap of (deadline, due, seq, job)
        self._seq = 0                    # FIFO tie-break, deterministic
        self.metrics = {
            "ticks": 0, "decode_steps": 0, "idle_ticks": 0,
            "slot_idle_steps": 0, "parked_slot_steps": 0,
            "admissions": 0, "resumes": 0, "unparks": 0, "pauses": 0,
            "parks": 0, "preempt_pauses": 0, "prefetches": 0,
            "deadline_misses": 0, "tokens": 0,
        }
        # per-tenant event counters (report() folds in token/stall sums)
        self.tenant_metrics: Dict[str, Dict[str, int]] = {}

    # ------------------------------------------------------------- intake
    def submit(self, job: SessionJob):
        if not job.turns:
            raise ValueError(f"job {job.sid!r} has no turns")
        if job.sid in self.jobs:
            raise KeyError(f"job {job.sid!r} already submitted")
        self.jobs[job.sid] = job
        job.state = "waiting"
        self._push_waiting(job)

    def submit_all(self, jobs):
        for j in jobs:
            self.submit(j)

    def _push_waiting(self, job: SessionJob):
        heapq.heappush(self._waiting, (job.due(), self._seq, job))
        self._seq += 1

    def _push_ready(self, job: SessionJob):
        job.state = "ready"
        heapq.heappush(self._ready,
                       (job.deadline(), job.due(), self._seq, job))
        self._seq += 1

    # ------------------------------------------------------------ queries
    def pending_work(self) -> bool:
        return any(j.state != "done" for j in self.jobs.values())

    def _lead_for(self, job: SessionJob) -> int:
        if self.prefetch_lead == "p99":
            return self.engine.prefetch_lead(job.sid)
        return int(self.prefetch_lead)

    def _bump(self, job: SessionJob, field: str, by: int = 1):
        """Count `field` against the job's tenant (no-op untagged)."""
        if not job.tenant:
            return
        m = self.tenant_metrics.get(job.tenant)
        if m is None:
            m = {"admissions": 0, "resumes": 0, "unparks": 0,
                 "parks": 0, "pauses": 0, "deadline_misses": 0}
            self.tenant_metrics[job.tenant] = m
        m[field] += by

    def _trace(self, name: str, **args):
        """Scheduler policy instant on the modeled clock (no-op unless
        an `Observability` with tracing is attached to the engine)."""
        obs = self.obs
        if obs is None or obs.tracer is None:
            return
        t = obs.tracer
        args["tick"] = self.now
        t.instant(t.track("scheduler", "policy"), name,
                  self.engine.clock.now(), cat="policy", args=args)

    # --------------------------------------------------------------- tick
    def tick(self):
        """One scheduler step: arrivals -> prefetch -> admission ->
        decode (or idle clock advance) -> turn boundaries."""
        eng = self.engine
        # 1. arrivals: due turns leave the waiting heap
        while self._waiting and self._waiting[0][0] <= self.now:
            _, _, job = heapq.heappop(self._waiting)
            if job.state == "parked":
                # resident the whole gap: just flip the slot back on.
                # This is an admission like any other — counted, and
                # held to the same deadline check paused sessions pay
                # (a parked turn popped late is still a miss)
                eng.unpark(job.sid)
                job.state = "running"
                job.admitted_step = self.now
                self.metrics["unparks"] += 1
                self._bump(job, "unparks")
                if self.now > job.deadline():
                    self.metrics["deadline_misses"] += 1
                    self._bump(job, "deadline_misses")
                    self._trace("deadline_miss", sid=job.sid,
                                deadline=job.deadline())
            else:
                self._push_ready(job)
        # 2. prefetch-led resume for paused sessions nearing their due
        for job in self._paused_jobs():
            lead = self._lead_for(job)
            if lead > 0 and job.due() - self.now <= lead:
                if job.sid not in eng._pending:
                    eng.prefetch(job.sid)
                    self.metrics["prefetches"] += 1
        # 3. admission: fill free slots in EDF order; parked slots are
        # preempted (offloaded) when the queue is hungry and the grid
        # is full
        while self._ready:
            if not eng._free_slots() and not self._preempt_parked():
                break
            _, _, _, job = heapq.heappop(self._ready)
            self._admit(job)
        # 4. decode or idle tick
        decoding = int((eng.live & eng.active).sum())
        if decoding:
            eng.step()
            self.metrics["decode_steps"] += 1
        else:
            if eng.step_time:
                eng.store.runtime.advance(eng.step_time)
            self.metrics["idle_ticks"] += 1
        if self.pending_work():
            idle_slots = eng.max_slots - decoding
            self.metrics["slot_idle_steps"] += idle_slots
            self.metrics["parked_slot_steps"] += int(
                (eng.live & ~eng.active).sum())
            if idle_slots and eng.step_time:
                self.ledger.add("scheduler_idle",
                                eng.step_time * idle_slots)
        self.metrics["ticks"] += 1
        self.now += 1
        # 5. turn boundaries: pause-on-idle / park / retire
        if decoding:
            self._turn_boundaries()

    def _paused_jobs(self):
        # sid-sorted for deterministic prefetch issue order
        return sorted((j for j in self.jobs.values()
                       if j.state == "paused"), key=lambda j: j.sid)

    def _preempt_parked(self) -> bool:
        """Offload the parked session whose next turn is furthest away;
        True when a slot was freed for the admission queue."""
        parked = [j for j in self.jobs.values() if j.state == "parked"]
        if not parked:
            return False
        victim = max(parked, key=lambda j: (j.due(), j.sid))
        self.engine.pause(victim.sid)
        victim.state = "paused"
        self.metrics["pauses"] += 1
        self.metrics["preempt_pauses"] += 1
        self._bump(victim, "pauses")
        self._trace("preempt_pause", sid=victim.sid, due=victim.due())
        return True

    def _admit(self, job: SessionJob):
        eng = self.engine
        if job.request is None:
            job.request = Request(job.sid, job.prompt,
                                  max_new=job.total())
            eng.admit(job.request)
            self.metrics["admissions"] += 1
            self._bump(job, "admissions")
        else:
            # the engine's stall clock advances inside resume (waiting
            # out the KV fetch); the delta is this session's restore
            # stall — the per-tenant p99 currency
            before = eng.kv_stall_time
            eng.resume(job.sid)
            job.stall += eng.kv_stall_time - before
            self.metrics["resumes"] += 1
            self._bump(job, "resumes")
        job.state = "running"
        job.admitted_step = self.now
        if self.now > job.deadline():
            self.metrics["deadline_misses"] += 1
            self._bump(job, "deadline_misses")
            self._trace("deadline_miss", sid=job.sid,
                        deadline=job.deadline())

    def _turn_boundaries(self):
        eng = self.engine
        for job in sorted(self.jobs.values(), key=lambda j: j.sid):
            if job.state != "running":
                continue
            req = job.request
            if req.done:
                job.state = "done"
                continue
            if len(req.generated) < job.target():
                continue
            # intermediate turn boundary: park short gaps, offload long
            job.turn_idx += 1
            gap = job.due() - self.now
            if 0 < gap <= self.pause_idle_steps:
                eng.park(job.sid)
                job.state = "parked"
                self.metrics["parks"] += 1
                self._bump(job, "parks")
                self._push_waiting(job)
            elif gap <= 0:
                # next turn already due: keep decoding in place
                pass
            else:
                eng.pause(job.sid)
                job.state = "paused"
                self.metrics["pauses"] += 1
                self._bump(job, "pauses")
                self._push_waiting(job)

    # ---------------------------------------------------------------- run
    def run(self, jobs: Optional[List[SessionJob]] = None, *,
            max_ticks: int = 100_000) -> Dict[str, float]:
        if jobs:
            self.submit_all(jobs)
        while self.pending_work() and self.metrics["ticks"] < max_ticks:
            self.tick()
        return self.report()

    def report(self) -> Dict[str, float]:
        eng = self.engine
        m = dict(self.metrics)
        tokens = sum(len(j.request.generated)
                     for j in self.jobs.values() if j.request is not None)
        m["tokens"] = tokens
        m["kv_stall"] = eng.kv_stall_time
        m["makespan"] = m["ticks"] * eng.step_time
        m["tokens_per_sec"] = (tokens / m["makespan"]
                               if m["makespan"] > 0 else 0.0)
        idle_cost = eng.step_time * m["slot_idle_steps"]
        m["per_token_stall"] = ((eng.kv_stall_time + idle_cost)
                                / max(tokens, 1))
        m["stall_ledger"] = self.stall_ledger()
        tenants = self.tenant_report()
        if tenants:
            for name, cell in tenants.items():
                tled = self._tenant_ledger(name)
                cell["ledger_stall"] = sum(tled.values())
                budget = self.stall_budgets.get(name)
                if budget:
                    # burn rate of the declared SLO budget: ledger
                    # seconds spent / (budget sec-per-token * tokens);
                    # > 1.0 means the tenant's stall budget is blown
                    cell["budget_burn"] = (
                        cell["ledger_stall"]
                        / (budget * max(cell["tokens"], 1)))
            m["tenants"] = tenants
        return m

    # ------------------------------------------------------- stall ledger
    def stall_ledger(self) -> Dict[str, float]:
        """Eq. 1 decomposition of this run's stalled seconds (delta
        since construction, so a shared fleet ledger reports only this
        scheduler's slice). Conservation law, enforced by tests:
        `total == kv_stall + step_time * slot_idle_steps` to 1e-9."""
        led = self.ledger.delta_since(self._ledger_base)
        led["total"] = sum(led[c] for c in COMPONENTS)
        return led

    def _tenant_ledger(self, tenant: str) -> Dict[str, float]:
        cur = self.ledger.tenants.get(tenant, {})
        base = self._ledger_tenant_base.get(tenant, {})
        return {c: cur.get(c, 0.0) - base.get(c, 0.0)
                for c in COMPONENTS}

    def tenant_report(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant SLO accounting over tagged jobs: token/stall
        sums, mean and p99 per-token restore stall (p99 across the
        tenant's sessions — each session's sample is its own
        stall/tokens), plus the event counters. Slot-idle rent is a
        fleet-level cost and stays out of the per-tenant stall."""
        out: Dict[str, Dict[str, float]] = {}
        samples: Dict[str, List[float]] = {}
        for job in sorted(self.jobs.values(), key=lambda j: j.sid):
            if not job.tenant:
                continue
            d = out.setdefault(job.tenant, {
                "sessions": 0, "tokens": 0, "stall": 0.0})
            tokens = (len(job.request.generated)
                      if job.request is not None else 0)
            d["sessions"] += 1
            d["tokens"] += tokens
            d["stall"] += job.stall
            samples.setdefault(job.tenant, []).append(
                job.stall / max(tokens, 1))
        for name, d in out.items():
            d["per_token_stall"] = d["stall"] / max(d["tokens"], 1)
            d["p99_per_token_stall"] = float(
                np.percentile(np.array(samples[name]), 99))
            # uniform cells: a tenant that never hit an event path (or
            # was never admitted at all) still reports zeroed counters,
            # so downstream JSON diffs compare keys, not key *sets*
            for k in ("admissions", "resumes", "unparks", "parks",
                      "pauses", "deadline_misses"):
                d[k] = self.tenant_metrics.get(name, {}).get(k, 0)
        return {k: out[k] for k in sorted(out)}


def run_lockstep(engine: DecodeEngine, jobs: List[SessionJob], *,
                 max_ticks: int = 100_000) -> Dict[str, float]:
    """All-or-nothing gang reference (the seed `run()` discipline, made
    turn-aware): admit a gang of due turns, decode until *every* gang
    member's turn completes (finished slots sit empty — no mid-gang
    admission), pause members with later turns, repeat. Idle-slot and
    stall accounting use the same definitions as the continuous
    scheduler, so the two reports are directly comparable."""
    jobs = list(jobs)
    for job in jobs:
        job.state = "waiting"
    now = 0
    metrics = {
        "ticks": 0, "decode_steps": 0, "idle_ticks": 0,
        "slot_idle_steps": 0, "parked_slot_steps": 0,
        "admissions": 0, "resumes": 0, "unparks": 0, "pauses": 0,
        "parks": 0, "preempt_pauses": 0, "prefetches": 0,
        "deadline_misses": 0,
    }

    def pending_work():
        return any(j.state != "done" for j in jobs)

    def tick_idle():
        nonlocal now
        if engine.step_time:
            engine.store.runtime.advance(engine.step_time)
        metrics["idle_ticks"] += 1
        metrics["ticks"] += 1
        if pending_work():
            metrics["slot_idle_steps"] += engine.max_slots
        now += 1

    while pending_work() and metrics["ticks"] < max_ticks:
        ready = sorted((j for j in jobs
                        if j.state in ("waiting", "paused")
                        and j.due() <= now),
                       key=lambda j: (j.deadline(), j.due(), j.sid))
        if not ready:
            tick_idle()
            continue
        gang: List[SessionJob] = []
        for job in ready:
            if not engine._free_slots():
                break
            if job.request is None:
                job.request = Request(job.sid, job.prompt,
                                      max_new=job.total())
                engine.admit(job.request)
                metrics["admissions"] += 1
            else:
                before = engine.kv_stall_time
                engine.resume(job.sid)
                job.stall += engine.kv_stall_time - before
                metrics["resumes"] += 1
            if now > job.deadline():
                metrics["deadline_misses"] += 1
            job.state = "running"
            job.admitted_step = now
            gang.append(job)
        # decode until the whole gang's turns complete — the lock-step
        # waste this module exists to remove
        while any(j.state == "running" for j in gang):
            decoding = int((engine.live & engine.active).sum())
            engine.step()
            metrics["decode_steps"] += 1
            metrics["ticks"] += 1
            metrics["slot_idle_steps"] += engine.max_slots - decoding
            now += 1
            for job in gang:
                if job.state != "running":
                    continue
                if job.request.done:
                    job.state = "done"
                elif len(job.request.generated) >= job.target():
                    job.turn_idx += 1
                    if job.due() <= now:
                        continue    # next turn already due: keep going
                    engine.pause(job.sid)
                    job.state = "paused"
                    metrics["pauses"] += 1

    tokens = sum(len(j.request.generated) for j in jobs
                 if j.request is not None)
    m = dict(metrics)
    m["tokens"] = tokens
    m["kv_stall"] = engine.kv_stall_time
    m["makespan"] = m["ticks"] * engine.step_time
    m["tokens_per_sec"] = (tokens / m["makespan"]
                           if m["makespan"] > 0 else 0.0)
    idle_cost = engine.step_time * m["slot_idle_steps"]
    m["per_token_stall"] = ((engine.kv_stall_time + idle_cost)
                            / max(tokens, 1))
    return m


def jobs_from_trace(scenario: str, *, n_jobs: int = 8,
                    n_turns: int = 3, tokens_per_turn: int = 6,
                    prompt_len: int = 5, vocab: int = 64,
                    horizon: int = 96, seed: int = 0
                    ) -> List[SessionJob]:
    """Deterministic multi-turn job set for an autopilot trace scenario,
    rendered through the `WorkloadDecl` compiler: the scenario name maps
    to a declared arrival process (zipf -> stationary, scan_flood ->
    periodic bursts, diurnal -> the day curve, multi_tenant -> a steady
    + a bursty tenant), so the continuous-vs-lockstep race runs on the
    same declared shapes the economics benches and the tenant-isolation
    bench use."""
    from ..autopilot.traces import SCENARIOS
    from ..platform.spec import (ArrivalDecl, SessionShapeDecl, SloDecl,
                                 TenantDecl, WorkloadDecl)
    from ..platform.workload import compile_workload
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; one of "
                         f"{SCENARIOS}")
    # heterogeneous turn lengths (tokens_per_turn//2 .. 2x) and wide
    # jittered gaps: long and short turns sharing a gang is exactly
    # where lock-step scheduling leaks slot-time
    shape = SessionShapeDecl(n_turns=n_turns,
                             tokens_per_turn=tokens_per_turn,
                             prompt_len=prompt_len,
                             gap_steps=max(1, horizon // (n_turns + 1)),
                             gap_jitter=0.9)
    slo = SloDecl(deadline_steps=4)
    if scenario == "multi_tenant":
        n_b = n_jobs // 2
        tenants = (
            TenantDecl(name="tenant_a", n_sessions=n_jobs - n_b,
                       session=shape,
                       arrival=ArrivalDecl(kind="stationary"), slo=slo),
            TenantDecl(name="tenant_b", n_sessions=n_b, session=shape,
                       arrival=ArrivalDecl(kind="scan_flood", period=30,
                                           burst_len=6), slo=slo))
    else:
        arrival = {
            "zipf": ArrivalDecl(kind="stationary"),
            "scan_flood": ArrivalDecl(kind="scan_flood", period=40,
                                      burst_len=8),
            "diurnal": ArrivalDecl(kind="diurnal", period=horizon),
        }[scenario]
        tenants = (TenantDecl(name="kv", n_sessions=n_jobs,
                              session=shape, arrival=arrival, slo=slo),)
    decl = WorkloadDecl(tenants=tenants, horizon_steps=horizon,
                        seed=seed * 7919 + SCENARIOS.index(scenario))
    return compile_workload(decl).jobs(vocab=vocab)


def compare_scheduling(engine_factory, jobs_factory, *,
                       pause_idle_steps: int = 4,
                       prefetch_lead="p99",
                       max_ticks: int = 100_000) -> Dict[str, object]:
    """Race continuous batching against the lock-step gang on identical
    jobs and fresh engines. Greedy decode means both arms must emit
    byte-identical tokens per session — asserted here, not assumed —
    so the race is purely about scheduling: modeled tokens/sec and
    per-token stall (restore stalls + idle-slot rent)."""
    cont_engine = engine_factory()
    sched = ContinuousScheduler(cont_engine,
                                pause_idle_steps=pause_idle_steps,
                                prefetch_lead=prefetch_lead)
    cont_jobs = jobs_factory()
    cont = sched.run(cont_jobs, max_ticks=max_ticks)

    lock_engine = engine_factory()
    lock_jobs = jobs_factory()
    lock = run_lockstep(lock_engine, lock_jobs, max_ticks=max_ticks)

    tokens_by_sid = {}
    for j in cont_jobs:
        tokens_by_sid[j.sid] = list(j.request.generated)
    mismatches = [j.sid for j in lock_jobs
                  if list(j.request.generated) != tokens_by_sid[j.sid]]
    return {
        "continuous": cont,
        "lockstep": lock,
        "tokens_identical": not mismatches,
        "token_mismatches": mismatches,
        "throughput_ratio": (cont["tokens_per_sec"]
                             / max(lock["tokens_per_sec"], 1e-12)),
        "stall_ratio": (cont["per_token_stall"]
                        / max(lock["per_token_stall"], 1e-12)),
        "continuous_wins": (
            cont["tokens_per_sec"] >= lock["tokens_per_sec"] - 1e-9
            and cont["per_token_stall"] <= lock["per_token_stall"] + 1e-9),
    }
