from .engine import DecodeEngine, Request  # noqa
