from .engine import DecodeEngine, Request  # noqa
from .scheduler import (  # noqa
    ContinuousScheduler, SessionJob, Turn, compare_scheduling,
    jobs_from_trace, run_lockstep)
from .tenants import run_tenant_bench, tenant_pack  # noqa
