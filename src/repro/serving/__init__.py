from .engine import DecodeEngine, Request  # noqa
from .scheduler import (  # noqa
    ContinuousScheduler, SessionJob, Turn, compare_scheduling,
    jobs_from_trace, run_lockstep)
