"""Fourth-tier serving bench: two new Eq. 1 columns, priced end to end.

PR 10's headline claim: the two new tier shapes each earn their Eq. 1
column on the workload shape that motivates them.

  * ``gpu_flash`` (BaM-style GPU-direct flash) drops the host-CPU term
    from the flash column — the accelerator's submission engine drives
    the device queue at deep queue depth, so a flash resume costs
    `alpha_submit/iops_submit` per IO instead of `alpha_core/iops_core`
    and services at the IOPS ladder's saturated rung. It should win on
    MoE-heavy / scan shapes whose paused KV is *economically cold*
    (reuse beyond every DRAM band): those resumes pay the flash path no
    matter what, so cheapening the path is the whole game.
  * The fleet-shared far-memory **pool** rents DRAM-class residency at
    `rent_factor` of the local rate (uncorrelated per-host peaks
    multiplex onto one shared slab). It should win on staggered-peak /
    diurnal multi-tenant shapes whose think gaps land *inside the pool
    band* `[tau_be, tau_pool)`: too cold for full-rate local DRAM, too
    hot to re-read from flash.

`run_tiers_bench` replays each scenario pack through four arms of the
same declared platform — ``baseline`` (3-tier), ``+gpu_flash``,
``+pool``, ``both`` — and prices each run with the fleet-shared rates
(`autopilot.bench.pricing_rates`): DRAM rent on provisioned capacity,
wire + page + per-IO path costs off the runtime's own lane counters,
pool rent on the pool's measured byte-seconds at its discounted rate,
and stalled-accelerator rent (`alpha_accel`) on the scheduler's
per-token stall. An arm *wins* iff its modeled $/token is strictly
below baseline at equal-or-lower per-token stall. The baseline
platform's `ProvisionAdvisor.advise_tiers` four-arm comparison is run
on the same observed reuse stream and its recommendation is checked
against the measured winners.

The JSON is deterministic (virtual clock, seeded draws, greedy decode):
CI runs `benchmarks/serving_tiers.py --smoke` twice and diffs bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..core.policy import Tier
from ..platform.spec import (ArrivalDecl, HierarchySpec, HostDecl,
                             PolicyDecl, PoolDecl, SchedulerDecl,
                             SessionShapeDecl, SloDecl, TenantDecl,
                             TierDecl, WorkloadDecl, gpu_flash_tier)
from .tenants import KV_BLOB_BYTES, STEP_TIME

__all__ = ["moe_scan_pack", "diurnal_pack", "scenario_packs",
           "default_pool_decl", "run_tiers_bench"]

# stalled-accelerator rent multiplier (Eq. 1 alpha_stall as a price) —
# matches the admission/autoscale benches so $/token stays comparable
ALPHA_ACCEL = 4.0
# accelerator submission-engine $/IO — economics-column defaults
ALPHA_SUBMIT = 0.5
IOPS_SUBMIT = 2e7

ARM_ORDER = ("baseline", "gpu_flash", "pool", "both")

# the packs' host flash is QLC-class (slow reads, long setup): the
# baseline arm's resumes visibly pay this queue, while the gpu_flash
# tier keeps its default BaM geometry (fast NAND behind the
# accelerator-submission queue) and the pool is CXL-class DRAM — so
# the per-token stall deltas between arms are physical, not epsilon
_SLOW_FLASH = TierDecl(capacity_bytes=float(4 << 30), read_bw=2e9,
                       read_latency=2e-4)


# ------------------------------------------------------- scenario packs
def moe_scan_pack(*, moe_sessions: int = 4, scan_sessions: int = 8,
                  dram_blobs: int = 6, horizon_steps: int = 96,
                  seed: int = 0) -> HierarchySpec:
    """MoE-heavy decodes + a cold-scan tenant: the gpu_flash shape.

    The scan tenant's think gaps (10 s) sit beyond every DRAM band —
    local (tau_be ~ 2.3 s at this geometry) *and* pooled (tau_pool
    ~ 8.3 s) — so its paused KV is priced to flash in every arm and the
    only lever left is the flash path itself. The MoE tenant supplies
    long decodes (tokens) and enough DRAM pressure that the small host
    DRAM stays contested."""
    moe = TenantDecl(
        name="moe", n_sessions=moe_sessions,
        session=SessionShapeDecl.moe_heavy(gap_steps=4),
        arrival=ArrivalDecl(kind="stationary"),
        slo=SloDecl(deadline_steps=12))
    scan = TenantDecl(
        name="scan", n_sessions=scan_sessions,
        # 40 steps * 0.25 s = 10 s think gaps: beyond tau_pool, so the
        # pool arm cannot claim these blobs — only the path can change
        session=SessionShapeDecl.scan(gap_steps=40, n_turns=3),
        arrival=ArrivalDecl(kind="flash_crowd", peak_step=6,
                            burst_len=4, baseline=0.01),
        slo=SloDecl(deadline_steps=48))
    workload = WorkloadDecl(tenants=(moe, scan),
                            horizon_steps=horizon_steps, seed=seed,
                            isolation="per-tenant")
    dram = TierDecl(capacity_bytes=float(dram_blobs * KV_BLOB_BYTES),
                    read_bw=45e9, read_latency=5e-7)
    return HierarchySpec(
        hosts=(HostDecl(tiers={"dram": dram, "flash": _SLOW_FLASH}),),
        policy=PolicyDecl.economic(l_blk=KV_BLOB_BYTES),
        step_time=STEP_TIME,
        scheduler=SchedulerDecl(pause_idle_steps=0, prefetch_lead=0),
        workload=workload)


def diurnal_pack(*, day_sessions: int = 5, night_sessions: int = 5,
                 dram_blobs: int = 5, horizon_steps: int = 96,
                 seed: int = 0) -> HierarchySpec:
    """Staggered-peak multi-tenant chat: the pool shape.

    Two tenant populations peak at opposite ends of the horizon
    (diurnal offset), with think gaps of 4 s and 6 s — inside the pool
    band `[tau_be ~ 2.3 s, tau_pool ~ 8.3 s)` at the default pool
    geometry. Their paused KV is too cold for full-rate local DRAM
    (baseline prices it to flash and the resumes stall) but hot enough
    that discounted pooled residency beats a flash re-read. The
    staggered peaks are the multiplexing argument made flesh: one
    pool slab absorbs both tenants' paused sets because they never
    peak together."""
    day = TenantDecl(
        name="day", n_sessions=day_sessions,
        session=SessionShapeDecl.chat(n_turns=3, gap_steps=16),
        arrival=ArrivalDecl(kind="flash_crowd", peak_step=4,
                            burst_len=6, baseline=0.01),
        slo=SloDecl(deadline_steps=24))
    night = TenantDecl(
        name="night", n_sessions=night_sessions,
        session=SessionShapeDecl.chat(n_turns=3, gap_steps=24),
        arrival=ArrivalDecl(kind="flash_crowd", peak_step=40,
                            burst_len=6, baseline=0.01),
        slo=SloDecl(deadline_steps=32))
    workload = WorkloadDecl(tenants=(day, night),
                            horizon_steps=horizon_steps, seed=seed,
                            isolation="per-tenant")
    dram = TierDecl(capacity_bytes=float(dram_blobs * KV_BLOB_BYTES),
                    read_bw=45e9, read_latency=5e-7)
    return HierarchySpec(
        hosts=(HostDecl(tiers={"dram": dram, "flash": _SLOW_FLASH}),),
        policy=PolicyDecl.economic(l_blk=KV_BLOB_BYTES),
        step_time=STEP_TIME,
        scheduler=SchedulerDecl(pause_idle_steps=0, prefetch_lead=0),
        workload=workload)


def default_pool_decl(*, blobs: int = 64) -> PoolDecl:
    """CXL-class pool geometry sized in KV-blob units; rent_factor 0.25
    keeps the band `[tau_be, tau_pool)` wide (~2.3 s .. ~8.3 s at the
    gpu profile and this l_blk)."""
    return PoolDecl(capacity_bytes=float(blobs * KV_BLOB_BYTES),
                    read_bw=40e9, rtt=2e-6, rent_factor=0.25)


def scenario_packs(*, smoke: bool = False) -> Dict[str, HierarchySpec]:
    """The benchmark's scenario set (pinned small variants for CI)."""
    if smoke:
        return {
            "moe_scan": moe_scan_pack(moe_sessions=2, scan_sessions=4,
                                      dram_blobs=4, horizon_steps=64),
            "diurnal": diurnal_pack(day_sessions=3, night_sessions=3,
                                    dram_blobs=3, horizon_steps=64),
        }
    return {"moe_scan": moe_scan_pack(), "diurnal": diurnal_pack()}


# ---------------------------------------------------------------- arms
def _with_gpu_flash(spec: HierarchySpec) -> HierarchySpec:
    hosts = tuple(
        dataclasses.replace(h, tiers={**h.tiers,
                                      "gpu_flash": gpu_flash_tier()})
        for h in spec.hosts)
    return dataclasses.replace(spec, hosts=hosts)


def _with_pool(spec: HierarchySpec, pool: PoolDecl) -> HierarchySpec:
    return dataclasses.replace(spec, pool=pool)


def _arms(spec: HierarchySpec,
          pool: PoolDecl) -> Dict[str, HierarchySpec]:
    return {
        "baseline": spec,
        "gpu_flash": _with_gpu_flash(spec),
        "pool": _with_pool(spec, pool),
        "both": _with_pool(_with_gpu_flash(spec), pool),
    }


# ---------------------------------------------------------- cost model
def _modeled_cost(platform, report: Dict[str, object]) -> Dict[str, float]:
    """Post-run $/token from the runtime's own counters.

    Normalized units (NAND die == 1, capital == rent), shared with the
    admission/autoscale benches via `pricing_rates`. Components:

      * dram_rent  — provisioned DRAM (+ HBM at 4x) capacity for the
        makespan; identical across arms with the same local tiers, so
        arm deltas come from the paths below.
      * flash_io   — host-flash lane: host CPU per IO + DRAM wire +
        page cost on bytes moved (the classic Eq. 1 column's numerator
        priced per event).
      * gpu_direct — gpu_flash lane: submission-engine per IO + page
        cost only; no host CPU, no host-DRAM wire (the BaM column).
      * dram_wire  — DRAM/HBM lane bytes at the wire rate.
      * pool       — fabric wire + per-IO RTT at `alpha_net`, plus the
        pool's measured byte-seconds rented at `rent_factor` of the
        local DRAM rate.
      * stall      — scheduler stall seconds priced at `ALPHA_ACCEL`
        (the stalled accelerator rents its capital while idle).

    NIC lanes between hosts are unpriced (single-host packs; replica
    traffic is identical across arms)."""
    from ..autopilot.bench import PAGE_BYTES, pricing_rates
    spec = platform.spec
    host_cfg, ssd = spec.policy.economics()
    rates = pricing_rates(host_cfg, ssd)
    page_rate = rates["page_io_cost"] / float(PAGE_BYTES)
    submit_cost = ALPHA_SUBMIT / IOPS_SUBMIT

    makespan = float(report["makespan"])
    tokens = max(int(report["tokens"]), 1)

    dram_rent = 0.0
    flash_io = 0.0
    gpu_direct = 0.0
    dram_wire = 0.0
    accesses = 0
    for store in platform.fabric.hosts.values():
        cap = {t: s.capacity_bytes for t, s in store.specs.items()}
        dram_rent += (cap.get(Tier.DRAM, 0.0)
                      + 4.0 * cap.get(Tier.HBM, 0.0)
                      ) * makespan * rates["rent_rate"]
        for lane, st in store.runtime.qstats.items():
            if lane == Tier.FLASH:
                flash_io += (st.submitted * rates["host_io_cost"]
                             + st.bytes_moved * (rates["dram_wire_rate"]
                                                 + page_rate))
            elif lane == Tier.GPU_FLASH:
                gpu_direct += (st.submitted * submit_cost
                               + st.bytes_moved * page_rate)
            elif lane in (Tier.DRAM, Tier.HBM):
                dram_wire += st.bytes_moved * rates["dram_wire_rate"]
        accesses += sum(s.hits for s in store.stats.values())

    pool_cost = 0.0
    pool = platform.fabric.pool
    if pool is not None:
        alpha_net = spec.pool.alpha_net
        for st in pool.runtime.qstats.values():
            pool_cost += (st.submitted * alpha_net * spec.pool.rtt
                          + st.bytes_moved * alpha_net / spec.pool.read_bw)
        pool_cost += (pool.byte_seconds() * rates["rent_rate"]
                      * spec.pool.rent_factor)
        accesses += pool.stats.gets

    stall_seconds = float(report["per_token_stall"]) * tokens
    stall = stall_seconds * ALPHA_ACCEL
    total = (dram_rent + flash_io + gpu_direct + dram_wire + pool_cost
             + stall)
    return {
        "dram_rent": dram_rent,
        "flash_io": flash_io,
        "gpu_direct": gpu_direct,
        "dram_wire": dram_wire,
        "pool": pool_cost,
        "stall": stall,
        "stall_seconds": stall_seconds,
        "total": total,
        "tokens": float(tokens),
        "accesses": float(accesses),
        "per_token": total / tokens,
        "per_token_stall": float(report["per_token_stall"]),
    }


# --------------------------------------------------------------- runner
def _run_arm(spec: HierarchySpec, cfg, params, rules, *,
             max_slots: int, max_len: int):
    from ..platform.compiler import Platform
    platform = Platform.compile(spec)
    sched = platform.scheduler(cfg, params, rules, max_slots=max_slots,
                               max_len=max_len)
    report = sched.run(platform.jobs(vocab=cfg.vocab))
    gate = platform.policy(0)
    costs = _modeled_cost(platform, report)
    out: Dict[str, object] = {
        "report": report,
        "costs": costs,
        "tau_be": float(getattr(gate, "tau_be", 0.0)),
    }
    tau_pool = getattr(gate, "tau_pool", None)
    if tau_pool is not None:
        out["tau_pool"] = float(tau_pool)
    gs = getattr(gate, "gate_stats", None)
    if gs is not None:
        out["gate"] = {k: int(v) for k, v in
                       dataclasses.asdict(gs).items()}
    if platform.fabric.pool is not None:
        out["pool_stats"] = platform.fabric.pool.snapshot_stats()
    return out, platform


def run_tiers_bench(packs: Optional[Dict[str, HierarchySpec]] = None, *,
                    pool: Optional[PoolDecl] = None, smoke: bool = False,
                    max_slots: int = 4, max_len: int = 64
                    ) -> Dict[str, object]:
    """Replay each scenario pack through the four arms and judge them.

    Returns a deterministic, JSON-serializable dict: per-scenario,
    per-arm scheduler reports, modeled cost breakdowns, gate/pool
    stats; per-scenario win verdicts (strictly cheaper $/token at
    equal-or-lower per-token stall than baseline) and the baseline
    advisor's `advise_tiers` recommendation with an agreement flag."""
    import jax
    from ..configs import get_config
    from ..models import model as M
    from ..parallel.sharding import single_device_rules

    packs = scenario_packs(smoke=smoke) if packs is None else packs
    pool = default_pool_decl() if pool is None else pool
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)

    out: Dict[str, object] = {
        "pool_decl": {"capacity_bytes": pool.capacity_bytes,
                      "read_bw": pool.read_bw, "rtt": pool.rtt,
                      "rent_factor": pool.rent_factor,
                      "alpha_net": pool.alpha_net},
        "alpha_accel": ALPHA_ACCEL,
    }
    for scen, spec in packs.items():
        spec.validate()
        cell: Dict[str, object] = {
            "horizon_steps": spec.workload.horizon_steps,
            "workload_seed": spec.workload.seed,
            "dram_bytes": spec.hosts[0].dram_capacity(),
        }
        baseline_platform = None
        for arm, arm_spec in _arms(spec, pool).items():
            cell[arm], platform = _run_arm(
                arm_spec, cfg, params, rules,
                max_slots=max_slots, max_len=max_len)
            if arm == "baseline":
                baseline_platform = platform

        base = cell["baseline"]["costs"]

        def _wins(arm_costs: Dict[str, float]) -> bool:
            return bool(
                arm_costs["per_token"] < base["per_token"] - 1e-15
                and (arm_costs["per_token_stall"]
                     <= base["per_token_stall"] + 1e-12))

        verdicts = {arm: _wins(cell[arm]["costs"])
                    for arm in ARM_ORDER if arm != "baseline"}
        cell["wins"] = verdicts

        # the advisor's four-arm comparison on the observed reuse
        # stream (baseline platform: its tracker saw the un-pooled run)
        accesses = base["accesses"]
        makespan = float(cell["baseline"]["report"]["makespan"])
        rate = accesses / makespan if makespan > 0 else 1.0
        advice = baseline_platform.advise_tiers(
            access_rate=max(rate, 1e-9), object_bytes=KV_BLOB_BYTES,
            pool_bw=pool.read_bw, pool_rtt=pool.rtt,
            rent_factor=pool.rent_factor, alpha_net=pool.alpha_net,
            alpha_stall=ALPHA_ACCEL)
        winners = sorted(a for a, w in verdicts.items() if w)
        agreement = (advice.recommended_arm in winners if winners
                     else advice.recommended_arm == "baseline")
        cell["advice"] = advice.as_dict()
        cell["advice_agreement"] = bool(agreement)
        out[scen] = cell

    out["gpu_flash_wins_somewhere"] = bool(any(
        out[s]["wins"]["gpu_flash"] for s in packs))
    out["pool_wins_somewhere"] = bool(any(
        out[s]["wins"]["pool"] for s in packs))
    return out
