"""Control-plane scale replay: 1M keys / 100k sessions per fleet step.

"Tearing Down the Memory Wall" (PAPERS.md) argues the host *control
plane*, not the flash media, is what caps AI-era hierarchies at high
IOPS — the paper's seconds-scale break-even only matters if routing,
reuse tracking and admission can keep up with millions of fine-grained
residency decisions. This module measures exactly that on this repo's
control plane, post-vectorization:

  * routing: `ShardedTieredStore.owner_batch` (one `searchsorted` over
    the ring arrays; key digests hashed once and reused every step),
  * reuse tracking: `ReuseTracker.observe_batch` over the array-backed
    ghost + one decayed-sketch update per step,
  * admission + capacity: a vectorized break-even gate (measured
    interval vs `tau_be`, class-quantile prior for first touches) and
    an array LRU over the DRAM tier,
  * stall pricing: the step's queued flash misses priced through
    `SsdQueueModel.service_total_batch` (a precomputed cumulative
    depth ladder — no per-fetch model calls).

Wall-clock control-plane cost is timed per section and returned in a
*separate* record from the modeled results: the modeled record (stall,
hit rates, op counters) is deterministic for a seed and byte-stable
across runs — that is what `benchmarks/serving_scale.py` JSON-diffs in
CI — while the timings depend on the machine and go to stderr.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..autopilot.reuse import ReuseTracker
from ..runtime.clock import VirtualClock
from ..runtime.fabric import ShardedTieredStore
from ..runtime.service import SsdQueueModel


def generate_scale_trace(*, n_keys: int, n_sessions: int, n_steps: int,
                         accesses_per_step: int, turns_per_session: int,
                         zipf_alpha: float = 3.0,
                         seed: int = 0) -> List[np.ndarray]:
    """Seeded per-step access-id arrays over a keyspace of `n_keys`.

    Ids [0, n_sessions) are session KV keys: each session takes
    `turns_per_session` turns at seeded steps, so its key re-appears at
    measurable reuse intervals. Ids [n_sessions, n_keys) are one-shot
    objects drawn with power-law popularity (`zipf_alpha` concentrates
    mass on the low ids) — the scan-flood-ish background the gate must
    keep out of DRAM. Everything is drawn up front from one rng, so the
    trace is a pure function of the arguments."""
    if n_sessions >= n_keys:
        raise ValueError("need n_keys > n_sessions")
    rng = np.random.default_rng(seed)
    # session turns: uniform start, uniform later turns — bucket by step
    turn_steps = rng.integers(0, n_steps,
                              size=(n_sessions, turns_per_session))
    sess_ids_by_step: List[List[int]] = [[] for _ in range(n_steps)]
    flat_steps = turn_steps.ravel()
    flat_sids = np.repeat(np.arange(n_sessions), turns_per_session)
    order = np.argsort(flat_steps, kind="stable")
    bounds = np.searchsorted(flat_steps[order],
                             np.arange(n_steps + 1))
    steps = []
    n_obj = n_keys - n_sessions
    for t in range(n_steps):
        sess = flat_sids[order[bounds[t]:bounds[t + 1]]]
        u = rng.random(accesses_per_step)
        obj = n_sessions + np.minimum(
            (n_obj * np.power(u, zipf_alpha)).astype(np.int64),
            n_obj - 1)
        steps.append(np.concatenate([sess.astype(np.int64), obj]))
    return steps


def scale_replay(*, n_keys: int = 1_000_000, n_sessions: int = 100_000,
                 n_steps: int = 120, accesses_per_step: int = 50_000,
                 turns_per_session: int = 3, n_hosts: int = 8,
                 dram_capacity_keys: Optional[int] = None,
                 l_blk: int = 128 << 10, tau_be: float = 5.0,
                 step_time: float = 0.25, zipf_alpha: float = 3.0,
                 seed: int = 0,
                 sim_cfg=None) -> Tuple[Dict[str, float],
                                        Dict[str, float]]:
    """Replay the scale trace through the vectorized control plane.

    Returns `(record, timings)`: `record` is deterministic (modeled
    stall, hit/admission counters, per-section op counts) and safe to
    byte-diff across runs; `timings` is measured wall-clock seconds per
    control-plane section on this machine (reported separately — never
    mixed into the modeled numbers)."""
    if dram_capacity_keys is None:
        dram_capacity_keys = n_keys // 10
    trace = generate_scale_trace(
        n_keys=n_keys, n_sessions=n_sessions, n_steps=n_steps,
        accesses_per_step=accesses_per_step,
        turns_per_session=turns_per_session, zipf_alpha=zipf_alpha,
        seed=seed)

    fabric = ShardedTieredStore(n_hosts, clock=VirtualClock())
    tracker = ReuseTracker(ghost_capacity=n_keys, n_buckets=32,
                           tau0=1e-3, decay=0.995, max_classes=4)
    kv_cid = tracker.class_id("kv")
    obj_cid = tracker.class_id("obj")

    # one-time digest pass: routing for the rest of the replay is pure
    # array math (digests survive ring changes)
    t0 = time.perf_counter()
    digests = fabric.key_digest_batch(np.arange(n_keys))
    t_digest = time.perf_counter() - t0

    # flash stall ladder: cumulative cost of n queued misses in a step
    # (depth ramps 1..d_max as the queue builds, then saturates)
    model = SsdQueueModel.shared(sim_cfg)
    d_max = SsdQueueModel.DEPTHS[-1]
    per_depth = model.service_total_batch(l_blk, np.arange(1, d_max + 1))
    cum_stall = np.concatenate([[0.0], np.cumsum(per_depth)])
    sat_cost = float(per_depth[-1])

    resident = np.zeros(n_keys, bool)       # DRAM residency
    last_access = np.full(n_keys, -1, np.int64)
    owner_counts = np.zeros(n_hosts, np.int64)

    counters = {"accesses": 0, "ring_lookups": 0, "ghost_touches": 0,
                "sketch_updates": 0, "admitted": 0, "evicted": 0,
                "dram_hits": 0, "flash_misses": 0, "first_touches": 0}
    timings = {"digest": t_digest, "routing": 0.0, "tracking": 0.0,
               "admission": 0.0, "stall_pricing": 0.0}
    total_stall = 0.0

    for t, ids in enumerate(trace):
        n = ids.size
        now = (t + 1) * step_time
        counters["accesses"] += n

        w0 = time.perf_counter()
        owners = fabric.owner_batch(digests=digests[ids])
        np.add.at(owner_counts, owners, 1)
        counters["ring_lookups"] += n
        w1 = time.perf_counter()
        cids = np.where(ids < n_sessions, kv_cid, obj_cid).astype(np.int32)
        intervals = tracker.observe_batch(ids.tolist(), cids, now)
        counters["ghost_touches"] += n
        counters["sketch_updates"] += 1
        w2 = time.perf_counter()

        # vectorized break-even admission: measured reuse wins, the
        # class sketch quantile covers first touches (the EconomicGate
        # cascade, array-shaped)
        measured = intervals > 0
        counters["first_touches"] += int(n - measured.sum())
        prior = np.empty(2)
        prior[0] = tracker.class_quantile("kv", 0.5) or np.inf
        prior[1] = tracker.class_quantile("obj", 0.5) or np.inf
        est = np.where(measured, intervals,
                       prior[(ids >= n_sessions).astype(np.int64)])
        hit = resident[ids]
        admit = (~hit) & (est < tau_be)
        resident[ids[admit]] = True
        last_access[ids] = t
        # array LRU: one partition evicts everything over capacity
        over = int(resident.sum()) - dram_capacity_keys
        if over > 0:
            rows = np.flatnonzero(resident)
            victims = rows[np.argpartition(last_access[rows],
                                           over - 1)[:over]]
            resident[victims] = False
            counters["evicted"] += over
        w3 = time.perf_counter()

        # modeled stall: this step's flash misses queue behind each
        # other; price the ramp off the precomputed ladder
        n_miss = int(n - hit.sum())
        stall = float(cum_stall[min(n_miss, d_max)]
                      + max(0, n_miss - d_max) * sat_cost)
        total_stall += stall
        counters["dram_hits"] += int(hit.sum())
        counters["flash_misses"] += n_miss
        counters["admitted"] += int(admit.sum())
        w4 = time.perf_counter()

        timings["routing"] += w1 - w0
        timings["tracking"] += w2 - w1
        timings["admission"] += w3 - w2
        timings["stall_pricing"] += w4 - w3

    accesses = counters["accesses"]
    record = {
        "n_keys": float(n_keys), "n_sessions": float(n_sessions),
        "n_steps": float(n_steps), "n_hosts": float(n_hosts),
        "accesses": float(accesses),
        "dram_capacity_keys": float(dram_capacity_keys),
        "tau_be": float(tau_be), "step_time": float(step_time),
        "hit_rate": counters["dram_hits"] / max(accesses, 1),
        "measured_rate": tracker.measured / max(tracker.observed, 1),
        "total_stall": total_stall,
        "per_access_stall": total_stall / max(accesses, 1),
        "owner_imbalance": float(owner_counts.max()
                                 / max(owner_counts.mean(), 1e-12)),
        "ghost_size": float(len(tracker._last_seen)),
    }
    for k, v in counters.items():
        record[f"ops_{k}"] = float(v)
    timings["total"] = sum(timings.values())
    timings["keys_per_sec"] = accesses / max(
        timings["total"] - timings["digest"], 1e-12)
    return record, timings
