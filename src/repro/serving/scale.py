"""Control-plane scale replay: 1M keys / 100k sessions per fleet step.

"Tearing Down the Memory Wall" (PAPERS.md) argues the host *control
plane*, not the flash media, is what caps AI-era hierarchies at high
IOPS — the paper's seconds-scale break-even only matters if routing,
reuse tracking and admission can keep up with millions of fine-grained
residency decisions. This module measures exactly that on this repo's
control plane, post-vectorization:

  * routing: `ShardedTieredStore.owner_batch` (one `searchsorted` over
    the ring arrays; key digests hashed once and reused every step),
  * reuse tracking: `ReuseTracker.observe_batch` over the array-backed
    ghost + one decayed-sketch update per step,
  * admission + capacity: a vectorized break-even gate (measured
    interval vs `tau_be`, class-quantile prior for first touches) and
    an array LRU over the DRAM tier,
  * stall pricing: the step's queued flash misses priced through
    `SsdQueueModel.service_total_batch` (a precomputed cumulative
    depth ladder — no per-fetch model calls).

Wall-clock control-plane cost is timed per section and returned in a
*separate* record from the modeled results: the modeled record (stall,
hit rates, op counters) is deterministic for a seed and byte-stable
across runs — that is what `benchmarks/serving_scale.py` JSON-diffs in
CI — while the timings depend on the machine and go to stderr.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..autopilot.reuse import ReuseTracker
from ..runtime.clock import VirtualClock
from ..runtime.fabric import ShardedTieredStore
from ..runtime.service import SsdQueueModel


def generate_scale_trace(*, n_keys: int, n_sessions: int, n_steps: int,
                         accesses_per_step: int, turns_per_session: int,
                         zipf_alpha: float = 3.0,
                         seed: int = 0) -> List[np.ndarray]:
    """Seeded per-step access-id arrays over a keyspace of `n_keys`,
    rendered through the `WorkloadDecl` compiler (the same generator
    behind `jobs_from_trace` and the autopilot traces).

    Two declared tenants: "kv" holds `n_sessions` sessions taking
    `turns_per_session` turns each (ids [0, n_sessions) — their keys
    re-appear at measurable reuse intervals), and "obj" is a stationary
    background stream of `accesses_per_step` one-shot objects per step
    drawn with power-law popularity over ids [n_sessions, n_keys) —
    the scan-flood-ish background the gate must keep out of DRAM.
    A pure function of the arguments."""
    if n_sessions >= n_keys:
        raise ValueError("need n_keys > n_sessions")
    from ..platform.spec import (ArrivalDecl, SessionShapeDecl,
                                 TenantDecl, WorkloadDecl)
    from ..platform.workload import compile_workload
    decl = WorkloadDecl(
        tenants=(
            TenantDecl(
                name="kv", n_sessions=n_sessions,
                session=SessionShapeDecl(
                    n_turns=turns_per_session,
                    gap_steps=max(1, n_steps // (turns_per_session + 1)),
                    gap_jitter=0.9),
                arrival=ArrivalDecl(kind="stationary")),
            TenantDecl(
                name="obj", n_sessions=0,
                arrival=ArrivalDecl(
                    kind="stationary",
                    background_per_step=accesses_per_step,
                    background_pool=n_keys - n_sessions,
                    background_zipf=zipf_alpha)),
        ),
        horizon_steps=n_steps, seed=seed)
    steps, _, _ = compile_workload(decl).id_steps()
    return steps


def _prior_or_inf(quantile: Optional[float]) -> float:
    """Class-sketch prior -> admission estimate: None (no evidence)
    means "never reused" for the vectorized gate. An explicit None
    check — `quantile or np.inf` would also send a legitimate 0.0
    prior (maximally hot) to infinity (maximally cold)."""
    return np.inf if quantile is None else float(quantile)


def scale_replay(*, n_keys: int = 1_000_000, n_sessions: int = 100_000,
                 n_steps: int = 120, accesses_per_step: int = 50_000,
                 turns_per_session: int = 3, n_hosts: int = 8,
                 dram_capacity_keys: Optional[int] = None,
                 l_blk: int = 128 << 10, tau_be: float = 5.0,
                 step_time: float = 0.25, zipf_alpha: float = 3.0,
                 seed: int = 0, sim_cfg=None,
                 trace: Optional[List[np.ndarray]] = None,
                 obs=None
                 ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Replay the scale trace through the vectorized control plane.

    Returns `(record, timings)`: `record` is deterministic (modeled
    stall, hit/admission counters, per-section op counts) and safe to
    byte-diff across runs; `timings` is measured wall-clock seconds per
    control-plane section on this machine (reported separately — never
    mixed into the modeled numbers). Pass `trace` (per-step id arrays,
    ids < n_sessions classed "kv", the rest "obj") to replay a custom
    access pattern — e.g. a `CompiledWorkload.id_steps()` rendering —
    instead of the generated one.

    `obs` (a `repro.obs.Observability`) keeps the metrics plane on
    during the replay: per-step batch observes into array-backed
    counters/gauges/histograms (per-host routing labels included) plus
    the step stall booked to the ledger's `flash_service` component.
    The modeled `record` is byte-identical with or without it; the
    metric cost lands in its own `timings["metrics"]` section (CI
    guards the total at <= 1.25x the metrics-off wall time)."""
    if dram_capacity_keys is None:
        dram_capacity_keys = n_keys // 10
    if trace is None:
        trace = generate_scale_trace(
            n_keys=n_keys, n_sessions=n_sessions, n_steps=n_steps,
            accesses_per_step=accesses_per_step,
            turns_per_session=turns_per_session, zipf_alpha=zipf_alpha,
            seed=seed)

    fabric = ShardedTieredStore(n_hosts, clock=VirtualClock())
    tracker = ReuseTracker(ghost_capacity=n_keys, n_buckets=32,
                           tau0=1e-3, decay=0.995, max_classes=4)
    kv_cid = tracker.class_id("kv")
    obj_cid = tracker.class_id("obj")

    # one-time digest pass: routing for the rest of the replay is pure
    # array math (digests survive ring changes)
    t0 = time.perf_counter()
    digests = fabric.key_digest_batch(np.arange(n_keys))
    t_digest = time.perf_counter() - t0

    # flash stall ladder: cumulative cost of n queued misses in a step
    # (depth ramps 1..d_max as the queue builds, then saturates)
    model = SsdQueueModel.shared(sim_cfg)
    d_max = SsdQueueModel.DEPTHS[-1]
    per_depth = model.service_total_batch(l_blk, np.arange(1, d_max + 1))
    cum_stall = np.concatenate([[0.0], np.cumsum(per_depth)])
    sat_cost = float(per_depth[-1])

    resident = np.zeros(n_keys, bool)       # DRAM residency
    last_access = np.full(n_keys, -1, np.int64)
    owner_counts = np.zeros(n_hosts, np.int64)

    counters = {"accesses": 0, "ring_lookups": 0, "ghost_touches": 0,
                "sketch_updates": 0, "admitted": 0, "evicted": 0,
                "dram_hits": 0, "flash_misses": 0, "first_touches": 0}
    timings = {"digest": t_digest, "routing": 0.0, "tracking": 0.0,
               "admission": 0.0, "stall_pricing": 0.0, "metrics": 0.0}
    total_stall = 0.0

    metrics = obs.metrics if obs is not None else None
    ledger = obs.ledger if obs is not None else None
    if metrics is not None:
        m_acc = metrics.counter("scale_accesses")
        m_hits = metrics.counter("scale_dram_hits")
        m_miss = metrics.counter("scale_flash_misses")
        m_routed = metrics.counter("scale_routed")
        m_res = metrics.gauge("scale_dram_resident")
        m_stall = metrics.histogram("scale_step_stall")
        host_labels = [(f"host{h}",) for h in range(n_hosts)]

    for t, ids in enumerate(trace):
        n = ids.size
        now = (t + 1) * step_time
        counters["accesses"] += n

        w0 = time.perf_counter()
        owners = fabric.owner_batch(digests=digests[ids])
        np.add.at(owner_counts, owners, 1)
        counters["ring_lookups"] += n
        w1 = time.perf_counter()
        cids = np.where(ids < n_sessions, kv_cid, obj_cid).astype(np.int32)
        intervals = tracker.observe_batch(ids.tolist(), cids, now)
        counters["ghost_touches"] += n
        counters["sketch_updates"] += 1
        w2 = time.perf_counter()

        # vectorized break-even admission: measured reuse wins, the
        # class sketch quantile covers first touches (the EconomicGate
        # cascade, array-shaped)
        measured = intervals > 0
        counters["first_touches"] += int(n - measured.sum())
        prior = np.empty(2)
        prior[0] = _prior_or_inf(tracker.class_quantile("kv", 0.5))
        prior[1] = _prior_or_inf(tracker.class_quantile("obj", 0.5))
        est = np.where(measured, intervals,
                       prior[(ids >= n_sessions).astype(np.int64)])
        hit = resident[ids]
        admit = (~hit) & (est < tau_be)
        resident[ids[admit]] = True
        last_access[ids] = t
        # array LRU: one partition evicts everything over capacity
        over = int(resident.sum()) - dram_capacity_keys
        if over > 0:
            rows = np.flatnonzero(resident)
            victims = rows[np.argpartition(last_access[rows],
                                           over - 1)[:over]]
            resident[victims] = False
            counters["evicted"] += over
        w3 = time.perf_counter()

        # modeled stall: this step's flash misses queue behind each
        # other; price the ramp off the precomputed ladder. Misses
        # dedupe per step: the *first* touch of a non-resident key
        # queues the flash fetch, later touches in the same step are
        # served by it (DRAM hits) — one cold key touched 50x in a
        # step is 1 queued miss, not 50
        n_miss = int(np.unique(ids[~hit]).size)
        stall = float(cum_stall[min(n_miss, d_max)]
                      + max(0, n_miss - d_max) * sat_cost)
        total_stall += stall
        counters["dram_hits"] += int(n - n_miss)
        counters["flash_misses"] += n_miss
        counters["admitted"] += int(admit.sum())
        w4 = time.perf_counter()

        if ledger is not None and stall:
            # coarse Eq. 1 attribution for the vectorized path: the
            # whole priced step stall is flash service time
            ledger.add("flash_service", stall)
        if metrics is not None:
            m_acc.inc(v=float(n))
            m_hits.inc(v=float(n - n_miss))
            m_miss.inc(v=float(n_miss))
            m_res.set(v=float(resident.sum()))
            m_stall.observe(stall)
            routed = np.bincount(owners, minlength=n_hosts)
            for h in range(n_hosts):
                m_routed.inc(host_labels[h], float(routed[h]))
        w5 = time.perf_counter()

        timings["routing"] += w1 - w0
        timings["tracking"] += w2 - w1
        timings["admission"] += w3 - w2
        timings["stall_pricing"] += w4 - w3
        timings["metrics"] += w5 - w4

    accesses = counters["accesses"]
    record = {
        "n_keys": float(n_keys), "n_sessions": float(n_sessions),
        "n_steps": float(n_steps), "n_hosts": float(n_hosts),
        "accesses": float(accesses),
        "dram_capacity_keys": float(dram_capacity_keys),
        "tau_be": float(tau_be), "step_time": float(step_time),
        "hit_rate": counters["dram_hits"] / max(accesses, 1),
        "measured_rate": tracker.measured / max(tracker.observed, 1),
        "total_stall": total_stall,
        "per_access_stall": total_stall / max(accesses, 1),
        "owner_imbalance": float(owner_counts.max()
                                 / max(owner_counts.mean(), 1e-12)),
        "ghost_size": float(len(tracker._last_seen)),
    }
    for k, v in counters.items():
        record[f"ops_{k}"] = float(v)
    timings["total"] = sum(timings.values())
    timings["keys_per_sec"] = accesses / max(
        timings["total"] - timings["digest"], 1e-12)
    return record, timings
