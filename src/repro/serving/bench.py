"""Modeled multi-turn session-serving benchmark: sync vs async KV restore.

The paper's LLM-memory workload (§VII-A): sessions pause between turns,
their KV blocks living on flash, and resume later. The seed runtime
fetched KV *synchronously* at resume — every turn began with the full
flash fetch stalling decode. The async runtime overlaps: the next
session's KV restore is issued `lead` decode steps early and streams
behind the current session's compute, so resume blocks only on the
unfinished remainder.

Everything runs on a `VirtualClock` with queueing-aware flash service
times from the calibrated ssdsim model, so the output is a deterministic
*modeled* per-token stall — comparable across modes, independent of host
speed. Run `benchmarks/serving_async.py` for the CLI report.

`multi_host_session_bench` scales the same workload onto the sharded
fabric: sessions pause on one host and resume on another (chosen by a
seeded schedule, optionally Zipf-skewed toward hot sessions), so most
restores cross the NIC transfer tier composed with the owner host's
flash queue. Async mode prefetches the next turn's KV from the host
that will serve it, `lead` decode steps before the current turn ends —
the cross-host stream rides behind decode exactly like the single-host
case. Run `benchmarks/serving_fleet.py` for the host-count x skew sweep.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..core.policy import Tier, TieringPolicy
from ..runtime.clock import VirtualClock
from ..runtime.fabric import ShardedTieredStore
from ..runtime.tiers import TieredStore


def multi_turn_session_bench(mode: str = "async", *,
                             n_sessions: int = 16,
                             rounds: int = 3,
                             kv_bytes: int = 2 << 20,
                             decode_steps: int = 32,
                             step_time: float = 2e-3,
                             lead: int = 8,
                             sim_cfg=None) -> Dict[str, float]:
    """Round-robin multi-turn serving on the virtual clock.

    Each round resumes every session once: restore KV (sync fetch, or a
    prefetch issued `lead` steps before the previous session finishes),
    decode `decode_steps` tokens at `step_time`, pause (KV back to
    flash). Returns modeled totals incl. per-token stall.
    """
    assert mode in ("sync", "async"), mode
    # thresholds pinned so session KV stays on the flash tier: the
    # benchmark measures the restore path, not placement churn
    policy = TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)
    clock = VirtualClock()
    store = TieredStore(policy, clock=clock, sim_cfg=sim_cfg)
    blob = np.zeros(kv_bytes // 4, np.float32)
    keys = [("kv", f"s{i}") for i in range(n_sessions)]
    for k in keys:
        store.put(k, blob, tier=Tier.FLASH)

    total_stall = 0.0
    tokens = 0
    pending = {}
    prefetch_at = max(0, decode_steps - lead)
    for _ in range(rounds):
        for i, key in enumerate(keys):
            # --- restore ------------------------------------------------
            t0 = clock.now()
            pf = pending.pop(key, None)
            if pf is None:
                pf = store.get_async(key)
            pf.wait()
            total_stall += clock.now() - t0
            # --- decode, issuing the next session's prefetch mid-turn ---
            nxt = keys[(i + 1) % n_sessions]
            for s in range(decode_steps):
                if (mode == "async" and s == prefetch_at
                        and nxt not in pending and nxt != key
                        and store.tier_of(nxt) is not None):
                    pending[nxt] = store.get_async(nxt)
                clock.advance(step_time)
            tokens += decode_steps
            # --- pause (write streams in the background) -----------------
            store.put(key, blob, tier=Tier.FLASH)

    flash = store.stats[Tier.FLASH]
    return {
        "mode": mode,
        "tokens": float(tokens),
        "total_stall": total_stall,
        "per_token_stall": total_stall / max(tokens, 1),
        "makespan": clock.now(),
        "prefetch_hits": float(flash.prefetch_hits),
        "prefetch_late": float(flash.prefetch_late),
        "miss_under_miss": float(
            store.runtime.qstats[Tier.FLASH].miss_under_miss),
    }


def compare(**kw) -> Dict[str, Dict[str, float]]:
    """Run both modes on identical workloads; async must stall less."""
    return {"sync": multi_turn_session_bench("sync", **kw),
            "async": multi_turn_session_bench("async", **kw)}


def _pinned_flash_policy(_host: int) -> TieringPolicy:
    # thresholds pinned so session KV stays on the flash tier: the
    # benchmark measures the restore path, not placement churn
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


def multi_host_session_bench(mode: str = "async", *,
                             n_hosts: int = 4,
                             n_sessions: int = 16,
                             rounds: int = 2,
                             kv_bytes: int = 1 << 20,
                             decode_steps: int = 16,
                             step_time: float = 2e-3,
                             lead: int = 8,
                             skew: float = 0.0,
                             seed: int = 0,
                             sim_cfg=None, net_model=None,
                             write_shield_depth=None) -> Dict[str, float]:
    """Fleet serving on the sharded fabric's shared virtual clock.

    Each turn resumes one session on one host: restore its KV through
    the fabric (a cross-host NIC + remote-flash composition whenever the
    serving host is not the shard owner), decode `decode_steps` tokens,
    pause (KV streams back to the owner shard). The (session, host)
    schedule is drawn up front from a seeded RNG — identical for both
    modes — with session popularity Zipf-skewed by `skew` (0 = uniform).
    Async mode issues the next turn's restore from the next serving
    host's vantage point, `lead` steps before the current turn ends.
    """
    assert mode in ("sync", "async"), mode
    clock = VirtualClock()
    fabric = ShardedTieredStore(
        n_hosts, policy_factory=_pinned_flash_policy, clock=clock,
        sim_cfg=sim_cfg, net_model=net_model,
        write_shield_depth=write_shield_depth)
    blob = np.zeros(max(kv_bytes // 4, 1), np.float32)
    keys = [("kv", f"s{i}") for i in range(n_sessions)]
    for i, k in enumerate(keys):
        fabric.put(k, blob, tier=Tier.FLASH, from_host=i % n_hosts)
    fabric.drain()                      # start from quiesced queues

    rng = np.random.default_rng(seed)
    n_turns = rounds * n_sessions
    w = np.power(np.arange(1, n_sessions + 1, dtype=float), -float(skew))
    w /= w.sum()
    sched = [(int(s), int(h)) for s, h in zip(
        rng.choice(n_sessions, size=n_turns, p=w),
        rng.integers(0, n_hosts, size=n_turns))]

    total_stall = 0.0
    tokens = 0
    pending: Dict[int, object] = {}     # turn index -> fetch handle
    prefetch_at = max(0, decode_steps - lead)
    for t, (si, host) in enumerate(sched):
        key = keys[si]
        # --- restore -----------------------------------------------------
        t0 = clock.now()
        pf = pending.pop(t, None)
        if pf is None:
            pf = fabric.get_async(key, from_host=host)
        pf.wait()
        total_stall += clock.now() - t0
        # --- decode, issuing the next turn's prefetch mid-turn -----------
        for s in range(decode_steps):
            if (mode == "async" and s == prefetch_at
                    and t + 1 < n_turns and t + 1 not in pending):
                nsi, nhost = sched[t + 1]
                if fabric.tier_of(keys[nsi]) is not None:
                    pending[t + 1] = fabric.get_async(
                        keys[nsi], from_host=nhost)
            clock.advance(step_time)
        tokens += decode_steps
        # --- pause (KV streams back to the owner shard) -------------------
        fabric.put(key, blob, tier=Tier.FLASH, from_host=host)

    s = fabric.summary()
    out = {
        "mode": mode,
        "hosts": float(n_hosts),
        "skew": float(skew),
        "tokens": float(tokens),
        "total_stall": total_stall,
        "per_token_stall": total_stall / max(tokens, 1),
        "makespan": clock.now(),
    }
    for k in ("local_fetches", "remote_fetches", "remote_puts",
              "prefetch_hits", "prefetch_late", "demotions_deferred",
              "nic_stall", "nic_bytes"):
        out[k] = s[k]
    return out


def compare_fleet(**kw) -> Dict[str, object]:
    """Both modes on the identical fleet schedule, plus the stall ratio
    (sync per-token stall over async — the prefetch win at fleet scale)."""
    sync = multi_host_session_bench("sync", **kw)
    async_ = multi_host_session_bench("async", **kw)
    speedup = sync["per_token_stall"] / max(async_["per_token_stall"],
                                            1e-12)
    return {"sync": sync, "async": async_, "stall_speedup": speedup}
