"""Modeled multi-turn session-serving benchmark: sync vs async KV restore.

The paper's LLM-memory workload (§VII-A): sessions pause between turns,
their KV blocks living on flash, and resume later. The seed runtime
fetched KV *synchronously* at resume — every turn began with the full
flash fetch stalling decode. The async runtime overlaps: the next
session's KV restore is issued `lead` decode steps early and streams
behind the current session's compute, so resume blocks only on the
unfinished remainder. `lead="p99"` sizes that lead per turn from the
calibrated open-loop p99 of the tier that will serve the fetch
(`ceil(p99_estimate / step_time)` steps early) instead of a fixed count.

Everything runs on a `VirtualClock` with queueing-aware flash service
times from the calibrated ssdsim model, so the output is a deterministic
*modeled* per-token stall — comparable across modes, independent of host
speed. Run `benchmarks/serving_async.py` for the CLI report.

`multi_host_session_bench` scales the same workload onto the sharded
fabric: sessions pause on one host and resume on another (chosen by a
seeded schedule, optionally Zipf-skewed toward hot sessions), so most
restores cross the NIC transfer tier composed with the owner host's
flash queue. `locality=True` reroutes each resume to a host already
holding the session's KV replica (remote restores become local reads);
`churn={"join_turn": t}` (and/or `"leave_turn"`) makes the fleet
elastic mid-schedule — the fabric streams the remapped ~1/N of keys as
background rebalance traffic and the benchmark prices the rebalance tax
as added stall per token (see `compare_churn`). Run
`benchmarks/serving_fleet.py` for the host-count x skew sweep and the
`--churn` elasticity report.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core.policy import Tier, TieringPolicy
from ..runtime.clock import VirtualClock
from ..runtime.fabric import ShardedTieredStore
from ..runtime.tiers import TieredStore


def _lead_steps(lead, store, key, step_time: float, decode_steps: int,
                **kw) -> int:
    """Fixed lead -> as given; "p99" -> sized from the serving tier's
    calibrated tail so the estimate is covered (capped at a full turn)."""
    if lead == "p99":
        return min(decode_steps,
                   store.prefetch_lead_steps(key, step_time, **kw))
    return int(lead)


def multi_turn_session_bench(mode: str = "async", *,
                             n_sessions: int = 16,
                             rounds: int = 3,
                             kv_bytes: int = 2 << 20,
                             decode_steps: int = 32,
                             step_time: float = 2e-3,
                             lead=8,
                             sim_cfg=None) -> Dict[str, float]:
    """Round-robin multi-turn serving on the virtual clock.

    Each round resumes every session once: restore KV (sync fetch, or a
    prefetch issued `lead` steps before the previous session finishes —
    `lead="p99"` sizes it from the flash tier's calibrated tail), decode
    `decode_steps` tokens at `step_time`, pause (KV back to flash).
    Returns modeled totals incl. per-token stall.
    """
    assert mode in ("sync", "async"), mode
    # thresholds pinned so session KV stays on the flash tier: the
    # benchmark measures the restore path, not placement churn
    policy = TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)
    clock = VirtualClock()
    store = TieredStore(policy, clock=clock, sim_cfg=sim_cfg)
    blob = np.zeros(kv_bytes // 4, np.float32)
    keys = [("kv", f"s{i}") for i in range(n_sessions)]
    for k in keys:
        store.put(k, blob, tier=Tier.FLASH)
    store.runtime.drain()
    store.reset_stats()         # measured phase only, not setup writes

    total_stall = 0.0
    tokens = 0
    pending = {}
    for _ in range(rounds):
        for i, key in enumerate(keys):
            # --- restore ------------------------------------------------
            t0 = clock.now()
            pf = pending.pop(key, None)
            if pf is None:
                pf = store.get_async(key)
            pf.wait()
            total_stall += clock.now() - t0
            # --- decode, issuing the next session's prefetch mid-turn ---
            nxt = keys[(i + 1) % n_sessions]
            prefetch_at = decode_steps
            if (mode == "async" and nxt not in pending and nxt != key
                    and store.tier_of(nxt) is not None):
                prefetch_at = max(0, decode_steps - _lead_steps(
                    lead, store, nxt, step_time, decode_steps))
            for s in range(decode_steps):
                if s == prefetch_at:
                    pending[nxt] = store.get_async(nxt)
                clock.advance(step_time)
            tokens += decode_steps
            # --- pause (write streams in the background) -----------------
            store.put(key, blob, tier=Tier.FLASH)

    flash = store.stats[Tier.FLASH]
    return {
        "mode": mode,
        "tokens": float(tokens),
        "total_stall": total_stall,
        "per_token_stall": total_stall / max(tokens, 1),
        "makespan": clock.now(),
        "prefetch_hits": float(flash.prefetch_hits),
        "prefetch_late": float(flash.prefetch_late),
        "miss_under_miss": float(
            store.runtime.qstats[Tier.FLASH].miss_under_miss),
    }


def compare(**kw) -> Dict[str, Dict[str, float]]:
    """Run both modes on identical workloads; async must stall less."""
    return {"sync": multi_turn_session_bench("sync", **kw),
            "async": multi_turn_session_bench("async", **kw)}


def _pinned_flash_policy(_host: int) -> TieringPolicy:
    # thresholds pinned so session KV stays on the flash tier: the
    # benchmark measures the restore path, not placement churn
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


def multi_host_session_bench(mode: str = "async", *,
                             n_hosts: Optional[int] = None,
                             n_sessions: int = 16,
                             rounds: int = 2,
                             kv_bytes: int = 1 << 20,
                             decode_steps: int = 16,
                             step_time: float = 2e-3,
                             lead=8,
                             skew: float = 0.0,
                             seed: int = 0,
                             sim_cfg=None, net_model=None,
                             write_shield_depth=None,
                             topology=None,
                             locality: bool = False,
                             churn: Optional[Dict[str, int]] = None,
                             rebalance_rate: Optional[float] = None,
                             spec=None,
                             kv_tier: Tier = Tier.FLASH
                             ) -> Dict[str, float]:
    """Fleet serving on the sharded fabric's shared virtual clock.

    Each turn resumes one session on one host: restore its KV through
    the fabric (a cross-host NIC + remote-flash composition whenever the
    serving host is not the shard owner), decode `decode_steps` tokens,
    pause (KV streams back to the owner shard). The (session, host)
    schedule is drawn up front from a seeded RNG — identical for both
    modes — with session popularity Zipf-skewed by `skew` (0 = uniform).
    Async mode issues the next turn's restore from the next serving
    host's vantage point, `lead` steps before the current turn ends
    (`lead="p99"` sizes it per turn from the owner flash tail + NIC leg).

    `locality=True` reroutes each turn to the least-loaded host already
    holding the session's KV (the scheduled host is only a fallback),
    turning remote restores into local reads. `churn={"join_turn": t}`
    joins a host before turn t (`"leave_turn"`/`"leave_host"` removes
    one); rebalance streams share the queues with serving traffic, and
    the rebalance tallies land in the returned record.
    `rebalance_rate` caps those streams per source host (bytes/s token
    bucket) so the tax stays bounded under short leads.

    Declarative mode: pass `spec=` (a `repro.platform.HierarchySpec`)
    and the fleet — per-host tier geometry, ring weights, policy, NIC,
    clock — is compiled from it instead of the keyword dialect (the
    fabric-shape kwargs must then stay at their defaults). A
    homogeneous pinned-flash spec reproduces the keyword path
    byte-for-byte. `kv_tier` is the pause/landing ask (FLASH measures
    the restore path; DRAM exercises capacity placement, where a
    capacity-weighted ring keeps big-DRAM hosts loaded proportionally).
    """
    assert mode in ("sync", "async"), mode
    if spec is not None:
        conflicts = [name for name, v in [
            ("n_hosts", n_hosts), ("sim_cfg", sim_cfg),
            ("net_model", net_model),
            ("write_shield_depth", write_shield_depth),
            ("topology", topology), ("rebalance_rate", rebalance_rate)]
            if v is not None]
        if conflicts:
            raise ValueError(
                f"spec= already declares the fleet; drop the keyword(s) "
                f"{conflicts} or fold them into the spec")
        from ..platform.compiler import Platform
        platform = Platform.compile(spec)
        clock, fabric = platform.clock, platform.fabric
        n_hosts = fabric.n_hosts
    else:
        n_hosts = 4 if n_hosts is None else n_hosts
        clock = VirtualClock()
        fabric = ShardedTieredStore(
            n_hosts, policy_factory=_pinned_flash_policy, clock=clock,
            sim_cfg=sim_cfg, net_model=net_model,
            write_shield_depth=write_shield_depth, topology=topology,
            rebalance_rate=rebalance_rate)
    blob = np.zeros(max(kv_bytes // 4, 1), np.float32)
    keys = [("kv", f"s{i}") for i in range(n_sessions)]
    for i, k in enumerate(keys):
        fabric.put(k, blob, tier=kv_tier, from_host=i % n_hosts)
    fabric.drain()                      # start from quiesced queues
    fabric.reset_stats()                # measured phase only, not setup
    resident_before = fabric.resident_bytes()

    rng = np.random.default_rng(seed)
    n_turns = rounds * n_sessions
    w = np.power(np.arange(1, n_sessions + 1, dtype=float), -float(skew))
    w /= w.sum()
    sched = [(int(s), int(h)) for s, h in zip(
        rng.choice(n_sessions, size=n_turns, p=w),
        rng.integers(0, n_hosts, size=n_turns))]

    events: Dict[int, list] = {}
    if churn:
        # join before leave at the same turn: the fleet grows, then the
        # newest host departs — both rebalances are measured
        if "join_turn" in churn:
            events.setdefault(int(churn["join_turn"]),
                              []).append(("join", None))
        if "leave_turn" in churn:
            events.setdefault(int(churn["leave_turn"]),
                              []).append(("leave", churn.get("leave_host")))

    def route(si: int, host: int) -> int:
        """Serving host for a turn: locality reroute when enabled, and a
        fallback when the scheduled host has left the fleet."""
        if locality:
            return fabric.preferred_host(keys[si], default=host)
        if host not in fabric.hosts:
            return fabric.preferred_host(keys[si],
                                         default=fabric.host_ids[0])
        return host

    total_stall = 0.0
    tokens = 0
    locality_hits = 0
    pending: Dict[int, tuple] = {}      # turn index -> (handle, host)
    for t, (si, host) in enumerate(sched):
        for action, victim in events.pop(t, ()):
            if action == "join":
                fabric.add_host()
            elif fabric.n_hosts > 1:
                victim = max(fabric.host_ids) if victim is None else victim
                fabric.remove_host(victim)
                pending = {k: v for k, v in pending.items()
                           if v[1] in fabric.hosts}
        key = keys[si]
        # --- restore -----------------------------------------------------
        t0 = clock.now()
        entry = pending.pop(t, None)
        pf, host = entry if entry is not None else (None, route(si, host))
        if fabric.hosts[host].tier_of(key) is not None:
            locality_hits += 1
        if pf is None:
            pf = fabric.get_async(key, from_host=host)
        pf.wait()
        total_stall += clock.now() - t0
        # --- decode, issuing the next turn's prefetch mid-turn -----------
        prefetch_at = decode_steps
        nxt = None
        if mode == "async" and t + 1 < n_turns and t + 1 not in pending:
            nsi, nhost = sched[t + 1]
            nhost = route(nsi, nhost)
            if fabric.tier_of(keys[nsi]) is not None:
                nxt = (nsi, nhost)
                prefetch_at = max(0, decode_steps - _lead_steps(
                    lead, fabric, keys[nsi], step_time, decode_steps,
                    from_host=nhost))
        for s in range(decode_steps):
            if s == prefetch_at and nxt is not None:
                nsi, nhost = nxt
                pending[t + 1] = (fabric.get_async(
                    keys[nsi], from_host=nhost), nhost)
            clock.advance(step_time)
        tokens += decode_steps
        # --- pause (KV streams back to the owner shard) -------------------
        fabric.put(key, blob, tier=kv_tier, from_host=host)

    s = fabric.summary()
    out = {
        "mode": mode,
        "hosts": float(n_hosts),
        "final_hosts": float(fabric.n_hosts),
        "skew": float(skew),
        "locality": float(locality),
        "locality_hits": float(locality_hits),
        "tokens": float(tokens),
        "total_stall": total_stall,
        "per_token_stall": total_stall / max(tokens, 1),
        "makespan": clock.now(),
        "resident_bytes": float(resident_before),
    }
    for k in ("local_fetches", "remote_fetches", "remote_puts",
              "prefetch_hits", "prefetch_late", "demotions_deferred",
              "nic_stall", "nic_bytes", "rebalances",
              "rebalance_keys_moved", "rebalance_bytes_moved"):
        out[k] = s[k]
    if fabric.rebalances:
        out["rebalance_events"] = [rb.as_dict()
                                   for rb in fabric.rebalances]
    return out


def compare_fleet(**kw) -> Dict[str, object]:
    """Both modes on the identical fleet schedule, plus the stall ratio
    (sync per-token stall over async — the prefetch win at fleet scale)."""
    sync = multi_host_session_bench("sync", **kw)
    async_ = multi_host_session_bench("async", **kw)
    speedup = sync["per_token_stall"] / max(async_["per_token_stall"],
                                            1e-12)
    return {"sync": sync, "async": async_, "stall_speedup": speedup}


def compare_churn(churn: Dict[str, int], *, baseline=None,
                  **kw) -> Dict[str, object]:
    """The rebalance tax, measured: the identical async schedule with and
    without the churn events, plus the added per-token stall and the
    moved-fraction of resident bytes (on a 4->5 join this should sit
    near 1/5 — the consistent-hash promise). Pass `baseline=` when the
    no-churn async record for these kwargs already exists (runs are
    byte-identical, so re-simulating it would only burn time)."""
    if baseline is None:
        baseline = multi_host_session_bench("async", **kw)
    churned = multi_host_session_bench("async", churn=churn, **kw)
    added = (churned["per_token_stall"] - baseline["per_token_stall"])
    return {
        "baseline": baseline,
        "churn": churned,
        "added_stall_per_token": added,
        "stall_ratio": (churned["per_token_stall"]
                        / max(baseline["per_token_stall"], 1e-12)),
        "rebalance_bytes": churned["rebalance_bytes_moved"],
        "rebalance_fraction": (churned["rebalance_bytes_moved"]
                               / max(churned["resident_bytes"], 1)),
    }
