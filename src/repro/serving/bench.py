"""Modeled multi-turn session-serving benchmark: sync vs async KV restore.

The paper's LLM-memory workload (§VII-A): sessions pause between turns,
their KV blocks living on flash, and resume later. The seed runtime
fetched KV *synchronously* at resume — every turn began with the full
flash fetch stalling decode. The async runtime overlaps: the next
session's KV restore is issued `lead` decode steps early and streams
behind the current session's compute, so resume blocks only on the
unfinished remainder.

Everything runs on a `VirtualClock` with queueing-aware flash service
times from the calibrated ssdsim model, so the output is a deterministic
*modeled* per-token stall — comparable across modes, independent of host
speed. Run `benchmarks/serving_async.py` for the CLI report.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..core.policy import Tier, TieringPolicy
from ..runtime.clock import VirtualClock
from ..runtime.tiers import TieredStore


def multi_turn_session_bench(mode: str = "async", *,
                             n_sessions: int = 16,
                             rounds: int = 3,
                             kv_bytes: int = 2 << 20,
                             decode_steps: int = 32,
                             step_time: float = 2e-3,
                             lead: int = 8,
                             sim_cfg=None) -> Dict[str, float]:
    """Round-robin multi-turn serving on the virtual clock.

    Each round resumes every session once: restore KV (sync fetch, or a
    prefetch issued `lead` steps before the previous session finishes),
    decode `decode_steps` tokens at `step_time`, pause (KV back to
    flash). Returns modeled totals incl. per-token stall.
    """
    assert mode in ("sync", "async"), mode
    # thresholds pinned so session KV stays on the flash tier: the
    # benchmark measures the restore path, not placement churn
    policy = TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)
    clock = VirtualClock()
    store = TieredStore(policy, clock=clock, sim_cfg=sim_cfg)
    blob = np.zeros(kv_bytes // 4, np.float32)
    keys = [("kv", f"s{i}") for i in range(n_sessions)]
    for k in keys:
        store.put(k, blob, tier=Tier.FLASH)

    total_stall = 0.0
    tokens = 0
    pending = {}
    prefetch_at = max(0, decode_steps - lead)
    for _ in range(rounds):
        for i, key in enumerate(keys):
            # --- restore ------------------------------------------------
            t0 = clock.now()
            pf = pending.pop(key, None)
            if pf is None:
                pf = store.get_async(key)
            pf.wait()
            total_stall += clock.now() - t0
            # --- decode, issuing the next session's prefetch mid-turn ---
            nxt = keys[(i + 1) % n_sessions]
            for s in range(decode_steps):
                if (mode == "async" and s == prefetch_at
                        and nxt not in pending and nxt != key
                        and store.tier_of(nxt) is not None):
                    pending[nxt] = store.get_async(nxt)
                clock.advance(step_time)
            tokens += decode_steps
            # --- pause (write streams in the background) -----------------
            store.put(key, blob, tier=Tier.FLASH)

    flash = store.stats[Tier.FLASH]
    return {
        "mode": mode,
        "tokens": float(tokens),
        "total_stall": total_stall,
        "per_token_stall": total_stall / max(tokens, 1),
        "makespan": clock.now(),
        "prefetch_hits": float(flash.prefetch_hits),
        "prefetch_late": float(flash.prefetch_late),
        "miss_under_miss": float(
            store.runtime.qstats[Tier.FLASH].miss_under_miss),
    }


def compare(**kw) -> Dict[str, Dict[str, float]]:
    """Run both modes on identical workloads; async must stall less."""
    return {"sync": multi_turn_session_bench("sync", **kw),
            "async": multi_turn_session_bench("async", **kw)}
