"""Per-tenant SLO isolation bench: one declared pack, three arms.

The headline claim of the WorkloadDecl + per-tenant economics work: with
per-tenant gating on, a scan-flood adversary **cannot** push a premium
tenant's p99 per-token restore stall past its declared
`SloDecl.p99_stall_budget` — and the very same pack violates the budget
when compiled against a single shared threshold/class (the
pre-WorkloadDecl behavior).

The pack (`tenant_pack`) is three declared tenants on one small host
whose DRAM holds `dram_blobs` paused KV blobs:

  * ``premium`` — interactive chat (short think gaps), a tight deadline,
    a declared p99 stall budget, and `alpha_stall` > 0 so its stalls
    rent DRAM harder (its own tau_be widens via Eq. 1 + the stall term);
  * ``batch``   — long decodes, lazy deadline, no budget: the tenant
    that is *allowed* to absorb flash resumes under pressure;
  * ``scan``    — the adversary: a flash-crowd burst of sessions with
    long (6 s) think gaps whose paused KV is economically cold.

Why the shared arm fails: one shared class means one shared prior, and
a prior wide enough to welcome premium's 0.75 s gaps also welcomes the
flood. The burst's fresh blobs land in DRAM together, capacity pressure
demotes the *stalest* resident — the premium session paused a second
ago — and its next resume pays the flash queue. Per-tenant compilation
gives scan its own declared 6 s prior (> its tau_be), so the flood is
priced straight to flash and premium's residency is never contested.

The third arm (``no_adversary``: shared gate, scan population zeroed)
shows causality: the shared gate alone meets the budget when no flood
arrives, so the violation is the adversary's doing, not the gate's.

`run_tenant_bench` returns a JSON-stable dict; CI runs the benchmark
driver twice and diffs the bytes (`benchmarks/serving_tenants.py`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..platform.spec import (ArrivalDecl, HierarchySpec, HostDecl,
                             PolicyDecl, SchedulerDecl, SessionShapeDecl,
                             SloDecl, TenantDecl, TierDecl, WorkloadDecl)

__all__ = ["KV_BLOB_BYTES", "tenant_pack", "run_tenant_bench"]

# one paused gemma-2b (reduced) session's KV blob at max_len=64 — the
# pack's DRAM is sized in these units and the economic policy prices
# this object size (tests assert the engine still produces this blob)
KV_BLOB_BYTES = 32768

STEP_TIME = 0.25                    # modeled seconds per decode step


def tenant_pack(*, premium_sessions: int = 4, batch_sessions: int = 3,
                scan_sessions: int = 10, dram_blobs: int = 8,
                p99_stall_budget: float = 2e-6,
                horizon_steps: int = 96, seed: int = 0) -> HierarchySpec:
    """The declared premium + batch + scan-flood pack.

    `dram_blobs` sizes the host DRAM in KV-blob units: large enough for
    every friendly paused blob (premium + batch), small enough that the
    scan burst overflows it. `p99_stall_budget` is premium's declared
    ceiling on p99 per-token restore stall (seconds/token)."""
    premium = TenantDecl(
        name="premium", n_sessions=premium_sessions,
        session=SessionShapeDecl.chat(),
        # concentrated early arrivals: the tenant is mid-conversation
        # (pausing every few steps) when the flood lands
        arrival=ArrivalDecl(kind="flash_crowd", peak_step=4,
                            burst_len=8, baseline=0.01),
        slo=SloDecl(deadline_steps=4, p99_stall_budget=p99_stall_budget,
                    alpha_stall=4.0))
    batch = TenantDecl(
        name="batch", n_sessions=batch_sessions,
        session=SessionShapeDecl.moe_heavy(tokens_per_turn=10),
        arrival=ArrivalDecl(kind="stationary"),
        slo=SloDecl(deadline_steps=12))
    scan = TenantDecl(
        name="scan", n_sessions=scan_sessions,
        session=SessionShapeDecl.scan(),
        # the whole flood arrives inside two steps and pauses together
        arrival=ArrivalDecl(kind="flash_crowd", peak_step=12,
                            burst_len=2, baseline=0.01),
        slo=SloDecl(deadline_steps=30))
    workload = WorkloadDecl(tenants=(premium, batch, scan),
                            horizon_steps=horizon_steps, seed=seed,
                            isolation="per-tenant")
    dram = TierDecl(capacity_bytes=float(dram_blobs * KV_BLOB_BYTES),
                    read_bw=45e9, read_latency=5e-7)
    chat_gap = premium.session.gap_steps * STEP_TIME
    return HierarchySpec(
        hosts=(HostDecl(tiers={"dram": dram}),),
        policy=PolicyDecl.economic(l_blk=KV_BLOB_BYTES),
        step_time=STEP_TIME,
        # the *shared* arm's single class gets the optimistic chat-gap
        # prior — the honest version of the control: the shared gate is
        # tuned for its premium users, and that is exactly what lets
        # the flood in (per-tenant arms seed per-tenant priors instead)
        class_priors={"kv": chat_gap},
        scheduler=SchedulerDecl(pause_idle_steps=0, prefetch_lead=0),
        workload=workload)


def _shared(spec: HierarchySpec) -> HierarchySpec:
    return dataclasses.replace(
        spec, workload=dataclasses.replace(spec.workload,
                                           isolation="shared"))


def _without_tenant(spec: HierarchySpec, name: str) -> HierarchySpec:
    tenants = tuple(t for t in spec.workload.tenants if t.name != name)
    return dataclasses.replace(
        spec, workload=dataclasses.replace(spec.workload,
                                           tenants=tenants))


def _run_arm(spec: HierarchySpec, cfg, params, rules, *,
             max_slots: int, max_len: int,
             trace_sink: Optional[Dict[str, object]] = None,
             arm: str = "") -> Dict[str, object]:
    from ..platform.compiler import Platform
    platform = Platform.compile(spec)
    if trace_sink is not None and platform.tracer is not None:
        trace_sink[arm] = platform.tracer
    sched = platform.scheduler(cfg, params, rules, max_slots=max_slots,
                               max_len=max_len)
    report = sched.run(platform.jobs(vocab=cfg.vocab))
    gate = platform.policy(0)
    taus = {t.name: float(gate.tau_for(("kv", f"{t.name}/000")))
            for t in spec.workload.tenants}
    gs = getattr(gate, "gate_stats", None)
    out: Dict[str, object] = {"report": report, "tau_be": taus}
    if gs is not None:
        out["gate"] = {k: int(v) for k, v in
                       dataclasses.asdict(gs).items()}
    return out


def run_tenant_bench(spec: Optional[HierarchySpec] = None, *,
                     max_slots: int = 4, max_len: int = 64,
                     trace_sink: Optional[Dict[str, object]] = None
                     ) -> Dict[str, object]:
    """Replay the pack through all three arms and judge the SLOs.

    Returns a deterministic, JSON-serializable dict: per-arm scheduler
    reports (with per-tenant p99 stall accounting, the Eq. 1 stall
    ledger and budget burn), per-arm thresholds, declared budgets, and
    the isolation verdicts. When the spec declares
    `observability.trace`, pass `trace_sink={}` to collect each arm's
    `Tracer` (arm name -> tracer) for Perfetto export."""
    import jax
    from ..configs import get_config
    from ..models import model as M
    from ..parallel.sharding import single_device_rules

    spec = tenant_pack() if spec is None else spec
    spec.validate()
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)

    arms = {
        "gated": spec,
        "shared": _shared(spec),
        "no_adversary": _without_tenant(_shared(spec), "scan"),
    }
    out: Dict[str, object] = {
        "spec": {"workload_seed": spec.workload.seed,
                 "horizon_steps": spec.workload.horizon_steps,
                 "dram_bytes": spec.hosts[0].dram_capacity(),
                 "step_time": STEP_TIME}}
    for name, arm_spec in arms.items():
        out[name] = _run_arm(arm_spec, cfg, params, rules,
                             max_slots=max_slots, max_len=max_len,
                             trace_sink=trace_sink, arm=name)

    budgets = {t.name: t.slo.p99_stall_budget
               for t in spec.workload.tenants
               if t.slo.p99_stall_budget is not None}
    out["budgets"] = budgets

    def p99(arm: str, tenant: str) -> float:
        tenants = out[arm]["report"].get("tenants", {})
        cell = tenants.get(tenant)
        return float(cell["p99_per_token_stall"]) if cell else 0.0

    verdicts: Dict[str, object] = {}
    for tenant, budget in budgets.items():
        v = {
            "budget": budget,
            "gated_p99": p99("gated", tenant),
            "shared_p99": p99("shared", tenant),
            "no_adversary_p99": p99("no_adversary", tenant),
        }
        v["gated_meets_budget"] = bool(v["gated_p99"] <= budget)
        v["shared_violates"] = bool(v["shared_p99"] > budget)
        v["adversary_causal"] = bool(v["no_adversary_p99"] <= budget)
        v["isolation_effective"] = bool(
            v["gated_meets_budget"] and v["shared_violates"]
            and v["adversary_causal"])
        verdicts[tenant] = v
    out["verdicts"] = verdicts
    out["isolation_effective"] = bool(all(
        v["isolation_effective"] for v in verdicts.values()))
    return out
