"""Serving engine: continuous batching over a fixed slot grid, with
five-minute-rule-driven KV offload.

The engine owns a decode cache of `max_slots` sequences. Requests are
prefilled into free slots (one jit'd prefill per admission batch) and all
live slots advance together through one jit'd decode step per token
(per-slot fill indices — slots at different positions coexist).

KV tiering (the paper's technique at work): when a request pauses (e.g.
multi-turn sessions) its per-slot KV block is *extracted* and handed to
the TieredStore keyed by session id; the TieringPolicy's observed reuse
interval vs the calibrated break-even threshold decides whether it lands
in host DRAM or flash. On resume the block is re-inserted into a free
slot. This is exactly the paper's "LLM memory layer / session-state"
workload (§VII-A) realized on the serving runtime.

Async KV restore (queueing-aware runtime): `prefetch` issues a session's
KV fetch through `TieredStore.get_async` *before* the slot is needed;
each decode step advances the store's injected clock by `step_time`
(modeled decode compute), so the flash transfer streams behind decode.
`resume` then blocks only on the unfinished remainder — zero stall
whenever the prefetch lead covers the queueing-aware fetch latency.
Stall and miss-under-miss accounting land in the store's `TierStats` /
the runtime's `QueueStats`; `kv_stall_time` totals the decode-visible
stalls. The clock is injectable (deterministic `VirtualClock` default —
see `repro.runtime.clock` for the testing contract).

Multi-host mode (sharded fabric): pass `store=fabric.host_view(host)`
(what `repro.platform.Platform.engine` does) and the engine's store
becomes that host's fabric view — KV blocks shard to their
consistent-hash owner host, and a session paused on one host can resume
on another: `export_session`/`import_session` hand the (tiny) session
metadata between engines while the KV block itself streams cross-host
through the fabric's NIC + remote-flash composition, behind decode when
`prefetch` is issued with enough lead. The old `fabric=`/`host=`
constructor dialect still works as a thin deprecated shim.

Session durability (self-healing fleet): with `checkpoint_interval=N`
every live slot re-puts its KV blob and restart metadata every N decode
steps (and on every pause). When the engine's host dies unplanned
(`fabric.fail_host`), a surviving engine adopts the session from
`checkpoints()` via `restore_checkpoint` — the replicated blob restores
from a surviving holder and greedy decode deterministically regenerates
the at-most-N tokens lost since the last checkpoint. `export_session`
refuses to hand out metadata whose KV blob has no surviving copy (a
torn session is restarted, never resurrected).

Compile behavior (the splice-jit cache): slot splices — admitting a
prefilled prompt into a slot, restoring a resumed session's KV block —
run through module-level jitted functions whose slot index is a
*traced* scalar, so one compiled program serves every slot of every
engine with the same cache geometry (cross-host resumes stop re-jitting
per slot). Prompt lengths are right-padded to power-of-two buckets
(when every cached sublayer is attention — recurrent states would
advance through pad garbage), so prefill compiles once per bucket
instead of once per exact length; causal masking keeps real positions
unaffected and `prefill(last_index=...)` returns the last *real*
token's logits. `splice_trace_counts()` exposes the retrace counters.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.policy import Tier, TieringPolicy
from ..models import model as model_lib
from ..models.config import ModelConfig
from ..parallel.sharding import Rules
from ..runtime.tiers import TieredStore


# eq=False: the generated dataclass __eq__ would compare the ndarray
# prompts elementwise ("truth value of an array is ambiguous" on any two
# distinct requests) — identity is the only meaningful equality here,
# and schedulers key on `rid` anyway
@dataclasses.dataclass(eq=False)
class Request:
    rid: str
    prompt: np.ndarray            # [S] int32
    max_new: int = 16
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


# ---------------------------------------------------------------------------
# Splice-jit cache: traced-slot splice programs shared by every engine
# with the same cache geometry. The counters increment only while jax
# traces (a cache miss), so tests can assert reuse across slots, prompt
# buckets and engines.
# ---------------------------------------------------------------------------

_SPLICE_TRACES = {"batch": 0, "block": 0}


def splice_trace_counts() -> Dict[str, int]:
    """Copy of the module-wide splice retrace counters."""
    return dict(_SPLICE_TRACES)


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


@jax.jit
def _splice_from_batch(cache, src_cache, slot, src_idx):
    """Write batch element `src_idx` of `src_cache` into `slot` of
    `cache` (both indices traced — one program per cache geometry)."""
    _SPLICE_TRACES["batch"] += 1
    groups = jax.tree.map(
        lambda dst, src: dst.at[:, slot].set(
            jax.lax.dynamic_index_in_dim(src, src_idx, axis=1,
                                         keepdims=False).astype(dst.dtype)),
        cache["groups"], src_cache["groups"])
    tail = jax.tree.map(
        lambda dst, src: dst.at[slot].set(
            jax.lax.dynamic_index_in_dim(src, src_idx, axis=0,
                                         keepdims=False).astype(dst.dtype)),
        cache["tail"], src_cache["tail"])
    return {"groups": groups, "tail": tail}


@jax.jit
def _splice_block(cache, blk, slot):
    """Write an extracted per-slot KV block back into `slot` (traced)."""
    _SPLICE_TRACES["block"] += 1
    groups = jax.tree.map(
        lambda dst, src: dst.at[:, slot].set(src.astype(dst.dtype)),
        cache["groups"], blk["groups"])
    tail = jax.tree.map(
        lambda dst, src: dst.at[slot].set(src.astype(dst.dtype)),
        cache["tail"], blk["tail"])
    return {"groups": groups, "tail": tail}


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, params, rules: Rules, *,
                 max_slots: int = 4, max_len: int = 256,
                 policy: Optional[TieringPolicy] = None,
                 store: Optional[TieredStore] = None,
                 fabric=None, host: int = 0,
                 clock=None, step_time: float = 0.0,
                 checkpoint_interval: int = 0,
                 compute_dtype=jnp.float32, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.max_slots = max_slots
        self.max_len = max_len
        self.dtype = compute_dtype
        self.greedy = greedy
        self.cache = model_lib.init_cache(cfg, max_slots, max_len,
                                          dtype=compute_dtype)
        self.lengths = np.zeros(max_slots, np.int32)    # filled positions
        self.live = np.zeros(max_slots, bool)
        # parked slots: live (KV resident, slot held) but not decoding —
        # a scheduler keeps short-gap multi-turn sessions resident
        # instead of paying the offload/restore round trip
        self.active = np.zeros(max_slots, bool)
        self.last_token = np.zeros(max_slots, np.int32)  # decode inputs
        self.slot_req: Dict[int, Request] = {}
        self.policy = policy or TieringPolicy(tau_hot=0.05, tau_be=5.0)
        if store is None and fabric is not None:
            # legacy constructor dialect — the declarative path is
            # Platform.engine(...) / store=fabric.host_view(host)
            warnings.warn(
                "DecodeEngine(fabric=..., host=...) is deprecated; "
                "compile a repro.platform.HierarchySpec and use "
                "Platform.engine(..., host=...), or pass "
                "store=fabric.host_view(host)", DeprecationWarning,
                stacklevel=2)
            store = fabric.host_view(host)
        elif store is not None:
            # a fabric host view carries its own host identity
            host = getattr(store, "host", host)
        self.host = host
        self.store = store or TieredStore(self.policy, clock=clock)
        self.clock = self.store.clock
        self.step_time = step_time      # modeled seconds of decode compute
        self.kv_stall_time = 0.0        # decode-visible restore stalls
        # observability rides in on the store (single-host or fabric
        # view): session lifecycle instants + causal flows join the
        # transfer spans the runtime already records
        self.obs = getattr(self.store, "obs", None)
        self._paused: Dict[str, tuple] = {}
        self._pending: Dict[str, object] = {}   # rid -> PendingFetch
        # periodic session durability: every `checkpoint_interval` decode
        # steps (0 = off) live slots re-put their KV blob and refresh the
        # restart metadata below, so an unplanned host failure loses at
        # most the tokens generated since the last checkpoint
        self.checkpoint_interval = int(checkpoint_interval)
        self._checkpoints: Dict[str, tuple] = {}
        self.steps = 0
        # prompt-length bucketing is sound only when no cached sublayer
        # carries recurrent state (pads would advance it) and there is
        # no encoder prefix
        self._bucket_prompts = cfg.encoder is None and all(
            spec.kind in ("attn", "ffn", "moe")
            for *_ignored, spec in cfg.sublayers())
        self.jit_stats = {"prefill_traces": 0}

        def _counted_prefill(*a, **kw):
            self.jit_stats["prefill_traces"] += 1
            return model_lib.prefill(*a, **kw)

        self._prefill = jax.jit(functools.partial(
            _counted_prefill, cfg=cfg, rules=rules,
            compute_dtype=compute_dtype))
        self._decode = jax.jit(functools.partial(
            model_lib.decode_step, cfg=cfg, rules=rules,
            compute_dtype=compute_dtype))

    # -------------------------------------------------------- observability
    def _trace_session(self, name: str, rid: str, flow: str = "",
                       **args) -> None:
        """Session-lifecycle instant on this engine's track; `flow`
        ("s"/"t"/"f") stitches the event into the session's causal
        chain (admission -> prefetch -> fetch spans -> resume)."""
        if self.obs is None or self.obs.tracer is None:
            return
        t = self.obs.tracer
        track = t.track(f"host{self.host}", "engine")
        now = self.clock.now()
        t.instant(track, name, now, cat="session",
                  args={"rid": rid, **args})
        if flow == "s":
            t.flow_start(track, f"session:{rid}", now, ("session", rid))
        elif flow == "t":
            t.flow_step(track, f"session:{rid}", now, ("session", rid))
        elif flow == "f":
            t.flow_end(track, f"session:{rid}", now, ("session", rid))

    # ------------------------------------------------------------ admission
    def _free_slots(self) -> List[int]:
        return [i for i in range(self.max_slots) if not self.live[i]]

    def admit(self, req: Request):
        """Prefill a request into a free slot (single-sequence prefill
        batched into the slot grid via masking writes). Prompts are
        right-padded to a power-of-two bucket when sound (attention-only
        caches): prefill compiles once per bucket, the causal mask keeps
        real positions pad-independent, decode masks positions beyond
        the fill index, and `last_index` picks the real last logits."""
        free = self._free_slots()
        if not free:
            raise RuntimeError("no free slots")
        slot = free[0]
        S = len(req.prompt)
        assert S < self.max_len
        tokens = req.prompt
        if self._bucket_prompts:
            L = min(_next_pow2(S), self.max_len - 1)
            if L > S:
                tokens = np.concatenate(
                    [req.prompt, np.zeros(L - S, req.prompt.dtype)])
        # run a batch-1 prefill against a temp cache, then splice the slot
        tmp_cache = model_lib.init_cache(self.cfg, 1, self.max_len,
                                         dtype=self.dtype)
        batch = {"tokens": jnp.asarray(tokens[None, :])}
        if self.cfg.encoder is not None:
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder.n_frames, self.cfg.d_model),
                self.dtype)
        if self._bucket_prompts:
            tmp_cache, logits = self._prefill(
                self.params, batch=batch, cache=tmp_cache,
                last_index=jnp.asarray(S - 1, jnp.int32))
        else:
            tmp_cache, logits = self._prefill(self.params, batch=batch,
                                              cache=tmp_cache)
        self._splice_slot(tmp_cache, slot)
        self.lengths[slot] = S
        self.live[slot] = True
        self.active[slot] = True
        req.slot = slot
        self.slot_req[slot] = req
        first = int(np.argmax(np.asarray(logits[0]))) if self.greedy else 0
        req.generated.append(first)
        self.last_token[slot] = first
        self._trace_session("admit", req.rid, flow="s", slot=slot,
                            prompt_len=S)
        return slot

    def _splice_slot(self, src_cache, slot: int, src_idx: int = 0):
        # group caches are stacked [G, B, ...] (batch at dim 1); tail
        # caches are unstacked [B, ...] (batch at dim 0). Both indices
        # are traced, so one compiled program serves every slot.
        self.cache = _splice_from_batch(
            self.cache, src_cache, jnp.asarray(slot, jnp.int32),
            jnp.asarray(src_idx, jnp.int32))

    def _extract_slot(self, slot: int):
        return {
            "groups": jax.tree.map(lambda a: np.asarray(a[:, slot]),
                                   self.cache["groups"]),
            "tail": jax.tree.map(lambda a: np.asarray(a[slot]),
                                 self.cache["tail"]),
        }

    def _slot_of_rid(self, rid: str) -> int:
        """Slot currently decoding `rid`; KeyError (not a bare
        StopIteration out of `next`) when the session is not live here —
        unknown, already paused, or finished."""
        for s, r in self.slot_req.items():
            if r.rid == rid:
                return s
        state = ("paused" if rid in self._paused else "not live")
        raise KeyError(f"session {rid!r} is {state} on this engine; "
                       f"only live sessions can be paused or "
                       f"checkpointed")

    # -------------------------------------------------------------- pausing
    def pause(self, rid: str):
        """Offload a session's KV block through the tiered store."""
        slot = self._slot_of_rid(rid)
        req = self.slot_req.pop(slot)
        blk = self._extract_slot(slot)
        flat = jax.tree.leaves(blk)
        blob = np.concatenate([np.asarray(l, np.float32).ravel()
                               for l in flat])
        self.store.put(("kv", rid), blob)
        state = (req, jax.tree.structure(blk),
                 [(l.shape, l.dtype) for l in flat],
                 int(self.lengths[slot]))
        self._paused[rid] = state
        # a pause is also the freshest durable point for the session
        self._checkpoints[rid] = state
        self.live[slot] = False
        self.active[slot] = False
        self.lengths[slot] = 0
        tier = self.store.tier_of(("kv", rid))
        self._trace_session("pause", rid, flow="t", slot=slot,
                            tier=getattr(tier, "name", str(tier)))
        return tier

    def park(self, rid: str) -> int:
        """Idle a live session in place: the slot and its KV stay
        resident but the slot stops decoding (no token append, no
        length advance) until `unpark`. Cheaper than `pause`/`resume`
        for short inter-turn gaps — no offload, no restore stall."""
        slot = self._slot_of_rid(rid)
        self.active[slot] = False
        return slot

    def unpark(self, rid: str) -> int:
        """Reactivate a parked session; decode picks up exactly where
        it left off (the parked slot's pending KV position is rewritten
        by the first real decode)."""
        slot = self._slot_of_rid(rid)
        self.active[slot] = True
        return slot

    # -------------------------------------------------------- checkpointing
    def checkpoint_session(self, rid: str):
        """Durable snapshot of a *live* session without evicting it: the
        slot's KV block is re-put to the store under the usual
        (\"kv\", rid) key (replicated when the store is a fabric view
        with replicas >= 2) and restart metadata is recorded, but decode
        keeps running in place. After an unplanned failure of this host,
        a surviving engine `import_session`s the checkpoint and `resume`s
        from the checkpointed position — greedy decode regenerates the
        lost tail deterministically."""
        slot = self._slot_of_rid(rid)
        req = self.slot_req[slot]
        blk = self._extract_slot(slot)
        flat = jax.tree.leaves(blk)
        blob = np.concatenate([np.asarray(l, np.float32).ravel()
                               for l in flat])
        self.store.put(("kv", rid), blob)
        # snapshot the request: later decode steps on this engine must
        # not mutate the checkpointed token list
        self._checkpoints[rid] = (
            dataclasses.replace(req, slot=None,
                                generated=list(req.generated)),
            jax.tree.structure(blk), [(l.shape, l.dtype) for l in flat],
            int(self.lengths[slot]))
        return self.store.tier_of(("kv", rid))

    def checkpoint_live(self):
        """Checkpoint every live, unfinished session (slot order)."""
        rids = [r.rid for s, r in sorted(self.slot_req.items())
                if self.live[s] and not r.done]
        for rid in rids:
            self.checkpoint_session(rid)
        return rids

    def checkpoints(self) -> Dict[str, tuple]:
        """rid -> restart state, same tuple format `import_session`
        takes. What a failover controller reads off a dead engine's
        last known state (the metadata is tiny and assumed mirrored;
        the KV blob's durability is the fabric's replication)."""
        return dict(self._checkpoints)

    def restore_checkpoint(self, rid: str, state=None):
        """Re-admit a session from its last checkpoint (here or, with
        `state` from another engine's `checkpoints()`, after failover).
        Returns the landing slot; the session re-decodes from the
        checkpointed position."""
        if state is None:
            state = self._checkpoints[rid]
        if rid not in self._paused:
            self.import_session(rid, state)
        return self.resume(rid)

    def export_session(self, rid: str):
        """Hand a paused session off to another host's engine: returns
        the session metadata (request + KV tree spec — a few hundred
        bytes). The KV block itself stays in the tiered store/fabric and
        streams to the resuming host on its `prefetch`/`resume`."""
        # an issued prefetch belongs to this host's vantage point; just
        # drop the handle — the in-flight transfer completes on its own
        # in the background, and waiting here would advance the shared
        # clock for data nobody will consume
        self._pending.pop(rid, None)
        state = self._paused.pop(rid)
        # torn-session guard: metadata must never outlive the KV blob.
        # `tier_of` is a structural check — a mid-flight ingest (readability
        # -gated restore, repair stream) already has its placement recorded
        # and any read pays the arrival gate, so exporting it is safe; only
        # a blob with *no* surviving copy anywhere makes the metadata
        # unresumable, and handing it out would resurrect a torn session
        # on some other host.
        if self.store.tier_of(("kv", rid)) is None:
            self._paused[rid] = state
            raise KeyError(
                f"session {rid!r}: KV blob has no surviving copy; "
                f"cannot export a torn session")
        self._checkpoints.pop(rid, None)
        return state

    def import_session(self, rid: str, state):
        """Adopt a session exported by another engine on the same store
        or fabric; `prefetch`/`resume` then work as if paused here."""
        if rid in self._paused:
            raise KeyError(f"session {rid!r} already paused here")
        self._paused[rid] = state

    def locality_host(self, rid: str) -> int:
        """Host a resuming session should be routed to: one already
        holding its KV replica (the remote NIC + remote-flash restore
        becomes a plain local read), else this engine's host. Only
        meaningful in fabric mode — a single-host store is its own
        locality."""
        fab = getattr(self.store, "fabric", None)
        if fab is None:
            return self.host
        return fab.preferred_host(("kv", rid), default=self.host)

    def prefetch_lead(self, rid: str) -> int:
        """p99-sized prefetch lead for `rid` in decode steps: how many
        steps before the slot is needed `prefetch` should be called so
        the tail-aware fetch estimate (owner flash p99 + NIC leg when
        remote) is covered by modeled decode compute. Falls back to one
        step when the store predates lead sizing or `step_time` is 0."""
        lead_fn = getattr(self.store, "prefetch_lead_steps", None)
        if lead_fn is None or self.step_time <= 0:
            return 1
        return lead_fn(("kv", rid), self.step_time)

    def prefetch(self, rid: str):
        """Issue a paused session's KV restore asynchronously: the fetch
        streams from its tier while decode steps keep advancing the clock.
        Idempotent; returns the pending handle."""
        if rid not in self._paused:
            raise KeyError(rid)
        if rid not in self._pending:
            self._pending[rid] = self.store.get_async(("kv", rid))
            self._trace_session("prefetch", rid, flow="t")
        return self._pending[rid]

    def prefetch_many(self, rids):
        """Batched async restore: issue all fetches back-to-back so the
        flash queue pipelines them (miss-under-miss)."""
        return [self.prefetch(r) for r in rids]

    def resume(self, rid: str):
        """Re-admit a paused session. Blocks only on the unfinished part
        of its (pre)fetch; the stall lands in `kv_stall_time`."""
        if rid not in self._paused:
            raise KeyError(f"session {rid!r} is not paused on this "
                           f"engine")
        # secure the slot *before* consuming any session state: the
        # no-free-slots failure must leave the session fully resumable
        # (metadata in `_paused`, any issued prefetch still pending)
        free = self._free_slots()
        if not free:
            raise RuntimeError("no free slots")
        slot = free[0]
        req, treedef, shapes, length = self._paused.pop(rid)
        pf = self._pending.pop(rid, None)
        if pf is None:
            pf = self.store.get_async(("kv", rid))
        t0 = self.clock.now()
        blob = pf.wait()
        stall = self.clock.now() - t0
        self.kv_stall_time += stall
        self._trace_session("resume", rid, flow="f", slot=slot,
                            stall=stall)
        leaves, off = [], 0
        for shape, dtype in shapes:
            n = int(np.prod(shape))
            leaves.append(np.asarray(
                blob[off:off + n].reshape(shape), dtype))
            off += n
        blk = jax.tree.unflatten(treedef, leaves)
        # traced-slot splice: repeated (cross-host) resumes reuse one
        # compiled program regardless of the landing slot
        self.cache = _splice_block(self.cache, blk,
                                   jnp.asarray(slot, jnp.int32))
        self.lengths[slot] = length
        self.live[slot] = True
        self.active[slot] = True
        if req.generated:
            self.last_token[slot] = req.generated[-1]
        req.slot = slot
        self.slot_req[slot] = req
        return slot

    # ---------------------------------------------------------------- step
    def step(self):
        """One decode step for all live, non-parked slots (vectorized
        across the slot grid: token gather, argmax and length advance
        are whole-array ops; Python only touches slots that finish this
        step). Parked and dead slots ride through the fixed-shape decode
        but their state is masked out — the garbage KV written at their
        pending position is overwritten by the first real decode after
        unpark/admit."""
        act = self.live & self.active
        if not act.any():
            return
        idx = jnp.asarray(self.lengths)
        self.cache, logits = self._decode(
            self.params, token=jnp.asarray(self.last_token[:, None]),
            cache=self.cache, index=idx)
        self.steps += 1
        if self.step_time:
            # modeled decode compute overlaps in-flight KV transfers
            self.store.runtime.advance(self.step_time)
        nxt = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        self.last_token = np.where(act, nxt, self.last_token)
        self.lengths[act] += 1
        for slot, req in list(self.slot_req.items()):
            if not act[slot]:
                continue
            req.generated.append(int(nxt[slot]))
            if (len(req.generated) >= req.max_new
                    or self.lengths[slot] >= self.max_len - 1):
                req.done = True
                self.live[slot] = False
                self.active[slot] = False
                del self.slot_req[slot]
                self._checkpoints.pop(req.rid, None)
        if (self.checkpoint_interval and self.live.any()
                and self.steps % self.checkpoint_interval == 0):
            self.checkpoint_live()

    def run(self, requests: List[Request], max_steps: int = 1000):
        """Simple gang scheduler loop: admit as slots free up, decode
        until all requests complete. Completion is tracked by rid (the
        old `r not in done` identity scan was O(n^2) per step)."""
        pending = list(requests)
        done: List[Request] = []
        done_rids = set()
        steps = 0
        while (pending or self.live.any()) and steps < max_steps:
            while pending and self._free_slots():
                self.admit(pending.pop(0))
            self.step()
            steps += 1
            for r in requests:
                if r.done and r.rid not in done_rids:
                    done_rids.add(r.rid)
                    done.append(r)
        return done


def route_session(engines: Dict[int, "DecodeEngine"], rid: str,
                  state=None) -> "DecodeEngine":
    """Locality-aware session routing across a fleet of engines (one per
    fabric host): pick the engine whose host already holds the session's
    KV replica, so the restore is a local flash read instead of the NIC
    + remote-flash composition. Falls back to the first engine when no
    replica exists (fresh session) or the holder runs no engine. When
    `state` (from `export_session`) is given, the session is imported
    into the chosen engine."""
    if not engines:
        raise ValueError("no engines to route over")
    first = next(iter(engines.values()))
    host = first.locality_host(rid)
    target = engines.get(host, first)
    if state is not None:
        target.import_session(rid, state)
    return target
