"""deepseek-7b [arXiv:2401.02954] — llama-arch dense.

30L, d_model=4096, 32 heads MHA (kv=32), head_dim=128, SwiGLU d_ff=11008,
vocab 102400.
"""
from ..models.config import AttnSpec, FfnSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        d_model=4096, vocab=102400, n_groups=30,
        pattern=((AttnSpec(n_heads=32, n_kv=32, head_dim=128),
                  FfnSpec(d_ff=11008)),),
        max_seq=32768, rope_theta=1e4, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((AttnSpec(n_heads=4, n_kv=4, head_dim=16),
                  FfnSpec(d_ff=160)),),
        max_seq=128, rope_theta=1e4, tie_embeddings=False,
    )
