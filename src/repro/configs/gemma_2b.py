"""gemma-2b [arXiv:2403.08295].

18L, d_model=2048, 8 heads / 1 kv (MQA), head_dim=256, GeGLU d_ff=16384,
vocab 256000, embeddings scaled by sqrt(d_model), tied.
"""
from ..models.config import AttnSpec, FfnSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        d_model=2048, vocab=256000, n_groups=18,
        pattern=((AttnSpec(n_heads=8, n_kv=1, head_dim=256),
                  FfnSpec(d_ff=16384, act="geglu")),),
        max_seq=8192, rope_theta=1e4, tie_embeddings=True,
        embed_scale=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((AttnSpec(n_heads=4, n_kv=1, head_dim=32),
                  FfnSpec(d_ff=256, act="geglu")),),
        max_seq=128, rope_theta=1e4, tie_embeddings=True,
        embed_scale=True,
    )
