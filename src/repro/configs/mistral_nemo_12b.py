"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407] — 128k ctx.

40L, d_model=5120, 32 heads / 8 kv (GQA), head_dim=128, SwiGLU d_ff=14336,
vocab 131072.
"""
from ..models.config import AttnSpec, FfnSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        d_model=5120, vocab=131072, n_groups=40,
        pattern=((AttnSpec(n_heads=32, n_kv=8, head_dim=128),
                  FfnSpec(d_ff=14336)),),
        max_seq=131072, rope_theta=1e6, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((AttnSpec(n_heads=4, n_kv=2, head_dim=16),
                  FfnSpec(d_ff=192)),),
        max_seq=128, rope_theta=1e4, tie_embeddings=False,
    )
