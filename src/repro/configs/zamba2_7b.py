"""zamba2-7b [arXiv:2411.15242] — Mamba2 backbone + shared attention.

81 layers, d_model=3584, ssm_state=64, mamba head_dim=64 (d_inner=7168,
112 heads). A *weight-shared* attention+FFN block (32 heads MHA,
d_ff=14336) is applied every 6th mamba layer (13 applications over the
13x6=78 scanned layers; the remaining 3 mamba layers form the tail).
Sub-quadratic (recurrent state dominates) -> runs long_500k.
"""
from ..models.config import AttnSpec, FfnSpec, Mamba2Spec, ModelConfig

_MAMBA = Mamba2Spec(d_state=64, head_dim=64, expand=2)
_SHARED_ATTN = AttnSpec(n_heads=32, n_kv=32, head_dim=112, shared=True)
_SHARED_FFN = FfnSpec(d_ff=14336, shared=True)


def config() -> ModelConfig:
    mamba_layer = (_MAMBA,)
    return ModelConfig(
        name="zamba2-7b",
        d_model=3584, vocab=32000, n_groups=13,
        pattern=(mamba_layer,) * 5 + (
            (_SHARED_ATTN, _SHARED_FFN, _MAMBA),),
        tail=(mamba_layer,) * 3,
        max_seq=524288, rope_theta=1e4, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    m = Mamba2Spec(d_state=16, head_dim=16, expand=2, chunk=16)
    return ModelConfig(
        name="zamba2-7b-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((m,), (AttnSpec(n_heads=4, n_kv=4, head_dim=16,
                                 shared=True),
                        FfnSpec(d_ff=128, shared=True), m)),
        tail=((m,),),
        max_seq=128, rope_theta=1e4, tie_embeddings=True,
    )
