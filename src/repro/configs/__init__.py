"""Architecture registry: the 10 assigned architectures (+ reduced smoke
variants) and the per-arch input-shape sets."""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.config import ModelConfig

ARCHS = (
    "qwen3-moe-235b-a22b",
    "llama4-maverick-400b-a17b",
    "xlstm-350m",
    "deepseek-7b",
    "granite-20b",
    "gemma-2b",
    "mistral-nemo-12b",
    "whisper-medium",
    "qwen2-vl-2b",
    "zamba2-7b",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MOD)}")
    mod = importlib.import_module(f".{_MOD[name]}", __package__)
    return mod.reduced() if reduced else mod.config()


def all_configs(reduced: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCHS}
