"""whisper-medium [arXiv:2212.04356] — encoder-decoder.

24L encoder + 24L decoder, d_model=1024, 16 heads MHA, d_ff=4096 (gelu),
vocab 51865. The conv/audio frontend is a STUB: input_specs provide
precomputed frame embeddings [B, 1500, d_model]; the encoder uses absolute
sinusoidal positions (no rope), the decoder has self-attn + cross-attn.
"""
from ..models.config import AttnSpec, EncoderConfig, FfnSpec, ModelConfig

_SELF = dict(n_heads=16, n_kv=16, head_dim=64, rope="none")


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        d_model=1024, vocab=51865, n_groups=24,
        pattern=((AttnSpec(**_SELF),
                  AttnSpec(**_SELF, cross=True, causal=False),
                  FfnSpec(d_ff=4096, act="gelu")),),
        encoder=EncoderConfig(
            n_groups=24,
            pattern=((AttnSpec(**_SELF, causal=False),
                      FfnSpec(d_ff=4096, act="gelu")),),
            n_frames=1500),
        max_seq=32768, tie_embeddings=True, modality="audio",
        norm="layernorm",
    )


def reduced() -> ModelConfig:
    small = dict(n_heads=4, n_kv=4, head_dim=16, rope="none")
    return ModelConfig(
        name="whisper-medium-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((AttnSpec(**small),
                  AttnSpec(**small, cross=True, causal=False),
                  FfnSpec(d_ff=128, act="gelu")),),
        encoder=EncoderConfig(
            n_groups=2,
            pattern=((AttnSpec(**small, causal=False),
                      FfnSpec(d_ff=128, act="gelu")),),
            n_frames=32),
        max_seq=128, tie_embeddings=True, modality="audio",
        norm="layernorm",
    )
