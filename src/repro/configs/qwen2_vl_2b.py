"""qwen2-vl-2b [arXiv:2409.12191] — VLM backbone with M-RoPE.

28L, d_model=1536, 12 heads / 2 kv (GQA), head_dim=128, SwiGLU d_ff=8960,
vocab 151936. The vision frontend is a STUB: input_specs provide
precomputed patch embeddings [B, S_vis, d_model] plus 3-axis (t,h,w)
M-RoPE position ids.
"""
from ..models.config import AttnSpec, FfnSpec, ModelConfig

_ATTN = dict(n_heads=12, n_kv=2, head_dim=128, rope="mrope",
             mrope_sections=(16, 24, 24))


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        d_model=1536, vocab=151936, n_groups=28,
        pattern=((AttnSpec(**_ATTN), FfnSpec(d_ff=8960)),),
        max_seq=32768, rope_theta=1e6, tie_embeddings=True,
        modality="vlm", vision_frac=0.25,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((AttnSpec(n_heads=4, n_kv=2, head_dim=16, rope="mrope",
                           mrope_sections=(2, 3, 3)),
                  FfnSpec(d_ff=128)),),
        max_seq=128, rope_theta=1e4, tie_embeddings=True,
        modality="vlm", vision_frac=0.25,
    )
