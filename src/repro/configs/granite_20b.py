"""granite-20b [arXiv:2405.04324] — code model, llama-arch, MQA.

52L, d_model=6144, 48 heads / 1 kv (MQA), head_dim=128, d_ff=24576 (gelu),
vocab 49152.
"""
from ..models.config import AttnSpec, FfnSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        d_model=6144, vocab=49152, n_groups=52,
        pattern=((AttnSpec(n_heads=48, n_kv=1, head_dim=128),
                  FfnSpec(d_ff=24576, act="gelu")),),
        max_seq=32768, rope_theta=1e4, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((AttnSpec(n_heads=4, n_kv=1, head_dim=16),
                  FfnSpec(d_ff=256, act="gelu")),),
        max_seq=128, rope_theta=1e4, tie_embeddings=True,
    )
