"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B family].

94L, d_model=4096, 64 q heads / 4 kv heads (GQA), head_dim=128, per-expert
d_ff=1536, 128 experts top-8, vocab 151936, qk-norm (qwen3), rope 1e6.
"""
from ..models.config import AttnSpec, ModelConfig, MoeSpec

_ATTN = dict(n_heads=64, n_kv=4, head_dim=128, qk_norm=True)
_MOE = dict(n_experts=128, top_k=8, d_ff=1536)


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        d_model=4096, vocab=151936, n_groups=94,
        pattern=((AttnSpec(**_ATTN), MoeSpec(**_MOE)),),
        max_seq=32768, rope_theta=1e6, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((AttnSpec(n_heads=4, n_kv=2, head_dim=16, qk_norm=True),
                  MoeSpec(n_experts=8, top_k=2, d_ff=96)),),
        max_seq=128, rope_theta=1e4, tie_embeddings=False,
    )
