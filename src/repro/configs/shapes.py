"""Assigned input shapes and abstract input specs per (arch x shape) cell.

  train_4k     seq_len=4096    global_batch=256   -> train_step
  prefill_32k  seq_len=32768   global_batch=32    -> prefill
  decode_32k   seq_len=32768   global_batch=128   -> decode (1 new token,
                                                     KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     -> decode; requires a
                                                     sub-quadratic arch

All specs are ShapeDtypeStructs (no allocation) — the same pattern the
dry-run uses to lower+compile every cell.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..models import model as model_lib
from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the cell runs; otherwise why it is skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 524288-token dense attention is "
                "quadratic; long_500k assigned to SSM/hybrid archs only")
    return None


def _mrope(cfg: ModelConfig) -> bool:
    return any(s.kind == "attn" and s.rope == "mrope"
               for _, _, _, s in cfg.sublayers())


def _seq_split(cfg: ModelConfig, seq: int):
    """(vision_seq, text_seq) for VLM inputs; (0, seq) otherwise."""
    if cfg.modality != "vlm":
        return 0, seq
    sv = int(seq * cfg.vision_frac) // 8 * 8
    return sv, seq - sv


def token_inputs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for a full-sequence step (train / prefill)."""
    B, S = shape.global_batch, shape.seq_len
    sv, st = _seq_split(cfg, S)
    out = {"tokens": jax.ShapeDtypeStruct((B, st), jnp.int32)}
    if cfg.modality == "vlm":
        out["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, sv, cfg.d_model), jnp.bfloat16)
        out["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    elif _mrope(cfg):
        out["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if cfg.encoder is not None:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    """Abstract KV/state cache for serving cells (no allocation)."""
    return jax.eval_shape(
        lambda: model_lib.init_cache(cfg, batch, max_len, dtype))


def decode_inputs(cfg: ModelConfig, shape: ShapeSpec,
                  kv_dtype=jnp.bfloat16):
    """(token, cache, index) abstract inputs for one decode step with a
    filled cache of length seq_len."""
    B = shape.global_batch
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache = cache_specs(cfg, B, shape.seq_len, dtype=kv_dtype)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return token, cache, index


def concrete_batch(cfg: ModelConfig, batch: int, seq: int, key=None):
    """Small *concrete* batch for smoke tests / examples."""
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    sv, st = _seq_split(cfg, seq)
    out = {"tokens": jax.random.randint(ks[0], (batch, st), 0, cfg.vocab)}
    if cfg.modality == "vlm":
        out["vision_embeds"] = jax.random.normal(
            ks[1], (batch, sv, cfg.d_model), jnp.bfloat16) * 0.02
        import numpy as np
        pos = np.broadcast_to(np.arange(seq)[None], (batch, seq))
        out["positions"] = jnp.asarray(
            np.broadcast_to(pos[None], (3, batch, seq)))
    if cfg.encoder is not None:
        out["frames"] = jax.random.normal(
            ks[2], (batch, cfg.encoder.n_frames, cfg.d_model),
            jnp.bfloat16) * 0.02
    return out
