"""xlstm-350m [arXiv:2405.04517].

24 blocks, d_model=1024, 4 heads, alternating mLSTM / sLSTM (the paper's
mixed-stack variant), vocab 50304. No separate FFN (d_ff=0): the blocks
carry their own up/down projections. Fully recurrent -> runs long_500k.
"""
from ..models.config import MLstmSpec, ModelConfig, SLstmSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        d_model=1024, vocab=50304, n_groups=12,
        pattern=((MLstmSpec(n_heads=4),), (SLstmSpec(n_heads=4),)),
        max_seq=524288, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=((MLstmSpec(n_heads=2, chunk=16),),
                 (SLstmSpec(n_heads=2),)),
        max_seq=128, tie_embeddings=True,
    )
