"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Maverick family].

48L, d_model=5120, 40 heads / 8 kv (GQA), head_dim=128, d_ff=8192, vocab
202048. MoE on alternating layers (interleave step 2, as in Maverick):
128 experts top-1 plus an always-on shared expert; dense FFN on the other
layers. Expressed as a 2-layer group scanned 24x.
"""
from ..models.config import AttnSpec, FfnSpec, ModelConfig, MoeSpec

_ATTN = dict(n_heads=40, n_kv=8, head_dim=128)


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        d_model=5120, vocab=202048, n_groups=24,
        pattern=(
            (AttnSpec(**_ATTN), FfnSpec(d_ff=8192)),
            (AttnSpec(**_ATTN),
             MoeSpec(n_experts=128, top_k=1, d_ff=8192, shared_d_ff=8192)),
        ),
        max_seq=32768, rope_theta=5e5, tie_embeddings=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b-reduced",
        d_model=64, vocab=512, n_groups=2,
        pattern=(
            (AttnSpec(n_heads=4, n_kv=2, head_dim=16), FfnSpec(d_ff=128)),
            (AttnSpec(n_heads=4, n_kv=2, head_dim=16),
             MoeSpec(n_experts=4, top_k=1, d_ff=128, shared_d_ff=128)),
        ),
        max_seq=128, rope_theta=1e4, tie_embeddings=False,
    )
