"""Autopilot serving benchmark: break-even admission vs static placement.

Replays a scenario trace (`autopilot.traces`) against a capacity-bound
`TieredStore` on the virtual clock under three placement policies:

  * ``economic``  — `EconomicGate`: admission/demotion by tracked reuse
                    interval vs the calibrated break-even threshold;
  * ``dram``      — admit everything to DRAM, capacity pressure evicts
                    (the LRU-ish seed behavior);
  * ``flash``     — keep everything on flash, every access pays the
                    queueing-aware fetch.

Each access is demand-driven (the restore stalls until served — the
admission question is exactly about which accesses may stall), and each
step then advances the clock by `step_time` of modeled decode compute.

Modeled $/token prices what the placement actually consumed, in the
paper's normalized units (NAND die == 1, capital cost == rent rate):

  * DRAM rent        resident byte-seconds x alpha_h_dram/c_h_dram_die
  * DRAM wire        tier bytes moved x alpha_h_dram/b_h_dram_die
  * flash IO         4KiB pages moved x ssd.cost/iops_ssd_peak(4KiB)
  * host CPU         IOs x alpha_core/iops_core
  * stall            stall seconds x alpha_accel — rent of the serving
                     resource a demand miss idles, in the same
                     capital-as-rent units as alpha_core (default 4.0:
                     roughly one GPU-host core-equivalent per stream)

so always-DRAM pays rent for squatters, always-flash pays stalled
accelerator time, and the gate pays only for what clears break-even.
The gate's threshold prices the miss the same way the cost model does
(`from_break_even(alpha_stall=..., fetch_seconds=...)`), so admission
and accounting agree on what a stall is worth.
The win criterion per scenario is the acceptance bound: the gate's
$/token must not exceed the best static baseline's while its per-token
stall does not exceed that same baseline's.

Everything runs on one `VirtualClock` with seeded traces and the
bit-exact numpy sketch path, so the emitted JSON is byte-identical
across runs (CI diffs two `--smoke` runs of
`benchmarks/serving_autopilot.py`).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..core.economics import GPU_GDDR, HostConfig
from ..core.policy import Tier, TieringPolicy
from ..core.ssd_model import SsdConfig, iops_ssd_peak, storage_next_ssd
from ..runtime.clock import VirtualClock
from ..runtime.service import SsdQueueModel
from ..runtime.tiers import TierSpec, TieredStore
from .advisor import ProvisionAdvisor
from .gate import EconomicGate
from .traces import SCENARIOS, generate

MODES = ("economic", "dram", "flash")

PAGE_BYTES = 4096               # flash IO accounting granularity


def pricing_rates(host: HostConfig, ssd: SsdConfig) -> Dict[str, float]:
    """The modeled $/unit rates (normalized units: NAND die == 1,
    capital == rent) every cost-reporting bench shares — one place, so
    the admission benchmark and the autoscale benchmark stay
    comparable: DRAM rent per byte-second, DRAM wire per byte moved,
    flash IO per `PAGE_BYTES` page, host CPU per IO."""
    return {
        "rent_rate": host.alpha_h_dram / host.c_h_dram_die,
        "dram_wire_rate": host.alpha_h_dram / host.b_h_dram_die,
        "page_io_cost": ssd.cost / float(iops_ssd_peak(ssd, PAGE_BYTES)),
        "host_io_cost": host.alpha_core / host.iops_core,
    }


def _policy_for(mode: str, host: HostConfig, ssd: SsdConfig, l_blk: int,
                alpha_accel: float, sim_cfg):
    if mode == "economic":
        # the threshold prices the miss fully: SSD IO + the engine
        # stalled for the modeled demand-fetch time (AI-era Eq. 1)
        fetch = SsdQueueModel.shared(sim_cfg).service(l_blk, 1).total
        return EconomicGate.from_break_even(
            host, ssd, l_blk, alpha_stall=alpha_accel,
            fetch_seconds=fetch)
    if mode == "dram":
        # everything wants DRAM; only capacity pressure demotes
        return TieringPolicy(tau_hot=1e-12, tau_be=1e12)
    if mode == "flash":
        # everything belongs on flash (the pinned-flash bench policy)
        return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)
    raise ValueError(f"unknown mode {mode!r}; one of {MODES}")


def run_scenario(scenario: str, mode: str, *,
                 n_steps: int = 240,
                 step_time: float = 0.25,
                 l_blk: int = 128 << 10,
                 tokens_per_step: int = 16,
                 dram_frac: float = 0.35,
                 alpha_accel: float = 4.0,
                 host: HostConfig = GPU_GDDR,
                 ssd: Optional[SsdConfig] = None,
                 seed: int = 0,
                 sim_cfg=None,
                 obs=None) -> Dict[str, object]:
    """One (scenario, policy) cell; returns a JSON-ready record.

    `obs` (a `repro.obs.Observability`) attaches the observability
    plane: transfer spans, stall attribution and gate-decision instants
    land in its tracer/metrics/ledger. The modeled record is identical
    with or without it."""
    ssd = ssd or storage_next_ssd()
    trace = generate(scenario, n_steps=n_steps, step_time=step_time,
                     seed=seed)
    n_keys = len(trace.distinct_keys())
    total_bytes = n_keys * l_blk
    # DRAM is provisioned as a fraction of the *recurring* working set
    # (keys touched more than once): one-touch flood keys must not
    # inflate the capacity they are attacking
    counts: Dict[tuple, int] = {}
    for step in trace.steps:
        for key in step:
            counts[key] = counts.get(key, 0) + 1
    recurring_bytes = sum(1 for c in counts.values() if c > 1) * l_blk
    specs = {
        Tier.HBM: TierSpec(2 * l_blk, 819e9, 1e-7),
        Tier.DRAM: TierSpec(max(dram_frac * recurring_bytes, 2 * l_blk),
                            45e9, 5e-7),
        Tier.FLASH: TierSpec(max(64 * total_bytes, 1 << 30), 7e9, 2e-5),
    }
    policy = _policy_for(mode, host, ssd, l_blk, alpha_accel, sim_cfg)
    if obs is not None and hasattr(policy, "obs"):
        policy.obs = obs
    clock = VirtualClock()
    store = TieredStore(policy, specs=specs, clock=clock, sim_cfg=sim_cfg,
                        obs=obs, label=f"{scenario}/{mode}")
    blob = np.zeros(max(l_blk // 4, 1), np.float32)
    put_tier = Tier.FLASH if mode == "flash" else Tier.DRAM

    total_stall = 0.0
    first_touches = 0
    byte_seconds = {Tier.HBM: 0.0, Tier.DRAM: 0.0}
    last_t = clock.now()
    for step in trace.steps:
        for key in step:
            if store.tier_of(key) is None:
                store.put(key, blob, tier=put_tier)
                first_touches += 1
            else:
                t0 = clock.now()
                store.get(key)
                total_stall += clock.now() - t0
        clock.advance(step_time)
        now = clock.now()
        dt = now - last_t
        for t in byte_seconds:
            byte_seconds[t] += store.used_bytes(t) * dt
        last_t = now
    horizon = clock.now()
    store.runtime.drain()
    store.flush_deferred_writes()

    # ----------------------------------------------------------- cost model
    rates = pricing_rates(host, ssd)
    rent_rate = rates["rent_rate"]                         # $/(B*s)
    dram_wire_rate = rates["dram_wire_rate"]               # $/B
    page_io_cost = rates["page_io_cost"]
    host_io_cost = rates["host_io_cost"]

    q = store.runtime.qstats
    flash_pages = -(-q[Tier.FLASH].bytes_moved // PAGE_BYTES)
    dram_bytes_moved = q[Tier.DRAM].bytes_moved + q[Tier.HBM].bytes_moved
    total_ios = sum(s.submitted for s in q.values())

    tokens = trace.n_steps * tokens_per_step
    cost = {
        "dram_rent": byte_seconds[Tier.DRAM] * rent_rate
        + byte_seconds[Tier.HBM] * 4.0 * rent_rate,
        "dram_wire": dram_bytes_moved * dram_wire_rate,
        "flash_io": flash_pages * page_io_cost,
        "host_cpu": total_ios * host_io_cost,
        "stall": total_stall * alpha_accel,
    }
    total_cost = sum(cost.values())

    flash = store.stats[Tier.FLASH]
    out: Dict[str, object] = {
        "scenario": scenario,
        "mode": mode,
        "tokens": float(tokens),
        "accesses": float(trace.accesses),
        "first_touches": float(first_touches),
        "horizon": float(horizon),
        "total_stall": float(total_stall),
        "per_token_stall": float(total_stall / max(tokens, 1)),
        "cost_total": float(total_cost),
        "cost_per_token": float(total_cost / max(tokens, 1)),
        "dram_resident_mib_mean": float(
            byte_seconds[Tier.DRAM] / max(horizon, 1e-12) / 2**20),
        "flash_reads": float(flash.bytes_read),
        "promotions": float(sum(s.promotions for s in
                                store.stats.values())),
        "demotions": float(sum(s.demotions for s in
                               store.stats.values())),
    }
    out.update({f"cost_{k}": float(v) for k, v in cost.items()})
    if mode == "economic":
        gs = policy.gate_stats
        out["gate"] = {
            "tau_be": float(policy.tau_be),
            "admits_dram": float(gs.admits_dram),
            "admits_flash": float(gs.admits_flash),
            "readmits_measured": float(gs.readmits_measured),
            "prior_decisions": float(gs.prior_decisions),
            "cold_defaults": float(gs.cold_defaults),
        }
        advisor = ProvisionAdvisor(host, ssd, l_blk)
        out["advice"] = _json_safe(
            advisor.advise(policy.tracker, store=store).as_dict())
    return out


def _json_safe(obj):
    """inf/nan are not valid JSON: encode as strings, recurse."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return repr(obj)
    return obj


def compare_scenario(scenario: str, **kw) -> Dict[str, object]:
    """All three modes on one scenario + the acceptance verdict: the
    gate wins when its $/token does not exceed the best static
    baseline's and its per-token stall does not exceed that same
    baseline's."""
    runs = {mode: run_scenario(scenario, mode, **kw) for mode in MODES}
    static = min(("dram", "flash"),
                 key=lambda m: runs[m]["cost_per_token"])
    gate, best = runs["economic"], runs[static]
    eps = 1e-12
    wins = (gate["cost_per_token"] <= best["cost_per_token"] + eps
            and gate["per_token_stall"] <= best["per_token_stall"] + eps)
    return {
        "scenario": scenario,
        "runs": runs,
        "best_static": static,
        "cost_ratio_vs_best_static": float(
            gate["cost_per_token"] / max(best["cost_per_token"], 1e-30)),
        "gate_wins": bool(wins),
    }


def run_suite(scenarios=SCENARIOS, **kw) -> Dict[str, object]:
    cells = [compare_scenario(s, **kw) for s in scenarios]
    return {
        "scenarios": cells,
        "wins": int(sum(c["gate_wins"] for c in cells)),
        "cells": len(cells),
    }
