"""ProvisionAdvisor — live, trace-driven provisioning guidance.

`core.platform.analyze_platform` answers the paper's §V questions for an
*assumed* (log-normal) workload. The advisor answers them for the
workload the runtime actually served: it consumes the ReuseTracker's
decayed per-class interval histograms (what reuse intervals really look
like right now), the store/fabric's `TierStats` (what the tiers really
did), and any `RebalanceStats` (what elasticity really cost), and emits
the same kind of actionable output — the economically-hot working set,
the DRAM:flash split to provision, a host count, and whether the
deployment is capacity- or bandwidth-limited per `core.workload`'s
T_B/T_S/T_C thresholds.

The bridge is `EmpiricalWorkload`: each class's histogram expands into a
weighted interval sample (bucket-center resolution), samples are scaled
so classes contribute proportionally to their resident key census, and
the §V threshold machinery runs unchanged on the result.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.economics import HostConfig, break_even_for_ssd
from ..core.ssd_model import SsdConfig, iops_ssd_peak
from ..core.workload import EmpiricalWorkload, thresholds
from ..core.policy import Tier
from .gate import default_classify
from .reuse import ReuseTracker


@dataclasses.dataclass
class ProvisionAdvice:
    tau_be: float                   # calibrated break-even (s)
    horizon: float                  # seconds of trace the stats cover
    resident_bytes: float           # unique payload across tiers
    dram_capacity: float
    dram_used: float
    hot_bytes: float                # economically-hot set |S(tau_be)|*l
    hot_fraction: float             # hot_bytes / resident_bytes
    recommended_dram_bytes: float   # provision target for DRAM
    recommended_hosts: int
    t_b: float                      # DRAM-bandwidth threshold
    t_s: float                      # SSD-bandwidth threshold
    t_c: float                      # DRAM-capacity threshold
    limit: str                      # capacity | dram-bandwidth |
    #                                 ssd-bandwidth | none
    verdict: str
    classes: Dict[str, Dict[str, float]]
    rebalance: Optional[Dict[str, float]] = None

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    def report(self) -> str:
        lines = [
            f"tau_be={self.tau_be:.3f}s  horizon={self.horizon:.1f}s  "
            f"resident={self.resident_bytes/2**20:.1f}MiB",
            f"hot set {self.hot_bytes/2**20:.1f}MiB "
            f"({self.hot_fraction*100:.0f}% of resident) -> provision "
            f"DRAM {self.recommended_dram_bytes/2**20:.1f}MiB "
            f"across {self.recommended_hosts} host(s) "
            f"(now: {self.dram_used/2**20:.1f}/"
            f"{self.dram_capacity/2**20:.1f}MiB)",
            f"T_B={self.t_b:.3g}s T_S={self.t_s:.3g}s T_C={self.t_c:.3g}s"
            f"  limit={self.limit}",
        ]
        for cls, row in self.classes.items():
            med = row["median_interval"]
            med_s = f"{med:.3f}s" if med == med else "unmeasured"
            lines.append(
                f"  class {cls:12s} keys={int(row['keys']):5d} "
                f"median={med_s:>10s} hot={row['hot_fraction']*100:5.1f}%")
        if self.rebalance:
            lines.append(
                f"  rebalance: {int(self.rebalance['events'])} event(s), "
                f"{self.rebalance['bytes_moved']/2**20:.1f}MiB moved "
                f"({self.rebalance['moved_fraction']*100:.1f}% of "
                f"resident)")
        lines.append(f"VERDICT: {self.verdict}")
        return "\n".join(lines)


class ProvisionAdvisor:
    def __init__(self, host: HostConfig, ssd: SsdConfig, l_blk: float, *,
                 gamma_rw: float = 9.0, phi_wa: float = 3.0,
                 dram_bytes_per_host: Optional[float] = None,
                 headroom: float = 1.25, classify=default_classify,
                 active_window: Optional[float] = None):
        self.host = host
        self.ssd = ssd
        self.l_blk = float(l_blk)
        self.gamma_rw = gamma_rw
        self.phi_wa = phi_wa
        self.dram_bytes_per_host = dram_bytes_per_host
        self.headroom = headroom        # provision above the hot set
        self.classify = classify
        # staleness horizon for the hot set: a resident key untouched
        # for longer than this (per the tracker's ghost) is excluded
        # from the hot-byte census — without it, yesterday's pool keeps
        # the recommendation pinned at peak after a diurnal shift,
        # because the interval *distribution* stays hot while the keys
        # carrying it go cold. None keeps the census-wide behavior.
        if active_window is not None and active_window <= 0:
            raise ValueError("active_window must be positive seconds")
        self.active_window = active_window
        self.tau_be = float(break_even_for_ssd(
            host, ssd, l_blk, gamma_rw=gamma_rw, phi_wa=phi_wa))

    # ----------------------------------------------------------------- util
    def _census(self, stores, tracker: Optional[ReuseTracker] = None,
                now: Optional[float] = None
                ) -> Dict[str, Dict[str, float]]:
        """Per-class resident key/byte counts (one copy per key).
        `active_bytes` restricts to keys touched within `active_window`
        of `now` (per the tracker's ghost); with no window every
        resident byte is active."""
        seen: Dict[object, int] = {}
        for store in stores:
            for key in store.keys():
                if key not in seen:
                    seen[key] = store.nbytes_of(key)
        census: Dict[str, Dict[str, float]] = {}
        for key, nbytes in seen.items():
            row = census.setdefault(self.classify(key),
                                    {"keys": 0.0, "bytes": 0.0,
                                     "active_bytes": 0.0})
            row["keys"] += 1
            row["bytes"] += nbytes
            active = True
            if (self.active_window is not None and tracker is not None
                    and now is not None):
                last = tracker.last_seen(key)
                active = (last is not None
                          and now - last <= self.active_window)
            if active:
                row["active_bytes"] += nbytes
        return census

    # ----------------------------------------------------------------- main
    def advise(self, tracker: ReuseTracker, store=None, fabric=None,
               horizon: Optional[float] = None) -> ProvisionAdvice:
        """Guidance from live state: pass a single `TieredStore` or a
        `ShardedTieredStore` fabric (its per-host stores aggregate)."""
        if (store is None) == (fabric is None):
            raise ValueError("pass exactly one of store= or fabric=")
        stores = [store] if store is not None else \
            list(fabric.hosts.values())
        clock = stores[0].clock
        horizon = clock.now() if horizon is None else float(horizon)

        census = self._census(stores, tracker=tracker, now=horizon)
        resident = sum(row["bytes"] for row in census.values())
        dram_cap = sum(s.specs[Tier.DRAM].capacity_bytes for s in stores)
        dram_used = sum(s.used_bytes(Tier.DRAM) for s in stores)

        # per-class hot fractions + a census-weighted combined workload
        classes: Dict[str, Dict[str, float]] = {}
        samples: List[np.ndarray] = []
        for cls, row in sorted(census.items()):
            sample = tracker.interval_samples(cls, max_samples=256)
            if sample.size:
                wl = EmpiricalWorkload(sample, l_blk=self.l_blk,
                                       n_blk=row["keys"])
                hot = float(wl.cached_block_fraction(self.tau_be))
                median = float(np.median(sample))
                # class contributes samples proportional to its keys
                reps = max(1, int(round(row["keys"])))
                idx = (np.arange(reps) * sample.size // reps)
                samples.append(sample[idx % sample.size])
            else:
                # no measured reuse: economically cold by default
                hot, median = 0.0, float("nan")
                samples.append(np.full(max(1, int(row["keys"])),
                                       self.tau_be * 64.0))
            classes[cls] = {"keys": row["keys"], "bytes": row["bytes"],
                            "median_interval": median,
                            "hot_fraction": hot}
            if self.active_window is not None:
                classes[cls]["active_bytes"] = row["active_bytes"]

        # hot bytes scale the *active* census when a staleness window is
        # set (keys untouched past it are squatters, not hot set)
        hot_bytes = sum(
            census[cls]["active_bytes" if self.active_window is not None
                        else "bytes"] * row["hot_fraction"]
            for cls, row in classes.items())
        target = hot_bytes * self.headroom

        if samples:
            combined = EmpiricalWorkload(
                np.concatenate(samples), l_blk=self.l_blk,
                n_blk=sum(r["keys"] for r in census.values()))
            b_dram = sum(s.specs[Tier.DRAM].read_bw for s in stores)
            b_ssd = sum(s.specs[Tier.FLASH].read_bw for s in stores)
            th = thresholds(combined, b_dram, b_ssd, c_dram=dram_cap)
            t_b, t_s, t_c = th.t_b, th.t_s, th.t_c
            if not th.viable:
                limit = "capacity" if t_c < th.t_v else "none"
            elif t_b >= t_s and t_b > self.tau_be:
                limit = "dram-bandwidth"
            elif t_s > t_b and t_s > self.tau_be:
                limit = "ssd-bandwidth"
            elif self.tau_be > t_c:
                limit = "capacity"
            else:
                limit = "none"
        else:
            t_b = t_s = t_c = float("nan")
            limit = "none"

        per_host = self.dram_bytes_per_host or (dram_cap /
                                                max(len(stores), 1))
        hosts = max(1, int(np.ceil(target / max(per_host, 1.0))))

        rebalance = None
        if fabric is not None and fabric.rebalances:
            moved = float(sum(rb.bytes_moved for rb in fabric.rebalances))
            rebalance = {
                "events": float(len(fabric.rebalances)),
                "bytes_moved": moved,
                "moved_fraction": moved / max(resident, 1.0),
            }

        verdict = self._verdict(limit, target, dram_cap, hosts,
                                len(stores))
        return ProvisionAdvice(
            tau_be=self.tau_be, horizon=horizon,
            resident_bytes=float(resident), dram_capacity=float(dram_cap),
            dram_used=float(dram_used), hot_bytes=float(hot_bytes),
            hot_fraction=float(hot_bytes / max(resident, 1.0)),
            recommended_dram_bytes=float(target),
            recommended_hosts=hosts, t_b=float(t_b), t_s=float(t_s),
            t_c=float(t_c), limit=limit, verdict=verdict,
            classes=classes, rebalance=rebalance)

    def _verdict(self, limit: str, target: float, dram_cap: float,
                 hosts: int, cur_hosts: int) -> str:
        if limit == "capacity":
            return ("capacity-limited: the measured hot set does not fit "
                    "DRAM; add DRAM or hosts before faster devices")
        if limit == "dram-bandwidth":
            return ("dram-bandwidth-limited: the miss path saturates "
                    "DRAM before capacity matters; faster memory, not "
                    "more of it")
        if limit == "ssd-bandwidth":
            return ("ssd-bandwidth-limited: the uncached stream exceeds "
                    "flash throughput; add SSDs or spread shards wider")
        if target > dram_cap:
            return (f"provision up: grow DRAM to the measured hot set "
                    f"({hosts} host(s) at current per-host capacity)")
        if hosts < cur_hosts:
            return (f"provision down: the measured hot set fits "
                    f"{hosts} host(s); the fleet is over-provisioned")
        return ("operate at tau_be: current provisioning matches the "
                "measured hot set")
