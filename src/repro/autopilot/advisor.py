"""ProvisionAdvisor — live, trace-driven provisioning guidance.

`core.platform.analyze_platform` answers the paper's §V questions for an
*assumed* (log-normal) workload. The advisor answers them for the
workload the runtime actually served: it consumes the ReuseTracker's
decayed per-class interval histograms (what reuse intervals really look
like right now), the store/fabric's `TierStats` (what the tiers really
did), and any `RebalanceStats` (what elasticity really cost), and emits
the same kind of actionable output — the economically-hot working set,
the DRAM:flash split to provision, a host count, and whether the
deployment is capacity- or bandwidth-limited per `core.workload`'s
T_B/T_S/T_C thresholds.

The bridge is `EmpiricalWorkload`: each class's histogram expands into a
weighted interval sample (bucket-center resolution), samples are scaled
so classes contribute proportionally to their resident key census, and
the §V threshold machinery runs unchanged on the result.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.economics import (HostConfig, break_even_for_ssd,
                              pool_flash_crossover)
from ..core.ssd_model import SsdConfig, iops_ssd_peak
from ..core.workload import EmpiricalWorkload, thresholds
from ..core.policy import Tier
from .gate import default_classify
from .reuse import ReuseTracker


@dataclasses.dataclass
class ProvisionAdvice:
    tau_be: float                   # calibrated break-even (s)
    horizon: float                  # seconds of trace the stats cover
    resident_bytes: float           # unique payload across tiers
    dram_capacity: float
    dram_used: float
    hot_bytes: float                # economically-hot set |S(tau_be)|*l
    hot_fraction: float             # hot_bytes / resident_bytes
    recommended_dram_bytes: float   # provision target for DRAM
    recommended_hosts: int
    t_b: float                      # DRAM-bandwidth threshold
    t_s: float                      # SSD-bandwidth threshold
    t_c: float                      # DRAM-capacity threshold
    limit: str                      # capacity | dram-bandwidth |
    #                                 ssd-bandwidth | none
    verdict: str
    classes: Dict[str, Dict[str, float]]
    rebalance: Optional[Dict[str, float]] = None

    @property
    def bandwidth_limited(self) -> bool:
        """True when the binding constraint is a *bandwidth* threshold
        (T_B: DRAM wire, T_S: SSD lanes) rather than capacity — more
        bytes on the same hosts won't help; more hosts (more spindles
        and DRAM channels) will. `Autoscaler` folds this verdict into
        its add/remove decisions."""
        return self.limit in ("dram-bandwidth", "ssd-bandwidth")

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    def report(self) -> str:
        lines = [
            f"tau_be={self.tau_be:.3f}s  horizon={self.horizon:.1f}s  "
            f"resident={self.resident_bytes/2**20:.1f}MiB",
            f"hot set {self.hot_bytes/2**20:.1f}MiB "
            f"({self.hot_fraction*100:.0f}% of resident) -> provision "
            f"DRAM {self.recommended_dram_bytes/2**20:.1f}MiB "
            f"across {self.recommended_hosts} host(s) "
            f"(now: {self.dram_used/2**20:.1f}/"
            f"{self.dram_capacity/2**20:.1f}MiB)",
            f"T_B={self.t_b:.3g}s T_S={self.t_s:.3g}s T_C={self.t_c:.3g}s"
            f"  limit={self.limit}",
        ]
        for cls, row in self.classes.items():
            med = row["median_interval"]
            med_s = f"{med:.3f}s" if med == med else "unmeasured"
            lines.append(
                f"  class {cls:12s} keys={int(row['keys']):5d} "
                f"median={med_s:>10s} hot={row['hot_fraction']*100:5.1f}%")
        if self.rebalance:
            lines.append(
                f"  rebalance: {int(self.rebalance['events'])} event(s), "
                f"{self.rebalance['bytes_moved']/2**20:.1f}MiB moved "
                f"({self.rebalance['moved_fraction']*100:.1f}% of "
                f"resident)")
        lines.append(f"VERDICT: {self.verdict}")
        return "\n".join(lines)


@dataclasses.dataclass
class AvailabilityAdvice:
    """Replication-factor recommendation: availability priced in $/s.

    `arms` maps each candidate replication factor r to its modeled
    cost-rate breakdown (NAND-die-normalized $ per second, the same
    units every cost-reporting bench uses):

      * rent   — extra DRAM byte-seconds for the r-1 replica copies
      * write  — extra wire + flash-page cost for streaming r-1 copies
                 on every put
      * repair — expected re-replication traffic after failures
                 (failure rate x bytes to re-stream per failure)
      * loss   — expected failure stall: with r=1 the dead host's
                 resident bytes are *gone* and must be recomputed /
                 re-ingested while the serving resource stalls;
                 replication converts this to a degraded read
    """
    mttf: float                     # per-host mean time to failure (s)
    failure_rate: float             # expected host failures / s (fleet)
    resident_bytes: float
    n_hosts: int
    recommended_replicas: int
    arms: Dict[int, Dict[str, float]]
    verdict: str

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        # JSON object keys are strings; keep the emitted dict stable
        d["arms"] = {str(r): row for r, row in sorted(self.arms.items())}
        return d

    def report(self) -> str:
        lines = [f"mttf={self.mttf:.0f}s/host  "
                 f"fleet failure rate={self.failure_rate:.2e}/s  "
                 f"resident={self.resident_bytes/2**20:.1f}MiB "
                 f"on {self.n_hosts} host(s)"]
        for r, row in sorted(self.arms.items()):
            tag = " <- recommended" if r == self.recommended_replicas \
                else ""
            lines.append(
                f"  r={r}: total={row['total']:.3e}/s  "
                f"(rent={row['rent']:.2e} write={row['write']:.2e} "
                f"repair={row['repair']:.2e} loss={row['loss']:.2e})"
                f"{tag}")
        lines.append(f"VERDICT: {self.verdict}")
        return "\n".join(lines)


@dataclasses.dataclass
class TierAdvice:
    """Fourth-tier recommendation: which hierarchy shape to deploy.

    `arms` maps each candidate shape to its modeled miss-path cost rate
    (NAND-die-normalized $ per second — DRAM rent for the locally-hot
    set is identical across arms and omitted):

      * baseline  — 3 tiers; every DRAM miss is a host-CPU flash IO
      * gpu_flash — misses ride the BaM submission engine (no host-CPU
                    or host-DRAM-wire rent, deeper device queue)
      * pool      — the pool band (tau_be <= interval < tau_pool) moves
                    to the fleet pool at discounted rent + an RTT lane;
                    the rest stays on the host flash path
      * both      — pool band pooled, residual misses gpu-direct

    Each row: io (wire + media + host/submit $), pool_rent (discounted
    DRAM-class rent on pooled bytes), stall (alpha_stall x modeled
    stall seconds), total, and stall_seconds (unpriced, per second of
    serving — the bench's equal-or-lower-stall check reads this).
    """
    tau_be: float
    tau_pool: float
    access_rate: float
    resident_bytes: float
    miss_fraction: float            # accesses priced out of local DRAM
    pool_band_fraction: float       # fraction of *misses* in the band
    arms: Dict[str, Dict[str, float]]
    recommended_arm: str
    verdict: str

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def report(self) -> str:
        lines = [f"tau_be={self.tau_be:.3f}s  tau_pool={self.tau_pool:.3f}s"
                 f"  miss={self.miss_fraction*100:.1f}%  "
                 f"pool band={self.pool_band_fraction*100:.1f}% of misses"]
        for arm in ("baseline", "gpu_flash", "pool", "both"):
            row = self.arms[arm]
            tag = " <- recommended" if arm == self.recommended_arm else ""
            lines.append(
                f"  {arm:9s}: total={row['total']:.3e}/s  "
                f"(io={row['io']:.2e} rent={row['pool_rent']:.2e} "
                f"stall={row['stall']:.2e}; "
                f"{row['stall_seconds']*1e3:.3f}ms stall/s){tag}")
        lines.append(f"VERDICT: {self.verdict}")
        return "\n".join(lines)


class ProvisionAdvisor:
    def __init__(self, host: HostConfig, ssd: SsdConfig, l_blk: float, *,
                 gamma_rw: float = 9.0, phi_wa: float = 3.0,
                 dram_bytes_per_host: Optional[float] = None,
                 headroom: float = 1.25, classify=default_classify,
                 active_window: Optional[float] = None):
        self.host = host
        self.ssd = ssd
        self.l_blk = float(l_blk)
        self.gamma_rw = gamma_rw
        self.phi_wa = phi_wa
        self.dram_bytes_per_host = dram_bytes_per_host
        self.headroom = headroom        # provision above the hot set
        self.classify = classify
        # staleness horizon for the hot set: a resident key untouched
        # for longer than this (per the tracker's ghost) is excluded
        # from the hot-byte census — without it, yesterday's pool keeps
        # the recommendation pinned at peak after a diurnal shift,
        # because the interval *distribution* stays hot while the keys
        # carrying it go cold. None keeps the census-wide behavior.
        if active_window is not None and active_window <= 0:
            raise ValueError("active_window must be positive seconds")
        self.active_window = active_window
        self.tau_be = float(break_even_for_ssd(
            host, ssd, l_blk, gamma_rw=gamma_rw, phi_wa=phi_wa))

    # ----------------------------------------------------------------- util
    def _census(self, stores, tracker: Optional[ReuseTracker] = None,
                now: Optional[float] = None
                ) -> Dict[str, Dict[str, float]]:
        """Per-class resident key/byte counts (one copy per key).
        `active_bytes` restricts to keys touched within `active_window`
        of `now` (per the tracker's ghost); with no window every
        resident byte is active."""
        seen: Dict[object, int] = {}
        for store in stores:
            for key in store.keys():
                if key not in seen:
                    seen[key] = store.nbytes_of(key)
        census: Dict[str, Dict[str, float]] = {}
        for key, nbytes in seen.items():
            row = census.setdefault(self.classify(key),
                                    {"keys": 0.0, "bytes": 0.0,
                                     "active_bytes": 0.0})
            row["keys"] += 1
            row["bytes"] += nbytes
            active = True
            if (self.active_window is not None and tracker is not None
                    and now is not None):
                last = tracker.last_seen(key)
                active = (last is not None
                          and now - last <= self.active_window)
            if active:
                row["active_bytes"] += nbytes
        return census

    # ----------------------------------------------------------------- main
    def advise(self, tracker: ReuseTracker, store=None, fabric=None,
               horizon: Optional[float] = None) -> ProvisionAdvice:
        """Guidance from live state: pass a single `TieredStore` or a
        `ShardedTieredStore` fabric (its per-host stores aggregate)."""
        if (store is None) == (fabric is None):
            raise ValueError("pass exactly one of store= or fabric=")
        stores = [store] if store is not None else \
            list(fabric.hosts.values())
        clock = stores[0].clock
        horizon = clock.now() if horizon is None else float(horizon)

        census = self._census(stores, tracker=tracker, now=horizon)
        resident = sum(row["bytes"] for row in census.values())
        dram_cap = sum(s.specs[Tier.DRAM].capacity_bytes for s in stores)
        dram_used = sum(s.used_bytes(Tier.DRAM) for s in stores)

        # per-class hot fractions + a census-weighted combined workload
        classes: Dict[str, Dict[str, float]] = {}
        samples: List[np.ndarray] = []
        for cls, row in sorted(census.items()):
            sample = tracker.interval_samples(cls, max_samples=256)
            if sample.size:
                wl = EmpiricalWorkload(sample, l_blk=self.l_blk,
                                       n_blk=row["keys"])
                hot = float(wl.cached_block_fraction(self.tau_be))
                median = float(np.median(sample))
                # class contributes samples proportional to its keys
                reps = max(1, int(round(row["keys"])))
                idx = (np.arange(reps) * sample.size // reps)
                samples.append(sample[idx % sample.size])
            else:
                # no measured reuse: economically cold by default
                hot, median = 0.0, float("nan")
                samples.append(np.full(max(1, int(row["keys"])),
                                       self.tau_be * 64.0))
            classes[cls] = {"keys": row["keys"], "bytes": row["bytes"],
                            "median_interval": median,
                            "hot_fraction": hot}
            if self.active_window is not None:
                classes[cls]["active_bytes"] = row["active_bytes"]

        # hot bytes scale the *active* census when a staleness window is
        # set (keys untouched past it are squatters, not hot set)
        hot_bytes = sum(
            census[cls]["active_bytes" if self.active_window is not None
                        else "bytes"] * row["hot_fraction"]
            for cls, row in classes.items())
        target = hot_bytes * self.headroom

        if samples:
            combined = EmpiricalWorkload(
                np.concatenate(samples), l_blk=self.l_blk,
                n_blk=sum(r["keys"] for r in census.values()))
            b_dram = sum(s.specs[Tier.DRAM].read_bw for s in stores)
            b_ssd = sum(s.specs[Tier.FLASH].read_bw for s in stores)
            th = thresholds(combined, b_dram, b_ssd, c_dram=dram_cap)
            t_b, t_s, t_c = th.t_b, th.t_s, th.t_c
            if not th.viable:
                limit = "capacity" if t_c < th.t_v else "none"
            elif t_b >= t_s and t_b > self.tau_be:
                limit = "dram-bandwidth"
            elif t_s > t_b and t_s > self.tau_be:
                limit = "ssd-bandwidth"
            elif self.tau_be > t_c:
                limit = "capacity"
            else:
                limit = "none"
        else:
            t_b = t_s = t_c = float("nan")
            limit = "none"

        per_host = self.dram_bytes_per_host or (dram_cap /
                                                max(len(stores), 1))
        hosts = max(1, int(np.ceil(target / max(per_host, 1.0))))

        rebalance = None
        if fabric is not None and fabric.rebalances:
            moved = float(sum(rb.bytes_moved for rb in fabric.rebalances))
            rebalance = {
                "events": float(len(fabric.rebalances)),
                "bytes_moved": moved,
                "moved_fraction": moved / max(resident, 1.0),
            }

        verdict = self._verdict(limit, target, dram_cap, hosts,
                                len(stores))
        return ProvisionAdvice(
            tau_be=self.tau_be, horizon=horizon,
            resident_bytes=float(resident), dram_capacity=float(dram_cap),
            dram_used=float(dram_used), hot_bytes=float(hot_bytes),
            hot_fraction=float(hot_bytes / max(resident, 1.0)),
            recommended_dram_bytes=float(target),
            recommended_hosts=hosts, t_b=float(t_b), t_s=float(t_s),
            t_c=float(t_c), limit=limit, verdict=verdict,
            classes=classes, rebalance=rebalance)

    # ------------------------------------------------------- availability
    def advise_availability(self, *, fabric=None,
                            resident_bytes: Optional[float] = None,
                            n_hosts: Optional[int] = None,
                            dram_fraction: Optional[float] = None,
                            mttf: float,
                            alpha_stall: float = 4.0,
                            recompute_seconds: float = 1.0,
                            put_bytes_per_second: float = 0.0,
                            max_replicas: int = 3) -> AvailabilityAdvice:
        """Recommend a replication factor the way `advise` recommends a
        DRAM:flash split: price each candidate r and pick the cheapest.

        The availability version of Eq. 1's tradeoff — replication
        *rent* (extra DRAM byte-seconds for the copies, extra wire +
        flash-page writes on every put, expected repair traffic after
        failures) against the expected *failure stall* of running
        unreplicated: a lost object's only copy is gone, so the serving
        resource (priced at `alpha_stall`, the same normalized rent the
        AI-era Eq. 1 correction uses) stalls `recompute_seconds` per
        object to regenerate it — a decode recompute, not an SSD
        re-read, which is exactly why the loss term dwarfs the IO rates
        at serving-scale MTTFs. With a long MTTF the loss term vanishes
        and r=1 wins; as MTTF shrinks the expected stall crosses the
        copy rent and the recommendation steps up — the bench's
        kill-at-peak scenario checks the recommendation against
        measured $/token.

        Pass `fabric=` to census live state, or the explicit scalars."""
        if mttf <= 0:
            raise ValueError("mttf must be positive seconds per host")
        if max_replicas < 1:
            raise ValueError("max_replicas must be >= 1")
        if fabric is not None:
            stores = list(fabric.hosts.values())
            seen: Dict[object, int] = {}
            for s in stores:
                for key in s.keys():
                    seen.setdefault(key, s.nbytes_of(key))
            if resident_bytes is None:
                resident_bytes = float(sum(seen.values()))
            if n_hosts is None:
                n_hosts = fabric.n_hosts
            if dram_fraction is None:
                used = sum(s.used_bytes(Tier.DRAM)
                           + s.used_bytes(Tier.FLASH) for s in stores)
                dram = sum(s.used_bytes(Tier.DRAM) for s in stores)
                dram_fraction = dram / used if used > 0 else 0.0
        if resident_bytes is None or n_hosts is None:
            raise ValueError(
                "pass fabric= or both resident_bytes= and n_hosts=")
        if dram_fraction is None:
            dram_fraction = 0.0
        n_hosts = int(n_hosts)
        if n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")

        # lazy: bench.py imports this module at load time
        from .bench import PAGE_BYTES, pricing_rates
        rates = pricing_rates(self.host, self.ssd)
        lam = n_hosts / mttf            # fleet-wide failures per second
        share = resident_bytes / n_hosts    # bytes lost with one host
        page_rate = rates["page_io_cost"] / PAGE_BYTES  # $ per byte of IO
        wire = rates["dram_wire_rate"]

        arms: Dict[int, Dict[str, float]] = {}
        # a copy set cannot exceed the fleet; candidate arms above
        # n_hosts would silently price the same placement
        r_max = min(max_replicas, n_hosts)
        for r in range(1, r_max + 1):
            rent = (r - 1) * resident_bytes * dram_fraction \
                * rates["rent_rate"]
            write = (r - 1) * put_bytes_per_second * (wire + page_rate)
            if r >= 2:
                # a failure re-streams the dead host's share; the ring
                # shrink also re-targets surviving copy sets, so repair
                # traffic scales with the total copies the host touched
                repair = lam * (r * share) * (wire + 2.0 * page_rate)
                loss = 0.0
            else:
                repair = 0.0
                # sole copies gone: the serving resource stalls
                # `recompute_seconds` per lost object to regenerate the
                # dead host's resident share (share/l_blk objects)
                loss = lam * (share / self.l_blk) \
                    * recompute_seconds * alpha_stall
            arms[r] = {"rent": float(rent), "write": float(write),
                       "repair": float(repair), "loss": float(loss),
                       "total": float(rent + write + repair + loss)}

        recommended = min(sorted(arms),
                          key=lambda r: (arms[r]["total"], r))
        if recommended == 1:
            verdict = ("run unreplicated: at this MTTF the expected "
                       "failure stall is cheaper than copy rent")
        else:
            verdict = (f"replicate x{recommended}: expected failure "
                       f"stall at mttf={mttf:.0f}s outprices the copy "
                       f"rent + repair traffic")
        return AvailabilityAdvice(
            mttf=float(mttf), failure_rate=float(lam),
            resident_bytes=float(resident_bytes), n_hosts=n_hosts,
            recommended_replicas=int(recommended), arms=arms,
            verdict=verdict)

    # ------------------------------------------------------------ 4th tier
    def advise_tiers(self, tracker: Optional[ReuseTracker] = None, *,
                     access_rate: float, resident_bytes: float,
                     object_bytes: Optional[float] = None,
                     interval_samples: Optional[np.ndarray] = None,
                     pool_bw: float = 40e9, pool_rtt: float = 2e-6,
                     rent_factor: float = 0.5, alpha_net: float = 2.0,
                     alpha_submit: float = 0.5, iops_submit: float = 2e7,
                     submit_latency: float = 3e-6,
                     alpha_stall: float = 4.0,
                     flash_fetch_seconds: Optional[float] = None,
                     gpu_fetch_seconds: Optional[float] = None,
                     max_samples: int = 256) -> TierAdvice:
        """Price the four hierarchy shapes (3-tier baseline, +gpu_flash,
        +pool, +both) against the measured reuse-interval distribution
        and recommend the cheapest.

        The split is Eq. 1 run per column: accesses whose tracked
        interval clears tau_be stay local-DRAM (identical across arms,
        not priced); the rest are the miss stream. Within it, intervals
        under the pool column's tau_pool earn the pool's discounted
        rent instead of a flash IO; gpu_flash reprices the *residual*
        flash IOs by dropping the host-CPU and host-DRAM-wire rent for
        a submission-engine charge. Stall seconds are priced at
        `alpha_stall` exactly like the AI-era tau_be correction.

        Pass `tracker=` for live distributions, or `interval_samples=`
        directly (the tiers bench replays its measured intervals)."""
        if access_rate < 0 or resident_bytes < 0:
            raise ValueError("rates and bytes must be non-negative")
        if (tracker is None) == (interval_samples is None):
            raise ValueError(
                "pass exactly one of tracker= or interval_samples=")
        b = float(object_bytes if object_bytes is not None else self.l_blk)
        if interval_samples is None:
            parts = [tracker.interval_samples(cls, max_samples=max_samples)
                     for cls in tracker.classes]
            parts = [p for p in parts if p.size]
            samples = (np.concatenate(parts) if parts
                       else np.empty(0))
        else:
            samples = np.asarray(interval_samples, dtype=float)

        tau_pool = float(pool_flash_crossover(
            self.host, self.l_blk, self.tau_be, pool_bw=pool_bw,
            pool_rtt=pool_rtt, rent_factor=rent_factor,
            alpha_net=alpha_net))
        if samples.size:
            miss = float(np.mean(samples >= self.tau_be))
            band = float(np.mean((samples >= self.tau_be)
                                 & (samples < tau_pool)))
        else:
            miss, band = 1.0, 0.0       # no evidence: everything cold
        p_band = band / miss if miss > 0 else 0.0

        # modeled demand-fetch times from the calibrated queue models
        # (lazy: runtime imports autopilot at package load)
        from ..runtime.service import GpuDirectQueueModel, SsdQueueModel
        ssd_q = SsdQueueModel.shared()
        if flash_fetch_seconds is None:
            flash_fetch_seconds = float(ssd_q.service(b, 1).total)
        if gpu_fetch_seconds is None:
            gpu_fetch_seconds = float(GpuDirectQueueModel(
                ssd_q, submit_latency=submit_latency).service(b, 1).total)
        pool_fetch_seconds = b / pool_bw + pool_rtt

        from .bench import PAGE_BYTES, pricing_rates
        rates = pricing_rates(self.host, self.ssd)
        page_per_byte = rates["page_io_cost"] / PAGE_BYTES
        # per-access $ on each miss path (host/submit + wire + media)
        flash_access = (rates["host_io_cost"]
                        + rates["dram_wire_rate"] * b + page_per_byte * b)
        gpu_access = alpha_submit / iops_submit + page_per_byte * b
        pool_access = alpha_net * pool_fetch_seconds
        pool_rent = (resident_bytes * band * rates["rent_rate"]
                     * rent_factor)
        miss_rate = access_rate * miss

        def _arm(residual: float, residual_fetch: float,
                 has_pool: bool) -> Dict[str, float]:
            frac = p_band if has_pool else 0.0
            io = miss_rate * ((1.0 - frac) * residual
                              + frac * pool_access)
            stall_s = miss_rate * ((1.0 - frac) * residual_fetch
                                   + frac * pool_fetch_seconds)
            rent = pool_rent if has_pool else 0.0
            stall = alpha_stall * stall_s
            return {"io": float(io), "pool_rent": float(rent),
                    "stall": float(stall), "stall_seconds": float(stall_s),
                    "total": float(io + rent + stall)}

        arms = {
            "baseline": _arm(flash_access, flash_fetch_seconds, False),
            "gpu_flash": _arm(gpu_access, gpu_fetch_seconds, False),
            "pool": _arm(flash_access, flash_fetch_seconds, True),
            "both": _arm(gpu_access, gpu_fetch_seconds, True),
        }
        order = ("baseline", "gpu_flash", "pool", "both")
        recommended = min(order, key=lambda a: (arms[a]["total"],
                                                order.index(a)))
        if recommended == "baseline":
            verdict = ("keep 3 tiers: at this reuse mix neither the BaM "
                       "path nor pooled rent beats host flash IO")
        elif recommended == "gpu_flash":
            verdict = ("add gpu_flash: host-CPU IO rent dominates the "
                       "miss stream; the submission engine removes it")
        elif recommended == "pool":
            verdict = ("add the fleet pool: the pool band's discounted "
                       "rent underprices its flash re-reads")
        else:
            verdict = ("add both: pool the reuse band, ride the BaM "
                       "path for the cold residual")
        return TierAdvice(
            tau_be=float(self.tau_be), tau_pool=tau_pool,
            access_rate=float(access_rate),
            resident_bytes=float(resident_bytes),
            miss_fraction=miss, pool_band_fraction=float(p_band),
            arms=arms, recommended_arm=recommended, verdict=verdict)

    def _verdict(self, limit: str, target: float, dram_cap: float,
                 hosts: int, cur_hosts: int) -> str:
        if limit == "capacity":
            return ("capacity-limited: the measured hot set does not fit "
                    "DRAM; add DRAM or hosts before faster devices")
        if limit == "dram-bandwidth":
            return ("dram-bandwidth-limited: the miss path saturates "
                    "DRAM before capacity matters; faster memory, not "
                    "more of it")
        if limit == "ssd-bandwidth":
            return ("ssd-bandwidth-limited: the uncached stream exceeds "
                    "flash throughput; add SSDs or spread shards wider")
        if target > dram_cap:
            return (f"provision up: grow DRAM to the measured hot set "
                    f"({hosts} host(s) at current per-host capacity)")
        if hosts < cur_hosts:
            return (f"provision down: the measured hot set fits "
                    f"{hosts} host(s); the fleet is over-provisioned")
        return ("operate at tau_be: current provisioning matches the "
                "measured hot set")
