"""ProvisionAdvisor — live, trace-driven provisioning guidance.

`core.platform.analyze_platform` answers the paper's §V questions for an
*assumed* (log-normal) workload. The advisor answers them for the
workload the runtime actually served: it consumes the ReuseTracker's
decayed per-class interval histograms (what reuse intervals really look
like right now), the store/fabric's `TierStats` (what the tiers really
did), and any `RebalanceStats` (what elasticity really cost), and emits
the same kind of actionable output — the economically-hot working set,
the DRAM:flash split to provision, a host count, and whether the
deployment is capacity- or bandwidth-limited per `core.workload`'s
T_B/T_S/T_C thresholds.

The bridge is `EmpiricalWorkload`: each class's histogram expands into a
weighted interval sample (bucket-center resolution), samples are scaled
so classes contribute proportionally to their resident key census, and
the §V threshold machinery runs unchanged on the result.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.economics import HostConfig, break_even_for_ssd
from ..core.ssd_model import SsdConfig, iops_ssd_peak
from ..core.workload import EmpiricalWorkload, thresholds
from ..core.policy import Tier
from .gate import default_classify
from .reuse import ReuseTracker


@dataclasses.dataclass
class ProvisionAdvice:
    tau_be: float                   # calibrated break-even (s)
    horizon: float                  # seconds of trace the stats cover
    resident_bytes: float           # unique payload across tiers
    dram_capacity: float
    dram_used: float
    hot_bytes: float                # economically-hot set |S(tau_be)|*l
    hot_fraction: float             # hot_bytes / resident_bytes
    recommended_dram_bytes: float   # provision target for DRAM
    recommended_hosts: int
    t_b: float                      # DRAM-bandwidth threshold
    t_s: float                      # SSD-bandwidth threshold
    t_c: float                      # DRAM-capacity threshold
    limit: str                      # capacity | dram-bandwidth |
    #                                 ssd-bandwidth | none
    verdict: str
    classes: Dict[str, Dict[str, float]]
    rebalance: Optional[Dict[str, float]] = None

    @property
    def bandwidth_limited(self) -> bool:
        """True when the binding constraint is a *bandwidth* threshold
        (T_B: DRAM wire, T_S: SSD lanes) rather than capacity — more
        bytes on the same hosts won't help; more hosts (more spindles
        and DRAM channels) will. `Autoscaler` folds this verdict into
        its add/remove decisions."""
        return self.limit in ("dram-bandwidth", "ssd-bandwidth")

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    def report(self) -> str:
        lines = [
            f"tau_be={self.tau_be:.3f}s  horizon={self.horizon:.1f}s  "
            f"resident={self.resident_bytes/2**20:.1f}MiB",
            f"hot set {self.hot_bytes/2**20:.1f}MiB "
            f"({self.hot_fraction*100:.0f}% of resident) -> provision "
            f"DRAM {self.recommended_dram_bytes/2**20:.1f}MiB "
            f"across {self.recommended_hosts} host(s) "
            f"(now: {self.dram_used/2**20:.1f}/"
            f"{self.dram_capacity/2**20:.1f}MiB)",
            f"T_B={self.t_b:.3g}s T_S={self.t_s:.3g}s T_C={self.t_c:.3g}s"
            f"  limit={self.limit}",
        ]
        for cls, row in self.classes.items():
            med = row["median_interval"]
            med_s = f"{med:.3f}s" if med == med else "unmeasured"
            lines.append(
                f"  class {cls:12s} keys={int(row['keys']):5d} "
                f"median={med_s:>10s} hot={row['hot_fraction']*100:5.1f}%")
        if self.rebalance:
            lines.append(
                f"  rebalance: {int(self.rebalance['events'])} event(s), "
                f"{self.rebalance['bytes_moved']/2**20:.1f}MiB moved "
                f"({self.rebalance['moved_fraction']*100:.1f}% of "
                f"resident)")
        lines.append(f"VERDICT: {self.verdict}")
        return "\n".join(lines)


@dataclasses.dataclass
class AvailabilityAdvice:
    """Replication-factor recommendation: availability priced in $/s.

    `arms` maps each candidate replication factor r to its modeled
    cost-rate breakdown (NAND-die-normalized $ per second, the same
    units every cost-reporting bench uses):

      * rent   — extra DRAM byte-seconds for the r-1 replica copies
      * write  — extra wire + flash-page cost for streaming r-1 copies
                 on every put
      * repair — expected re-replication traffic after failures
                 (failure rate x bytes to re-stream per failure)
      * loss   — expected failure stall: with r=1 the dead host's
                 resident bytes are *gone* and must be recomputed /
                 re-ingested while the serving resource stalls;
                 replication converts this to a degraded read
    """
    mttf: float                     # per-host mean time to failure (s)
    failure_rate: float             # expected host failures / s (fleet)
    resident_bytes: float
    n_hosts: int
    recommended_replicas: int
    arms: Dict[int, Dict[str, float]]
    verdict: str

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        # JSON object keys are strings; keep the emitted dict stable
        d["arms"] = {str(r): row for r, row in sorted(self.arms.items())}
        return d

    def report(self) -> str:
        lines = [f"mttf={self.mttf:.0f}s/host  "
                 f"fleet failure rate={self.failure_rate:.2e}/s  "
                 f"resident={self.resident_bytes/2**20:.1f}MiB "
                 f"on {self.n_hosts} host(s)"]
        for r, row in sorted(self.arms.items()):
            tag = " <- recommended" if r == self.recommended_replicas \
                else ""
            lines.append(
                f"  r={r}: total={row['total']:.3e}/s  "
                f"(rent={row['rent']:.2e} write={row['write']:.2e} "
                f"repair={row['repair']:.2e} loss={row['loss']:.2e})"
                f"{tag}")
        lines.append(f"VERDICT: {self.verdict}")
        return "\n".join(lines)


class ProvisionAdvisor:
    def __init__(self, host: HostConfig, ssd: SsdConfig, l_blk: float, *,
                 gamma_rw: float = 9.0, phi_wa: float = 3.0,
                 dram_bytes_per_host: Optional[float] = None,
                 headroom: float = 1.25, classify=default_classify,
                 active_window: Optional[float] = None):
        self.host = host
        self.ssd = ssd
        self.l_blk = float(l_blk)
        self.gamma_rw = gamma_rw
        self.phi_wa = phi_wa
        self.dram_bytes_per_host = dram_bytes_per_host
        self.headroom = headroom        # provision above the hot set
        self.classify = classify
        # staleness horizon for the hot set: a resident key untouched
        # for longer than this (per the tracker's ghost) is excluded
        # from the hot-byte census — without it, yesterday's pool keeps
        # the recommendation pinned at peak after a diurnal shift,
        # because the interval *distribution* stays hot while the keys
        # carrying it go cold. None keeps the census-wide behavior.
        if active_window is not None and active_window <= 0:
            raise ValueError("active_window must be positive seconds")
        self.active_window = active_window
        self.tau_be = float(break_even_for_ssd(
            host, ssd, l_blk, gamma_rw=gamma_rw, phi_wa=phi_wa))

    # ----------------------------------------------------------------- util
    def _census(self, stores, tracker: Optional[ReuseTracker] = None,
                now: Optional[float] = None
                ) -> Dict[str, Dict[str, float]]:
        """Per-class resident key/byte counts (one copy per key).
        `active_bytes` restricts to keys touched within `active_window`
        of `now` (per the tracker's ghost); with no window every
        resident byte is active."""
        seen: Dict[object, int] = {}
        for store in stores:
            for key in store.keys():
                if key not in seen:
                    seen[key] = store.nbytes_of(key)
        census: Dict[str, Dict[str, float]] = {}
        for key, nbytes in seen.items():
            row = census.setdefault(self.classify(key),
                                    {"keys": 0.0, "bytes": 0.0,
                                     "active_bytes": 0.0})
            row["keys"] += 1
            row["bytes"] += nbytes
            active = True
            if (self.active_window is not None and tracker is not None
                    and now is not None):
                last = tracker.last_seen(key)
                active = (last is not None
                          and now - last <= self.active_window)
            if active:
                row["active_bytes"] += nbytes
        return census

    # ----------------------------------------------------------------- main
    def advise(self, tracker: ReuseTracker, store=None, fabric=None,
               horizon: Optional[float] = None) -> ProvisionAdvice:
        """Guidance from live state: pass a single `TieredStore` or a
        `ShardedTieredStore` fabric (its per-host stores aggregate)."""
        if (store is None) == (fabric is None):
            raise ValueError("pass exactly one of store= or fabric=")
        stores = [store] if store is not None else \
            list(fabric.hosts.values())
        clock = stores[0].clock
        horizon = clock.now() if horizon is None else float(horizon)

        census = self._census(stores, tracker=tracker, now=horizon)
        resident = sum(row["bytes"] for row in census.values())
        dram_cap = sum(s.specs[Tier.DRAM].capacity_bytes for s in stores)
        dram_used = sum(s.used_bytes(Tier.DRAM) for s in stores)

        # per-class hot fractions + a census-weighted combined workload
        classes: Dict[str, Dict[str, float]] = {}
        samples: List[np.ndarray] = []
        for cls, row in sorted(census.items()):
            sample = tracker.interval_samples(cls, max_samples=256)
            if sample.size:
                wl = EmpiricalWorkload(sample, l_blk=self.l_blk,
                                       n_blk=row["keys"])
                hot = float(wl.cached_block_fraction(self.tau_be))
                median = float(np.median(sample))
                # class contributes samples proportional to its keys
                reps = max(1, int(round(row["keys"])))
                idx = (np.arange(reps) * sample.size // reps)
                samples.append(sample[idx % sample.size])
            else:
                # no measured reuse: economically cold by default
                hot, median = 0.0, float("nan")
                samples.append(np.full(max(1, int(row["keys"])),
                                       self.tau_be * 64.0))
            classes[cls] = {"keys": row["keys"], "bytes": row["bytes"],
                            "median_interval": median,
                            "hot_fraction": hot}
            if self.active_window is not None:
                classes[cls]["active_bytes"] = row["active_bytes"]

        # hot bytes scale the *active* census when a staleness window is
        # set (keys untouched past it are squatters, not hot set)
        hot_bytes = sum(
            census[cls]["active_bytes" if self.active_window is not None
                        else "bytes"] * row["hot_fraction"]
            for cls, row in classes.items())
        target = hot_bytes * self.headroom

        if samples:
            combined = EmpiricalWorkload(
                np.concatenate(samples), l_blk=self.l_blk,
                n_blk=sum(r["keys"] for r in census.values()))
            b_dram = sum(s.specs[Tier.DRAM].read_bw for s in stores)
            b_ssd = sum(s.specs[Tier.FLASH].read_bw for s in stores)
            th = thresholds(combined, b_dram, b_ssd, c_dram=dram_cap)
            t_b, t_s, t_c = th.t_b, th.t_s, th.t_c
            if not th.viable:
                limit = "capacity" if t_c < th.t_v else "none"
            elif t_b >= t_s and t_b > self.tau_be:
                limit = "dram-bandwidth"
            elif t_s > t_b and t_s > self.tau_be:
                limit = "ssd-bandwidth"
            elif self.tau_be > t_c:
                limit = "capacity"
            else:
                limit = "none"
        else:
            t_b = t_s = t_c = float("nan")
            limit = "none"

        per_host = self.dram_bytes_per_host or (dram_cap /
                                                max(len(stores), 1))
        hosts = max(1, int(np.ceil(target / max(per_host, 1.0))))

        rebalance = None
        if fabric is not None and fabric.rebalances:
            moved = float(sum(rb.bytes_moved for rb in fabric.rebalances))
            rebalance = {
                "events": float(len(fabric.rebalances)),
                "bytes_moved": moved,
                "moved_fraction": moved / max(resident, 1.0),
            }

        verdict = self._verdict(limit, target, dram_cap, hosts,
                                len(stores))
        return ProvisionAdvice(
            tau_be=self.tau_be, horizon=horizon,
            resident_bytes=float(resident), dram_capacity=float(dram_cap),
            dram_used=float(dram_used), hot_bytes=float(hot_bytes),
            hot_fraction=float(hot_bytes / max(resident, 1.0)),
            recommended_dram_bytes=float(target),
            recommended_hosts=hosts, t_b=float(t_b), t_s=float(t_s),
            t_c=float(t_c), limit=limit, verdict=verdict,
            classes=classes, rebalance=rebalance)

    # ------------------------------------------------------- availability
    def advise_availability(self, *, fabric=None,
                            resident_bytes: Optional[float] = None,
                            n_hosts: Optional[int] = None,
                            dram_fraction: Optional[float] = None,
                            mttf: float,
                            alpha_stall: float = 4.0,
                            recompute_seconds: float = 1.0,
                            put_bytes_per_second: float = 0.0,
                            max_replicas: int = 3) -> AvailabilityAdvice:
        """Recommend a replication factor the way `advise` recommends a
        DRAM:flash split: price each candidate r and pick the cheapest.

        The availability version of Eq. 1's tradeoff — replication
        *rent* (extra DRAM byte-seconds for the copies, extra wire +
        flash-page writes on every put, expected repair traffic after
        failures) against the expected *failure stall* of running
        unreplicated: a lost object's only copy is gone, so the serving
        resource (priced at `alpha_stall`, the same normalized rent the
        AI-era Eq. 1 correction uses) stalls `recompute_seconds` per
        object to regenerate it — a decode recompute, not an SSD
        re-read, which is exactly why the loss term dwarfs the IO rates
        at serving-scale MTTFs. With a long MTTF the loss term vanishes
        and r=1 wins; as MTTF shrinks the expected stall crosses the
        copy rent and the recommendation steps up — the bench's
        kill-at-peak scenario checks the recommendation against
        measured $/token.

        Pass `fabric=` to census live state, or the explicit scalars."""
        if mttf <= 0:
            raise ValueError("mttf must be positive seconds per host")
        if max_replicas < 1:
            raise ValueError("max_replicas must be >= 1")
        if fabric is not None:
            stores = list(fabric.hosts.values())
            seen: Dict[object, int] = {}
            for s in stores:
                for key in s.keys():
                    seen.setdefault(key, s.nbytes_of(key))
            if resident_bytes is None:
                resident_bytes = float(sum(seen.values()))
            if n_hosts is None:
                n_hosts = fabric.n_hosts
            if dram_fraction is None:
                used = sum(s.used_bytes(Tier.DRAM)
                           + s.used_bytes(Tier.FLASH) for s in stores)
                dram = sum(s.used_bytes(Tier.DRAM) for s in stores)
                dram_fraction = dram / used if used > 0 else 0.0
        if resident_bytes is None or n_hosts is None:
            raise ValueError(
                "pass fabric= or both resident_bytes= and n_hosts=")
        if dram_fraction is None:
            dram_fraction = 0.0
        n_hosts = int(n_hosts)
        if n_hosts < 1:
            raise ValueError("n_hosts must be >= 1")

        # lazy: bench.py imports this module at load time
        from .bench import PAGE_BYTES, pricing_rates
        rates = pricing_rates(self.host, self.ssd)
        lam = n_hosts / mttf            # fleet-wide failures per second
        share = resident_bytes / n_hosts    # bytes lost with one host
        page_rate = rates["page_io_cost"] / PAGE_BYTES  # $ per byte of IO
        wire = rates["dram_wire_rate"]

        arms: Dict[int, Dict[str, float]] = {}
        # a copy set cannot exceed the fleet; candidate arms above
        # n_hosts would silently price the same placement
        r_max = min(max_replicas, n_hosts)
        for r in range(1, r_max + 1):
            rent = (r - 1) * resident_bytes * dram_fraction \
                * rates["rent_rate"]
            write = (r - 1) * put_bytes_per_second * (wire + page_rate)
            if r >= 2:
                # a failure re-streams the dead host's share; the ring
                # shrink also re-targets surviving copy sets, so repair
                # traffic scales with the total copies the host touched
                repair = lam * (r * share) * (wire + 2.0 * page_rate)
                loss = 0.0
            else:
                repair = 0.0
                # sole copies gone: the serving resource stalls
                # `recompute_seconds` per lost object to regenerate the
                # dead host's resident share (share/l_blk objects)
                loss = lam * (share / self.l_blk) \
                    * recompute_seconds * alpha_stall
            arms[r] = {"rent": float(rent), "write": float(write),
                       "repair": float(repair), "loss": float(loss),
                       "total": float(rent + write + repair + loss)}

        recommended = min(sorted(arms),
                          key=lambda r: (arms[r]["total"], r))
        if recommended == 1:
            verdict = ("run unreplicated: at this MTTF the expected "
                       "failure stall is cheaper than copy rent")
        else:
            verdict = (f"replicate x{recommended}: expected failure "
                       f"stall at mttf={mttf:.0f}s outprices the copy "
                       f"rent + repair traffic")
        return AvailabilityAdvice(
            mttf=float(mttf), failure_rate=float(lam),
            resident_bytes=float(resident_bytes), n_hosts=n_hosts,
            recommended_replicas=int(recommended), arms=arms,
            verdict=verdict)

    def _verdict(self, limit: str, target: float, dram_cap: float,
                 hosts: int, cur_hosts: int) -> str:
        if limit == "capacity":
            return ("capacity-limited: the measured hot set does not fit "
                    "DRAM; add DRAM or hosts before faster devices")
        if limit == "dram-bandwidth":
            return ("dram-bandwidth-limited: the miss path saturates "
                    "DRAM before capacity matters; faster memory, not "
                    "more of it")
        if limit == "ssd-bandwidth":
            return ("ssd-bandwidth-limited: the uncached stream exceeds "
                    "flash throughput; add SSDs or spread shards wider")
        if target > dram_cap:
            return (f"provision up: grow DRAM to the measured hot set "
                    f"({hosts} host(s) at current per-host capacity)")
        if hosts < cur_hosts:
            return (f"provision down: the measured hot set fits "
                    f"{hosts} host(s); the fleet is over-provisioned")
        return ("operate at tau_be: current provisioning matches the "
                "measured hot set")
