"""ReuseTracker — online per-key-class reuse-interval estimation.

Two structures, both O(1) per access:

  * a **ghost cache**: key -> last-seen time, kept even after the object
    is evicted from every tier (bounded size, FIFO on last touch). The
    ghost is what turns a re-admission into a *measured* reuse interval
    instead of a first touch — Flashield's trick, pointed at economics:
    without it every flood re-entry looks new and admission cannot
    distinguish "was here, came back fast" from "never seen".
  * a per-class **decayed log-bucket interval histogram** (the sketch):
    bucket b covers [tau0 * 2^b, tau0 * 2^(b+1)); each observed interval
    increments its (class, bucket) cell and the whole sketch ages by
    `decay` per batch, so estimates track drift (diurnal shifts,
    tenant bursts). Classes are caller-defined strings — "kv" sessions,
    "expert" weights, per-tenant streams — registered on first use.

The batched update path runs the `kernels/reuse_sketch` Pallas kernel
(thousands of keys per decode step in one launch); `use_kernel=False`
uses the numpy oracle, which is update-for-update identical — the
default here, since the CPU containers this repo tests on would pay
interpret-mode overhead per step for bit-identical results.

Class quantiles of the sketch answer "what reuse interval should I
assume for a key I know nothing about" (the EconomicGate's first-touch
prior) and, expanded to a weighted sample, feed `core.workload`'s
EmpiricalWorkload for the ProvisionAdvisor's threshold analysis.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from ..kernels.reuse_sketch.ref import reference_reuse_sketch


class _ArrayGhost:
    """Array-backed ghost state: the key -> row map stays a Python dict
    (arbitrary keys must hash somewhere), but last-seen times and touch
    sequence live in flat numpy arrays, so a batch touch is one
    vectorized pass instead of per-key OrderedDict churn — the
    difference between 1e3 and 1e6 tracked keys per step.

    Semantics match the old OrderedDict ghost exactly for any batch
    that fits inside the capacity headroom: first-ever touch measures
    0.0, a duplicate within one batch measures the 1e-9 floor, and a
    re-touch measures max(now - last, 1e-9). The one deliberate
    difference: eviction (FIFO on last touch == smallest touch
    sequence) is enforced per *batch*, not per element, so a single
    batch larger than the capacity can measure against entries the
    element-at-a-time code would already have evicted mid-batch. Size
    the ghost above the per-step batch (every real config does) and
    the two are indistinguishable."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        cap0 = 1024
        self._times = np.zeros(cap0, np.float64)
        self._seq = np.zeros(cap0, np.int64)
        self._occ = np.zeros(cap0, bool)
        self._keys: List[object] = [None] * cap0
        self._row: Dict[object, int] = {}
        self._free: List[int] = list(range(cap0 - 1, -1, -1))
        self._next_seq = 0

    def __len__(self) -> int:
        return len(self._row)

    def __contains__(self, key) -> bool:
        return key in self._row

    def get(self, key, default=None):
        r = self._row.get(key)
        return default if r is None else float(self._times[r])

    def discard(self, key) -> None:
        r = self._row.pop(key, None)
        if r is not None:
            self._occ[r] = False
            self._keys[r] = None
            self._free.append(r)

    def _grow(self, need: int) -> None:
        cap = len(self._times)
        if need <= cap:
            return
        new = cap
        while new < need:
            new *= 2
        pad = new - cap
        self._times = np.concatenate(
            [self._times, np.zeros(pad, np.float64)])
        self._seq = np.concatenate([self._seq, np.zeros(pad, np.int64)])
        self._occ = np.concatenate([self._occ, np.zeros(pad, bool)])
        self._keys.extend([None] * pad)
        self._free.extend(range(new - 1, cap - 1, -1))

    def touch_batch(self, keys: Sequence[object],
                    now: float) -> np.ndarray:
        """Touch a batch at one timestamp; returns float32 measured
        intervals (0.0 where the key was brand new)."""
        n = len(keys)
        self._grow(len(self._row) + n)
        rows = np.empty(n, np.int64)
        new = np.zeros(n, bool)
        dup = np.zeros(n, bool)
        seen = set()
        for i, key in enumerate(keys):
            r = self._row.get(key)
            if r is None:
                r = self._free.pop()
                self._row[key] = r
                self._keys[r] = key
                self._occ[r] = True
                self._times[r] = now
                new[i] = True
            elif key in seen:
                dup[i] = True
            rows[i] = r
            seen.add(key)
        iv = np.maximum(now - self._times[rows], 1e-9)
        iv = np.where(dup, 1e-9, iv)
        iv = np.where(new, 0.0, iv)
        # touch order: the key's *last* occurrence in the batch decides
        # its sequence (OrderedDict move-to-end semantics). Fancy
        # assignment with duplicate indices has no ordering guarantee,
        # so pick the last occurrence explicitly via reversed unique.
        u, pos_rev = np.unique(rows[::-1], return_index=True)
        self._times[u] = now
        self._seq[u] = self._next_seq + (n - 1 - pos_rev)
        self._next_seq += n
        self._evict()
        return iv.astype(np.float32)

    def _evict(self) -> None:
        over = len(self._row) - self.capacity
        if over <= 0:
            return
        occ = np.flatnonzero(self._occ)
        # smallest touch sequences go; sequences are unique (monotone
        # counter), so the victim set is deterministic
        victims = occ[np.argpartition(self._seq[occ], over - 1)[:over]]
        for r in victims:
            key = self._keys[int(r)]
            self._row.pop(key)
            self._keys[int(r)] = None
            self._occ[r] = False
            self._free.append(int(r))


class ReuseTracker:
    def __init__(self, n_buckets: int = 32, tau0: float = 1e-3,
                 decay: float = 0.995, ghost_capacity: int = 1 << 16,
                 max_classes: int = 8, use_kernel: bool = False):
        if n_buckets < 2 or tau0 <= 0 or not 0.0 < decay <= 1.0:
            raise ValueError("invalid sketch parameters")
        self.n_buckets = n_buckets
        self.tau0 = float(tau0)
        self.decay = float(decay)
        self.ghost_capacity = int(ghost_capacity)
        self.max_classes = int(max_classes)
        self.use_kernel = use_kernel
        self.hist = np.zeros((max_classes, n_buckets), np.float32)
        self._class_ids: Dict[str, int] = {}
        # array-backed ghost; keeps the `_last_seen` name (and len())
        # the tests and tooling observe
        self._last_seen = _ArrayGhost(self.ghost_capacity)
        self.observed = 0           # accesses fed in
        self.measured = 0           # of those, with a measured interval

    # ------------------------------------------------------------- classes
    def class_id(self, cls: str) -> int:
        cid = self._class_ids.get(cls)
        if cid is None:
            if len(self._class_ids) >= self.max_classes:
                raise ValueError(
                    f"more than {self.max_classes} key classes; raise "
                    f"max_classes")
            cid = len(self._class_ids)
            self._class_ids[cls] = cid
        return cid

    @property
    def classes(self) -> List[str]:
        return list(self._class_ids)

    # ------------------------------------------------------------ tracking
    def _touch(self, key, now: float) -> float:
        """Update the ghost; returns the measured interval (<= 0 when the
        key is new to the ghost)."""
        return float(self._last_seen.touch_batch([key], now)[0])

    def observe(self, key, cls: str, now: float) -> Optional[float]:
        """Single-key path; returns the measured interval or None."""
        iv = self.observe_batch([key], [cls], now)
        return iv[0] if iv[0] > 0 else None

    def observe_batch(self, keys: Sequence[object], classes: Sequence[str],
                      now: float) -> np.ndarray:
        """Feed one step's accesses; returns the measured intervals
        (<= 0 where the key was a first touch). The ghost update is one
        vectorized `touch_batch`, and the sketch sees one update — the
        Pallas kernel when `use_kernel`, else the bit-identical oracle.
        `classes` may be a single string applied to the whole batch, or
        a precomputed int array of `class_id` values (the zero-Python
        path for large control planes)."""
        n = len(keys)
        if isinstance(classes, str):
            cids = np.full(n, self.class_id(classes), np.int32)
        elif (isinstance(classes, np.ndarray)
                and classes.dtype.kind in "iu"):
            cids = classes.astype(np.int32)
        else:
            cids = np.fromiter((self.class_id(c) for c in classes),
                               np.int32, count=n)
        intervals = self._last_seen.touch_batch(keys, now)
        self.observed += n
        self.measured += int((intervals > 0).sum())
        if self.use_kernel:
            from ..kernels.reuse_sketch.ops import reuse_sketch_update
            self.hist = np.asarray(reuse_sketch_update(
                self.hist, intervals, cids, tau0=self.tau0,
                decay=self.decay))
        else:
            self.hist = reference_reuse_sketch(
                self.hist, intervals, cids, tau0=self.tau0,
                decay=self.decay)
        return intervals

    def last_seen(self, key) -> Optional[float]:
        return self._last_seen.get(key)

    def forget_keys(self, keys: Sequence[object]) -> None:
        """Purge ghost entries for keys that no longer exist anywhere
        (deleted, or lost to an unplanned host failure). Without this a
        key re-created after loss measures a spurious "reuse interval"
        against its dead predecessor's last touch and the gate admits it
        on evidence about an object that is gone. Class sketch mass is
        untouched — measured history of the *class* remains valid."""
        for key in keys:
            self._last_seen.discard(key)

    def seed_prior(self, cls: str, interval: float, weight: float = 1.0):
        """Declared workload prior: add `weight` mass at `interval` to
        the class sketch directly (no synthetic ghost entries) — how
        `HierarchySpec.class_priors` pre-loads first-touch admission
        before any reuse has been measured. Decays away like measured
        mass, so real telemetry supersedes the declaration."""
        if interval <= 0:
            raise ValueError(f"prior interval must be positive seconds "
                             f"(got {interval!r})")
        if weight <= 0:
            raise ValueError("prior weight must be positive")
        cid = self.class_id(cls)
        b = int(np.clip(np.floor(np.log2(interval / self.tau0)), 0,
                        self.n_buckets - 1))
        self.hist[cid, b] += weight

    # ----------------------------------------------------------- estimates
    def bucket_centers(self) -> np.ndarray:
        """Geometric center of each bucket (seconds)."""
        return self.tau0 * np.exp2(np.arange(self.n_buckets) + 0.5)

    def class_mass(self, cls: str) -> float:
        cid = self._class_ids.get(cls)
        return float(self.hist[cid].sum()) if cid is not None else 0.0

    def class_quantile(self, cls: str, q: float = 0.5) -> Optional[float]:
        """Interval at cumulative mass `q` of the class's decayed
        histogram (bucket-center resolution); None when the class has
        (essentially) no measured mass yet."""
        cid = self._class_ids.get(cls)
        if cid is None:
            return None
        row = self.hist[cid]
        total = float(row.sum())
        if total < 1e-6:
            return None
        cum = np.cumsum(row)
        b = int(np.searchsorted(cum, q * total, side="left"))
        return float(self.bucket_centers()[min(b, self.n_buckets - 1)])

    def interval_samples(self, cls: str,
                         max_samples: int = 512) -> np.ndarray:
        """Expand the class histogram into a representative interval
        sample (bucket centers repeated by normalized weight) — the
        input `core.workload.EmpiricalWorkload` takes. Deterministic."""
        cid = self._class_ids.get(cls)
        if cid is None:
            return np.zeros(0)
        row = self.hist[cid]
        total = float(row.sum())
        if total < 1e-6:
            return np.zeros(0)
        reps = np.round(row / total * max_samples).astype(int)
        centers = self.bucket_centers()
        out = np.repeat(centers, reps)
        if out.size == 0:                       # all mass in tiny slivers
            out = centers[np.argmax(row)][None]
        return out

    def histogram(self, cls: str) -> Optional[np.ndarray]:
        cid = self._class_ids.get(cls)
        return None if cid is None else self.hist[cid].copy()
