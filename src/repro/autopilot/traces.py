"""Scenario-diverse access-trace generator for the autopilot benchmark.

Four canonical cache-adversarial shapes, all deterministic under a seed
and expressed as decode steps (one step = `step_time` seconds of
compute; each step touches a small batch of keys):

  * ``zipf``          — stationary skewed popularity: a hot head reused
                        every few steps, a long tail reused rarely.
  * ``scan_flood``    — the same hot core plus periodic one-touch floods
                        of *fresh* keys (class "scan"): the classic
                        LRU-killer; an admission gate must keep the
                        flood out of DRAM.
  * ``diurnal``       — the hot set migrates from pool A to pool B over
                        the trace (hotspot shift): yesterday's hot keys
                        squat in DRAM unless staleness-aware demotion
                        reclaims them.
  * ``multi_tenant``  — a steady tenant plus a bursty tenant (distinct
                        key classes): within a burst the bursty keys are
                        economically hot, between bursts they are not.

Keys are `(class, id)` tuples, so `autopilot.gate.default_classify`
recovers the class and the per-class sketch learns separate priors.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

SCENARIOS = ("zipf", "scan_flood", "diurnal", "multi_tenant")

Access = Tuple[tuple, str]          # (key, class)


@dataclasses.dataclass(frozen=True)
class Trace:
    name: str
    step_time: float
    steps: List[List[tuple]]        # per step: keys touched (in order)

    @property
    def n_steps(self) -> int:
        return len(self.steps)

    def distinct_keys(self) -> List[tuple]:
        seen = dict.fromkeys(k for step in self.steps for k in step)
        return list(seen)

    @property
    def accesses(self) -> int:
        return sum(len(s) for s in self.steps)


def _zipf_weights(n: int, a: float) -> np.ndarray:
    w = np.power(np.arange(1, n + 1, dtype=float), -a)
    return w / w.sum()


def generate(name: str, *, n_steps: int = 240, step_time: float = 0.25,
             seed: int = 0) -> Trace:
    """Build one scenario trace. All randomness comes from a
    scenario-salted `default_rng`, so (name, n_steps, seed) fully
    determine the byte-exact access sequence."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown scenario {name!r}; one of {SCENARIOS}")
    rng = np.random.default_rng(seed * 1009 + SCENARIOS.index(name))
    steps: List[List[tuple]] = []

    if name == "zipf":
        n_keys, per_step = 48, 4
        w = _zipf_weights(n_keys, 1.1)
        for _ in range(n_steps):
            ids = rng.choice(n_keys, size=per_step, p=w)
            steps.append([("kv", int(i)) for i in ids])

    elif name == "scan_flood":
        n_hot, per_step = 24, 3
        w = _zipf_weights(n_hot, 1.2)
        flood_every, flood_len, flood_per_step = 40, 8, 4
        flood_id = 0
        for t in range(n_steps):
            step = [("kv", int(i))
                    for i in rng.choice(n_hot, size=per_step, p=w)]
            if (t % flood_every) < flood_len:
                # one-touch keys, fresh every flood: never reused
                for _ in range(flood_per_step):
                    step.append(("scan", flood_id))
                    flood_id += 1
            steps.append(step)

    elif name == "diurnal":
        pool, per_step = 24, 4
        w = _zipf_weights(pool, 1.2)
        for t in range(n_steps):
            # phase 0 -> pool A hot; phase 1 -> pool B hot; smooth shift
            p = float(np.clip((t - n_steps / 3) / (n_steps / 3), 0.0, 1.0))
            step = []
            for _ in range(per_step):
                which = pool if rng.random() < p else 0
                step.append(("kv", int(which + rng.choice(pool, p=w))))
            steps.append(step)

    else:                                            # multi_tenant
        n_a, n_b = 16, 16
        w_a = _zipf_weights(n_a, 1.2)
        w_b = _zipf_weights(n_b, 0.8)
        burst_every, burst_len = 30, 6
        for t in range(n_steps):
            step = [("tenant_a", int(i))
                    for i in rng.choice(n_a, size=2, p=w_a)]
            if (t % burst_every) < burst_len:
                step += [("tenant_b", int(i))
                         for i in rng.choice(n_b, size=4, p=w_b)]
            steps.append(step)

    return Trace(name=name, step_time=step_time, steps=steps)


def from_workload(decl, *, step_time: float = 0.25,
                  name: str = "workload") -> Trace:
    """Render a declared multi-tenant scenario (`WorkloadDecl`, see
    `repro.platform.spec`) as an access trace: keys are `(tenant, id)`
    tuples, so the per-class sketch learns separate per-tenant priors —
    the declared counterpart of the hand-coded shapes above."""
    from ..platform.workload import compile_workload
    return compile_workload(decl).trace(step_time=step_time, name=name)
