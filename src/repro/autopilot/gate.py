"""EconomicGate — break-even admission/demotion for the tiered runtime.

`core.policy.TieringPolicy` already moves *resident* objects by their
EMA'd reuse interval vs the calibrated thresholds. What it cannot do is
place an object it has never re-observed: the seed runtime admitted
everything to DRAM and let capacity pressure sort it out (LRU-ish), so
one scan flood evicts the economically-hot set and every cold write
pays DRAM rent until eviction.

The gate closes that loop with the paper's own threshold. On every
`put`/`ingest` the store asks `admit_tier(key, requested, now)`:

  * a key with an EMA (re-observed while resident) follows the
    inherited hysteresis logic — no behavior change;
  * a key the ghost cache remembers (evicted, came back) is priced by
    its *measured* time-since-last-touch;
  * a first-touch key is priced by its class's decayed sketch quantile
    (KV sessions, MoE experts, per-tenant streams learn separate
    priors), and with no class evidence defaults cold — DRAM residency
    is earned by demonstrated reuse below tau_be, never granted.

Admission to DRAM happens iff the estimate sits below the break-even
interval `tau_be` (Eq. 1, via `economics.break_even_for_ssd`); the
inherited multiplicative hysteresis band keeps boundary keys from
oscillating between admit and demote. HBM residency stays earned-only
(EMA below tau_hot), never granted at admission.

Construct with explicit thresholds, or `EconomicGate.from_break_even`
(host + SSD configs -> tau_be) / `from_platform` (feasibility-capped
IOPS, inherited). The same gate instance (or a per-host factory) plugs
into `TieredStore`, `ShardedTieredStore`, `DecodeEngine` and
`ExpertStore` unchanged — they all speak TieringPolicy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from ..core.economics import HostConfig, break_even_for_ssd
from ..core.policy import Tier, TieringPolicy
from ..core.ssd_model import SsdConfig
from .reuse import ReuseTracker


def default_classify(key) -> str:
    """Key -> class label: the runtime's tuple-key conventions map
    ("kv", rid) -> "kv", (layer, expert) int pairs -> "expert"; anything
    else shares one bucket."""
    if isinstance(key, tuple) and key:
        if isinstance(key[0], str):
            return key[0]
        if all(isinstance(x, (int, np.integer)) for x in key):
            return "expert"
    return "obj"


@dataclasses.dataclass
class GateStats:
    admits_dram: int = 0        # admitted under break-even
    admits_flash: int = 0       # priced out (or unknown, cold default)
    admits_pool: int = 0        # priced out of DRAM but under tau_pool
    admits_gpu_flash: int = 0   # cold admits routed to the BaM path
    readmits_measured: int = 0  # ghost supplied a measured interval
    prior_decisions: int = 0    # first touch priced by the class sketch
    cold_defaults: int = 0      # first touch with no class evidence


class EconomicGate(TieringPolicy):
    """TieringPolicy + break-even admission from tracked reuse."""

    def __init__(self, tau_hot: float, tau_be: float, *,
                 tracker: Optional[ReuseTracker] = None,
                 classify: Callable[[object], str] = default_classify,
                 prior_quantile: float = 0.5,
                 hysteresis: float = 0.25, ema_alpha: float = 0.2,
                 class_tau_be: Optional[Dict[str, float]] = None,
                 tau_pool: Optional[float] = None,
                 gpu_direct: bool = False):
        super().__init__(tau_hot=tau_hot, tau_be=tau_be,
                         hysteresis=hysteresis, ema_alpha=ema_alpha)
        self.tracker = tracker or ReuseTracker()
        self.classify = classify
        self.prior_quantile = prior_quantile
        self.gate_stats = GateStats()
        # observability: attached by the fabric/platform (tracer instants
        # for every admit decision); `_priced_out` remembers keys this
        # gate sent to FLASH against a warmer ask, so the stall ledger
        # can bill their later restores to the *decision*
        # (gate_miss_restore), not the media (flash_service)
        self.obs = None
        self._priced_out = set()
        # per-class (per-tenant) break-even overrides: a class's SLO
        # alpha_stall folds into its own tau_be (see `breakeven_tau`);
        # classes not listed fall back to the fleet-wide threshold
        self.class_tau_be = dict(class_tau_be) if class_tau_be else None
        # fourth-tier thresholds. tau_pool bounds the pool band: an
        # object priced out of local DRAM (est >= tau_be) but reused
        # faster than tau_pool earns the fleet pool's discounted rent;
        # slower goes to flash. gpu_direct routes gate-cold admissions
        # to the BaM path (GPU_FLASH) — same media, no host-CPU rent.
        if tau_pool is not None and tau_pool <= tau_be:
            raise ValueError(
                "tau_pool must exceed tau_be: the pool band sits "
                "between local DRAM and flash in the reuse spectrum")
        self.tau_pool = tau_pool
        self.gpu_direct = bool(gpu_direct)

    def tau_for(self, key) -> float:
        """Break-even threshold governing `key`: its class's declared
        per-tenant tau_be when one exists, else the fleet-wide value."""
        if not self.class_tau_be:
            return self.tau_be
        return self.class_tau_be.get(self.classify(key), self.tau_be)

    # ------------------------------------------------------------ tracking
    def observe(self, key, now: Optional[float] = None) -> Tier:
        """Every runtime access (get/put) flows through here: feed the
        ghost + sketch, then the inherited EMA/hysteresis placement."""
        if now is None:
            raise ValueError("EconomicGate requires an explicit clock "
                             "time (the runtime always passes one)")
        self.tracker.observe(key, self.classify(key), now)
        return super().observe(key, now=now)

    # ----------------------------------------------------------- admission
    def _estimate(self, key, now: float):
        """Evidence cascade behind every estimate: resident EMA >
        ghost-measured gap > class sketch prior > nothing. Returns
        (estimate_or_None, source) with source in {"ema", "ghost",
        "prior", "none"} — the single place the priority order lives."""
        ema = self._ema.get(key)
        if ema is not None:
            return ema, "ema"
        last = self.tracker.last_seen(key)
        if last is not None and now > last:
            return now - last, "ghost"
        prior = self.tracker.class_quantile(self.classify(key),
                                            self.prior_quantile)
        return (prior, "prior") if prior is not None else (None, "none")

    def estimate_interval(self, key, now: float) -> Optional[float]:
        """Best reuse-interval estimate for `key` at `now`; None when no
        evidence exists at any level of the cascade."""
        return self._estimate(key, now)[0]

    def admit_tier(self, key, requested: Tier, now: float) -> Tier:
        """Landing tier for a put/ingest: DRAM iff the estimated reuse
        interval clears break-even; cold (FLASH) when nothing is known.
        Never admits straight to HBM — that residency is earned by the
        observed EMA dropping below tau_hot. Records the decision so the
        first-touch default of `tier_of` agrees with it."""
        st = self.gate_stats
        est, source = self._estimate(key, now)
        if source == "ghost":
            st.readmits_measured += 1
        elif source == "prior":
            st.prior_decisions += 1
        elif source == "none":
            st.cold_defaults += 1
        if est is not None and est < self.tau_for(key):
            decided = Tier.DRAM
            st.admits_dram += 1
        else:
            decided = Tier.FLASH
            st.admits_flash += 1
        # an explicit colder request (setup pinning data to flash) wins;
        # the gate only ever *demotes* relative to the caller's ask
        decided = Tier(max(decided, requested))
        # gate-cold admissions ride the BaM path when the host has one:
        # same flash media, but the submission engine replaces the
        # host-CPU/host-DRAM IO path (the dropped Eq. 1 rent terms). An
        # explicit FLASH pin stays FLASH — spills and restores are not
        # gate decisions.
        if (decided == Tier.FLASH and self.gpu_direct
                and requested != Tier.FLASH):
            decided = Tier.GPU_FLASH
            st.admits_gpu_flash += 1
        self._tier[key] = decided
        # priced out = the gate denied a warmer ask; a flash-pinned put
        # was never a decision and must not bill restores to the gate
        if decided == Tier.FLASH and requested < Tier.FLASH:
            self._priced_out.add(key)
        else:
            self._priced_out.discard(key)
        if self.obs is not None and self.obs.tracer is not None:
            t = self.obs.tracer
            t.instant(t.track("gate", "admit"), "admit_tier", now,
                      cat="policy",
                      args={"key": str(key),
                            "est": -1.0 if est is None else est,
                            "source": source,
                            "tau_be": self.tau_for(key),
                            "requested": requested.name,
                            "decided": decided.name})
        return decided

    def pool_admit(self, key, requested: Tier, now: float) -> bool:
        """Fleet-pool admission (the fabric asks before host placement):
        True iff the tracked estimate prices out of *local* DRAM rent
        but clears the pool column's wider tau — the band where the
        pool's discounted rent beats both DRAM rent and a flash IO.
        Cold keys (no evidence) and explicit flash pins never pool."""
        if self.tau_pool is None:
            return False
        if requested >= Tier.FLASH:
            return False
        est, _ = self._estimate(key, now)
        if est is None or est < self.tau_for(key):
            return False
        if est < self.tau_pool:
            self.gate_stats.admits_pool += 1
            return True
        return False

    def priced_out(self, key) -> bool:
        """Did this gate's last admission decision for `key` deny a
        warmer tier? (`TieredStore` asks on flash fetches — the ledger's
        gate_miss_restore attribution.)"""
        return key in self._priced_out

    def tier_of(self, key) -> Tier:
        """Resident placement under the key's *own* class threshold
        when per-class tau_be overrides exist — same EMA + hysteresis
        discipline as the inherited logic, so a premium class's wider
        tau keeps its re-observed keys in DRAM where the fleet-wide
        threshold would demote them."""
        tau_be = self.tau_for(key)
        if tau_be == self.tau_be:
            return super().tier_of(key)
        ema = self._ema.get(key)
        if ema is None:
            return self._tier.setdefault(key, Tier.DRAM)
        cur = self._tier.get(key, Tier.DRAM)
        want = Tier.HBM if ema < self.tau_hot else (
            Tier.DRAM if ema < tau_be else Tier.FLASH)
        if want == cur:
            self._tier[key] = cur
            return cur
        h = 1.0 + self.hysteresis
        boundary = self.tau_hot if min(want, cur) == Tier.HBM else tau_be
        if want > cur and ema > boundary * h:
            cur = Tier(cur + 1)
        elif want < cur and ema < boundary / h:
            cur = Tier(cur - 1)
        self._tier[key] = cur
        return cur

    def forget_keys(self, keys) -> None:
        """Key loss purges both the inherited placement state and the
        tracker's ghost entry, so a re-created key is a genuine first
        touch (priced by the class prior, not its dead predecessor)."""
        super().forget_keys(keys)
        self.tracker.forget_keys(keys)
        for key in keys:
            self._priced_out.discard(key)

    # ------------------------------------------------------------- eviction
    def evict_candidates(self, tier: Tier, now: Optional[float] = None,
                         limit: int = 0):
        """Demotion order under capacity pressure, staleness-aware: a
        key's effective interval is max(EMA, time since last touch). The
        inherited order ranks by EMA alone, so a key that was hot
        yesterday (small EMA) but has not been touched since squats in
        DRAM through a hotspot shift; the max() reclaims it first."""
        if now is None:
            raise ValueError("EconomicGate requires an explicit clock "
                             "time (the runtime always passes one)")
        keys = [k for k, t in self._tier.items() if t == tier]

        def staleness(k):
            gap = now - self._last_seen.get(k, now)
            ema = self._ema.get(k)
            return max(ema if ema is not None else 0.0, gap)

        keys.sort(key=lambda k: -staleness(k))
        return keys[:limit] if limit else keys

    # -------------------------------------------------------- constructors
    @staticmethod
    def breakeven_tau(host: HostConfig, ssd: SsdConfig, l_blk: float, *,
                      gamma_rw: float = 9.0, phi_wa: float = 3.0,
                      iops_ssd: Optional[float] = None,
                      alpha_stall: float = 0.0,
                      fetch_seconds: float = 0.0) -> float:
        """Eq. 1 tau_be with the AI-era stall correction folded in (see
        `from_break_even`). Exposed separately so per-tenant thresholds
        — one tau per declared SLO `alpha_stall` — price through the
        identical formula."""
        tau_be = float(break_even_for_ssd(host, ssd, l_blk,
                                          gamma_rw=gamma_rw,
                                          phi_wa=phi_wa,
                                          iops_ssd=iops_ssd))
        if alpha_stall and fetch_seconds:
            rent_rate = l_blk * host.alpha_h_dram / host.c_h_dram_die
            tau_be += alpha_stall * fetch_seconds / rent_rate
        return tau_be

    @classmethod
    def from_break_even(cls, host: HostConfig, ssd: SsdConfig,
                        l_blk: float, *, gamma_rw: float = 9.0,
                        phi_wa: float = 3.0,
                        iops_ssd: Optional[float] = None,
                        alpha_stall: float = 0.0,
                        fetch_seconds: float = 0.0,
                        tau_hot: Optional[float] = None, **kw):
        """Thresholds straight from the calibrated economics (Eq. 1):
        tau_be = break_even_for_ssd(host, ssd, l_blk); tau_hot defaults
        to tau_be / 50 (the HBM rent heuristic `from_platform` uses).

        The AI-era correction the paper argues for: a serving miss does
        not just consume an SSD IO, it *stalls the engine* for the fetch.
        Pass `alpha_stall` (normalized rent of the stalled serving
        resource, $/s in NAND-die units — the same units alpha_core is
        in) and `fetch_seconds` (the modeled demand-fetch time, e.g.
        `SsdQueueModel.service(l_blk, 1).total`) and the miss's stall
        cost joins Eq. 1's numerator:

            tau_be += alpha_stall * fetch_seconds / dram_rent_rate

        which widens the DRAM set exactly as much as stalled-accelerator
        time is worth."""
        tau_be = cls.breakeven_tau(host, ssd, l_blk, gamma_rw=gamma_rw,
                                   phi_wa=phi_wa, iops_ssd=iops_ssd,
                                   alpha_stall=alpha_stall,
                                   fetch_seconds=fetch_seconds)
        if tau_hot is None:
            tau_hot = tau_be / 50.0
        return cls(tau_hot=min(tau_hot, tau_be), tau_be=tau_be, **kw)
