"""Economics-in-the-loop autopilot: online reuse tracking (ghost cache +
decayed log-bucket sketch, Pallas-batched), break-even admission for the
tiered runtime, and a live provisioning advisor over fabric telemetry.
"""
from .advisor import ProvisionAdvice, ProvisionAdvisor
from .gate import EconomicGate, GateStats, default_classify
from .reuse import ReuseTracker
from .traces import SCENARIOS, Trace, generate

__all__ = [
    "EconomicGate", "GateStats", "default_classify",
    "ProvisionAdvice", "ProvisionAdvisor",
    "ReuseTracker",
    "SCENARIOS", "Trace", "generate",
]
