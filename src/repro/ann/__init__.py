from . import corpus, model, progressive  # noqa
