"""MRL-like synthetic embedding corpus.

Matryoshka Representation Learning trains embeddings whose prefixes are
themselves good embeddings. We emulate the property the paper relies on
(prefix-truncations preserve neighborhoods) with a Gaussian-mixture corpus
whose cluster structure lives in the leading dimensions and whose energy
decays along the feature axis — prefix distances then correlate strongly
with full distances, exactly the regime where two-stage progressive search
keeps recall high."""
from __future__ import annotations

import numpy as np


def make_corpus(n: int, d_full: int, d_reduced: int, n_clusters: int = 64,
                decay: float = 8.0, noise: float = 0.10, seed: int = 0):
    """Returns (full [n, d_full] f32, reduced [n, d_reduced] f32,
    queries' generator-compatible params)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d_full)).astype(np.float32)
    # energy concentrates in leading dims (the MRL property)
    scale = np.exp(-decay * np.arange(d_full) / d_full).astype(np.float32)
    centers *= scale
    assign = rng.integers(0, n_clusters, n)
    pts = centers[assign] + noise * scale * rng.normal(
        size=(n, d_full)).astype(np.float32)
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    reduced = pts[:, :d_reduced].copy()
    return pts.astype(np.float32), reduced.astype(np.float32), assign


def make_queries(corpus: np.ndarray, n_q: int, jitter: float = 0.05,
                 seed: int = 1):
    """Queries near existing corpus points (realistic retrieval load)."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(corpus), n_q)
    q = corpus[idx] + jitter * rng.normal(
        size=(n_q, corpus.shape[1])).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    return q.astype(np.float32)
