"""Analytical throughput model for two-stage SSD-resident ANN search
(paper Fig. 10): KQPS vs DRAM capacity across reduced->full geometries.

Per query:
  stage-1: V1 reduced-vector (512B) random reads, a fraction served from
           the DRAM cache of hot upper-layer HNSW nodes (layer-aware
           profile: upper layers are exponentially hotter),
  stage-2: promote_frac * V1 full-vector reads (2-8KB, bandwidth-type).

Bounds: usable SSD IOPS (tail-capped + host budget), host IOPS, DRAM
bandwidth (cache hits + DMA of both read classes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..core.constraints import usable_iops
from ..core.ssd_model import (SsdConfig, iops_ssd_peak, normal_ssd,
                              storage_next_ssd)
from ..core.workload import LogNormalWorkload


@dataclasses.dataclass(frozen=True)
class AnnWorkload:
    n_vectors: float = 8e9
    d_reduced_bytes: int = 512
    d_full_bytes: int = 4096
    beam_hops: int = 600              # HNSW traversal length (ef-style)
    degree: int = 32                  # graph degree: reads per hop
    promote_frac: float = 0.10        # fraction re-ranked on full vectors
    sigma: float = 1.6                # layer-aware skew of node popularity

    @property
    def visits_stage1(self) -> int:
        # each hop evaluates the reduced vectors of all neighbors
        return self.beam_hops * self.degree


@dataclasses.dataclass(frozen=True)
class AnnPlatform:
    name: str
    host_iops: float
    b_dram: float
    n_ssd: int = 4
    ssd: SsdConfig = None
    util_cap: float = 0.70


def gpu_sn() -> AnnPlatform:
    return AnnPlatform("GPU+SN", 400e6, 640e9, ssd=storage_next_ssd())


def cpu_sn() -> AnnPlatform:
    return AnnPlatform("CPU+SN", 100e6, 540e9, ssd=storage_next_ssd())


def gpu_nr() -> AnnPlatform:
    return AnnPlatform("GPU+NR", 400e6, 640e9, ssd=normal_ssd())


def throughput_kqps(plat: AnnPlatform, wl: AnnWorkload,
                    dram_bytes: float) -> Dict[str, float]:
    # node popularity profile (upper HNSW layers exponentially hotter)
    prof = LogNormalWorkload.from_total_throughput(
        throughput=1.0, sigma=wl.sigma, n_blk=wl.n_vectors,
        l_blk=wl.d_reduced_bytes)
    hit = float(prof.hit_rate_for_capacity(dram_bytes))

    v1_ssd = wl.visits_stage1 * (1.0 - hit)          # 512B random reads
    v2 = wl.visits_stage1 * wl.promote_frac          # full-vector reads
    # stage-2 reads issued as (d_full/512) packet-equivalents against the
    # IOPS budget? No — they are few and large: charge them against IOPS
    # once each and against bandwidth by size.
    gamma = float("inf")                             # read-only search
    peak_small = float(iops_ssd_peak(plat.ssd, wl.d_reduced_bytes, gamma,
                                     1.0))
    peak_big = float(iops_ssd_peak(plat.ssd, wl.d_full_bytes, gamma, 1.0))
    ssd_small = min(plat.util_cap * peak_small,
                    plat.host_iops / plat.n_ssd) * plat.n_ssd
    ssd_big = min(plat.util_cap * peak_big,
                  plat.host_iops / plat.n_ssd) * plat.n_ssd

    # time-shares on the device: q/s bound st v1/ssd_small + v2/ssd_big <= 1
    ssd_bound = 1.0 / max(v1_ssd / ssd_small + v2 / ssd_big, 1e-15)
    host_bound = plat.host_iops / max(v1_ssd + v2, 1e-9)
    bytes_per_q = (wl.visits_stage1 * hit * wl.d_reduced_bytes
                   + 2.0 * v1_ssd * wl.d_reduced_bytes
                   + 2.0 * v2 * wl.d_full_bytes)
    dram_bound = plat.b_dram / bytes_per_q

    qps = min(ssd_bound, host_bound, dram_bound)
    limiter = {ssd_bound: "ssd", host_bound: "host-iops",
               dram_bound: "dram-bw"}[qps]
    return {"kqps": qps / 1e3, "limiter": limiter, "hit_rate": hit,
            "ssd_iops_small": ssd_small, "ssd_iops_big": ssd_big}
