"""Two-stage progressive SSD-resident ANN search (paper §VII-B, Fig. 9).

Stage 1: scan *reduced* vectors (512B-class rows) with the fused
distance+top-M Pallas kernel — predominantly small-block reads, the
IOPS-friendly regime Storage-Next unlocks.
Stage 2: re-rank the small promoted candidate set on *full* vectors
(2-8KB rows) — the bandwidth-bound tail, amortized by the >90% rejection
rate of stage 1 (Gao et al.).

`search` measures recall against exact brute force; the paper's >98%
recall claim is validated on the MRL-like corpus in tests/benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ..kernels.ann_topk.ops import ann_topk


@dataclasses.dataclass
class SearchStats:
    queries: int = 0
    stage1_reads: int = 0            # reduced-vector row reads (512B-class)
    stage2_reads: int = 0            # full-vector row reads (KB-class)


def exact_topk(queries: np.ndarray, corpus: np.ndarray, k: int):
    d = (np.sum(corpus ** 2, 1)[None, :]
         - 2.0 * queries @ corpus.T)
    return np.argsort(d, axis=1)[:, :k]


def search(queries: np.ndarray, reduced: np.ndarray, full: np.ndarray,
           k: int = 10, promote: int = 64, stats: SearchStats = None,
           use_kernel: bool = True) -> Tuple[np.ndarray, SearchStats]:
    """Two-stage search. Returns (ids [Q, k], stats)."""
    stats = stats or SearchStats()
    Q = len(queries)
    d_red = reduced.shape[1]
    # stage 1: top-`promote` on reduced vectors
    if use_kernel:
        _, cand = ann_topk(jnp.asarray(queries[:, :d_red]),
                           jnp.asarray(reduced), k=promote,
                           tile=min(512, len(reduced)))
        cand = np.asarray(cand)
    else:
        cand = exact_topk(queries[:, :d_red], reduced, promote)
    stats.queries += Q
    stats.stage1_reads += Q * len(reduced)      # streamed scan rows
    # stage 2: exact re-rank of the promoted set on full vectors
    out = np.empty((Q, k), np.int64)
    gather = full[cand]                          # [Q, promote, D]
    stats.stage2_reads += Q * promote
    d2 = np.sum(gather ** 2, -1) - 2.0 * np.einsum(
        "qd,qpd->qp", queries, gather)
    order = np.argsort(d2, axis=1)[:, :k]
    out = np.take_along_axis(cand, order, axis=1)
    return out, stats


def recall_at_k(pred: np.ndarray, truth: np.ndarray) -> float:
    hits = 0
    for p, t in zip(pred, truth):
        hits += len(set(p.tolist()) & set(t.tolist()))
    return hits / truth.size
