from .cuckoo import BlockedCuckooStore  # noqa
from .tiered import TimedCuckooStore  # noqa
from . import model  # noqa
