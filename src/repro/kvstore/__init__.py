from .cuckoo import BlockedCuckooStore  # noqa
from . import model  # noqa
