"""Analytical throughput model for the SSD-resident KV store (paper Fig. 8).

Combines the calibrated device model (usable IOPS under the 70% tail-
latency utilization cap), host IOPS budgets, DRAM bandwidth, the log-normal
access-interval profile (hot-pair cache hit rate as a function of DRAM
capacity), and WAL write coalescing:

  demand per op (SSD IOs)  = f_get * miss * E[reads|GET]           (1.5)
                           + f_put * (2 / c)                (RMW / coalesce)
  throughput = min( SSD_IOPS / demand, HOST_IOPS / demand_host,
                    B_DRAM / bytes_per_op )

Strong locality (sigma=1.2) raises both the cache hit rate and the WAL
coalescing factor; weak locality (sigma=0.4) keeps both near worst-case —
reproducing the paper's spread between the two regimes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import numpy as np

from ..core.constraints import LatencyTargets, rho_max_for_targets, \
    usable_iops
from ..core.economics import CPU_DDR, GPU_GDDR
from ..core.ssd_model import (SsdConfig, gamma_from_mix, iops_ssd_peak,
                              normal_ssd, storage_next_ssd)
from ..core.workload import LogNormalWorkload


@dataclasses.dataclass(frozen=True)
class KvWorkload:
    n_items: float = 80e9
    item_bytes: float = 64.0
    get_frac: float = 0.9
    insert_frac_of_puts: float = 0.2
    sigma: float = 1.2                # locality (1.2 strong / 0.4 weak)
    wal_entries: int = 4096


@dataclasses.dataclass(frozen=True)
class KvPlatform:
    name: str
    host_iops: float                  # total budget
    b_dram: float                     # bytes/s
    n_ssd: int = 4
    ssd: SsdConfig = None
    bucket_bytes: int = 512
    util_cap: float = 0.70


def gpu_sn_platform() -> KvPlatform:
    return KvPlatform("GPU+SN", host_iops=400e6, b_dram=640e9,
                      ssd=storage_next_ssd(), bucket_bytes=512)


def cpu_sn_platform() -> KvPlatform:
    return KvPlatform("CPU+SN", host_iops=100e6, b_dram=540e9,
                      ssd=storage_next_ssd(), bucket_bytes=512)


def gpu_nr_platform() -> KvPlatform:
    return KvPlatform("GPU+NR", host_iops=400e6, b_dram=640e9,
                      ssd=normal_ssd(), bucket_bytes=4096)


def cpu_nr_platform() -> KvPlatform:
    return KvPlatform("CPU+NR", host_iops=100e6, b_dram=540e9,
                      ssd=normal_ssd(), bucket_bytes=4096)


def wal_coalescing(wl: KvWorkload) -> float:
    """Expected updates absorbed per RMW: W appends hit D(W) distinct
    buckets; c = W / D(W). Under the log-normal popularity profile hot
    keys repeat within a WAL window, so strong locality -> larger c.
    Estimated by a short deterministic simulation of the profile."""
    rng = np.random.default_rng(7)
    n_probe = 200_000
    rates = np.exp(rng.normal(0.0, wl.sigma, n_probe))
    p = rates / rates.sum()
    draws = rng.choice(n_probe, size=wl.wal_entries, p=p)
    distinct = len(np.unique(draws))
    return wl.wal_entries / max(distinct, 1)


def achievable_throughput(plat: KvPlatform, wl: KvWorkload,
                          dram_bytes: float) -> Dict[str, float]:
    """Paper Fig. 8: achievable ops/s for one platform/workload point."""
    gamma = gamma_from_mix(wl.get_frac * 100, (1 - wl.get_frac) * 100)
    peak = float(iops_ssd_peak(plat.ssd, plat.bucket_bytes, gamma, 3.0))
    ssd_iops = plat.util_cap * peak * plat.n_ssd   # device-only bound;
    # the host budget is applied as its own bound below

    # hot-pair cache: hit rate from the interval profile at this capacity
    prof = LogNormalWorkload.from_total_throughput(
        throughput=1.0, sigma=wl.sigma, n_blk=wl.n_items,
        l_blk=wl.item_bytes)
    hit = float(prof.hit_rate_for_capacity(dram_bytes))

    c = wal_coalescing(wl)
    f_put = 1.0 - wl.get_frac
    # SSD IOs per logical op
    io_get = wl.get_frac * (1.0 - hit) * 1.5
    io_put = f_put * 2.0 / c
    io_per_op = io_get + io_put
    # host issues every SSD IO (+ minor cache work, ignored)
    host_bound = plat.host_iops / max(io_per_op, 1e-12)
    ssd_bound = ssd_iops / max(io_per_op, 1e-12)
    # DRAM traffic: hits read the item; misses DMA the bucket + read
    bytes_per_op = (wl.get_frac * hit * wl.item_bytes
                    + wl.get_frac * (1 - hit) * 2.0 * plat.bucket_bytes
                    + f_put * (2.0 / c) * plat.bucket_bytes)
    dram_bound = plat.b_dram / max(bytes_per_op, 1e-12)

    tput = min(host_bound, ssd_bound, dram_bound)
    limiter = {host_bound: "host-iops", ssd_bound: "ssd",
               dram_bound: "dram-bw"}[min(host_bound, ssd_bound,
                                          dram_bound)]
    return {
        "throughput": tput, "limiter": limiter, "hit_rate": hit,
        "ssd_iops_usable": ssd_iops, "io_per_op": io_per_op,
        "coalescing": c, "peak_iops_per_ssd": peak,
    }
