"""Runtime-backed KV store — the paper's §VII-A workload on the shared
async movement engine.

`TimedCuckooStore` fronts a `BlockedCuckooStore` with the same
`AsyncTierRuntime` that serves the LLM-session KV and MoE-expert
workloads: every bucket probe becomes a flash-tier transfer with
queueing-aware service time from the calibrated ssdsim model, hot-pair
cache hits become DRAM transfers, and WAL commits become batched flash
writes. On the runtime's virtual clock this yields modeled GET/PUT
latencies (and stall under load) that respond to queue depth — the thing
the seed's fixed-latency accounting could not express.

`get_many` is the async path: all probes are issued back-to-back (the
flash queue pipelines them, miss-under-miss) and waited at the end —
batched 512B reads, the device-side pattern behind the paper's Fig. 8
throughput claims.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Tuple

from ..core.policy import Tier
from ..runtime.async_engine import AsyncTierRuntime
from .cuckoo import BlockedCuckooStore

BLOCK = 512          # one bucket == one 512B flash block
ITEM = 8             # key+value pair bytes in the scaled-down store


class TimedCuckooStore:
    def __init__(self, n_buckets: int, slots: int = 8,
                 dram_cache_items: int = 0, wal_limit: int = 256,
                 runtime: Optional[AsyncTierRuntime] = None,
                 clock=None, seed: int = 0):
        self.inner = BlockedCuckooStore(
            n_buckets, slots=slots, dram_cache_items=dram_cache_items,
            wal_limit=wal_limit, seed=seed)
        self.runtime = runtime or AsyncTierRuntime(clock=clock)
        self.clock = self.runtime.clock

    # ------------------------------------------------------------- internal
    def _charge_delta(self, before) -> List:
        """Submit transfers for the flash blocks the wrapped op touched
        (reads are always kind='fetch' — including a WAL commit's
        read-modify-write reads — writes kind='write')."""
        st = self.inner.stats
        trs = []
        for _ in range(st.block_reads - before[0]):
            trs.append(self.runtime.submit(Tier.FLASH, None, BLOCK,
                                           kind="fetch"))
        for _ in range(st.block_writes - before[1]):
            trs.append(self.runtime.submit(Tier.FLASH, None, BLOCK,
                                           kind="write"))
        return trs

    def _snap(self) -> Tuple[int, int]:
        return (self.inner.stats.block_reads, self.inner.stats.block_writes)

    # ------------------------------------------------------------------ api
    def get(self, key: int) -> Optional[int]:
        """Synchronous GET: blocks the clock for the queueing-aware time
        of its 1-2 bucket reads (or a DRAM hit)."""
        before = self._snap()
        hits0 = self.inner.stats.cache_hits
        val = self.inner.get(key)
        trs = self._charge_delta(before)
        if not trs and self.inner.stats.cache_hits > hits0:
            trs = [self.runtime.submit(Tier.DRAM, key, ITEM, kind="fetch")]
        for tr in trs:
            self.runtime.wait(tr)
        return val

    def get_many(self, keys: Iterable[int]) -> List[Optional[int]]:
        """Batched async GETs: issue every probe, then wait once — deep
        queue, pipelined service, far lower per-op stall than serial."""
        vals, all_trs = [], []
        for key in keys:
            before = self._snap()
            vals.append(self.inner.get(key))
            all_trs.extend(self._charge_delta(before))
        for tr in all_trs:
            self.runtime.wait(tr)
        return vals

    def put(self, key: int, value: int):
        """PUT appends to the WAL (DRAM charge); a triggered commit's
        read-modify-writes stream on the flash queue."""
        before = self._snap()
        self.inner.put(key, value)
        self.runtime.submit(Tier.DRAM, key, ITEM, kind="write")
        self._charge_delta(before)                  # WAL flush, if any

    def flush(self):
        before = self._snap()
        self.inner.flush()
        for tr in self._charge_delta(before):
            self.runtime.wait(tr)

    # ---------------------------------------------------------------- stats
    @property
    def stats(self):
        return self.inner.stats

    def modeled_report(self) -> str:
        return self.runtime.report()
