"""SSD-resident blocked-Cuckoo KV store (paper §VII-A), runnable.

Design mirrors the paper exactly:
  * the hash table lives entirely on the (emulated) flash tier — one
    bucket == one 512B flash block == `slots` fixed-size KV pairs; there
    is NO DRAM-resident index or metadata,
  * each key maps to two candidate buckets (two independent hashes);
    lookups read 1-2 blocks (expected 1.5 at random),
  * inserts use cuckoo displacement chains instead of discards (load
    factor up to ~0.95 for slots >= 4 per Pagh & Rodler / Kirsch et al.),
  * all available DRAM is a hot-pair cache in front of the table,
  * durability via a write-ahead log that coalesces updates per bucket
    before committing (amortizing read-modify-write).

Batched GETs go through the `cuckoo_probe` Pallas kernel (the TPU analogue
of the 512B random-read path); the pure-python path is kept for inserts
and as the oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

_H1 = np.uint32(0x9E3779B1)
_H2 = np.uint32(0x85EBCA77)


def h1(keys: np.ndarray, n_buckets: int) -> np.ndarray:
    k = keys.astype(np.uint32)
    return (((k * _H1) ^ (k >> np.uint32(16)))
            % np.uint32(n_buckets)).astype(np.int64)


def h2(keys: np.ndarray, n_buckets: int) -> np.ndarray:
    k = keys.astype(np.uint32)
    return (((k * _H2) ^ (k >> np.uint32(13)))
            % np.uint32(n_buckets)).astype(np.int64)


@dataclasses.dataclass
class StoreStats:
    gets: int = 0
    puts: int = 0
    inserts: int = 0
    updates: int = 0
    relocations: int = 0
    failed_inserts: int = 0
    block_reads: int = 0
    block_writes: int = 0
    cache_hits: int = 0
    wal_appends: int = 0
    wal_flushes: int = 0


class BlockedCuckooStore:
    """int32 key -> int32 value store (fixed-size pairs, paper's 64B items
    scaled down; the geometry — pairs per 512B block — is preserved)."""

    def __init__(self, n_buckets: int, slots: int = 8,
                 dram_cache_items: int = 0, wal_limit: int = 256,
                 max_chain: int = 64, seed: int = 0):
        self.nb = n_buckets
        self.slots = slots
        self.keys = np.zeros((n_buckets, slots), np.int32)   # 0 = empty
        self.vals = np.zeros((n_buckets, slots), np.int32)
        self.stats = StoreStats()
        self.max_chain = max_chain
        self.rng = np.random.default_rng(seed)
        # DRAM: hot-pair cache only (no index!)
        self.cache_cap = dram_cache_items
        self.cache: Dict[int, int] = {}
        # WAL: pending updates coalesced per bucket
        self.wal_limit = wal_limit
        self.wal: List[Tuple[int, int]] = []

    # ---------------------------------------------------------------- reads
    def get(self, key: int) -> Optional[int]:
        self.stats.gets += 1
        for k, v in reversed(self.wal):          # WAL is authoritative
            if k == key:
                return v
        if key in self.cache:
            self.stats.cache_hits += 1
            self._cache_touch(key, self.cache[key])
            return self.cache[key]
        for b in (int(h1(np.asarray([key]), self.nb)[0]),
                  int(h2(np.asarray([key]), self.nb)[0])):
            self.stats.block_reads += 1
            hit = np.nonzero(self.keys[b] == key)[0]
            if len(hit):
                val = int(self.vals[b, hit[0]])
                self._cache_touch(key, val)
                return val
        return None

    def get_batch(self, keys: np.ndarray, use_kernel: bool = True
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized GET path (misses the WAL/cache layers on purpose —
        this is the raw flash-path benchmark; found flags returned)."""
        self.stats.gets += len(keys)
        self.stats.block_reads += 2 * len(keys)
        if use_kernel:
            import jax.numpy as jnp
            from ..kernels.cuckoo_probe.ops import cuckoo_probe
            f, v = cuckoo_probe(jnp.asarray(keys, jnp.int32),
                                jnp.asarray(self.keys),
                                jnp.asarray(self.vals))
            return np.asarray(f), np.asarray(v)
        from ..kernels.cuckoo_probe.ref import reference_cuckoo_probe
        import jax.numpy as jnp
        from ..kernels.cuckoo_probe.ops import hash_pair
        f, v = reference_cuckoo_probe(
            jnp.asarray(keys, jnp.int32),
            *hash_pair(jnp.asarray(keys, jnp.int32), self.nb),
            jnp.asarray(self.keys), jnp.asarray(self.vals))
        return np.asarray(f), np.asarray(v)

    # --------------------------------------------------------------- writes
    def put(self, key: int, value: int):
        """Durable write: append to WAL; commit when the WAL fills."""
        assert key != 0, "key 0 is the empty sentinel"
        self.stats.puts += 1
        self.stats.wal_appends += 1
        self.wal.append((key, value))
        if key in self.cache:
            self.cache[key] = value
        if len(self.wal) >= self.wal_limit:
            self.flush()

    def flush(self):
        """Commit WAL entries, coalescing updates that hit the same bucket
        (one read-modify-write per touched bucket, as in the paper)."""
        if not self.wal:
            return
        self.stats.wal_flushes += 1
        latest: Dict[int, int] = {}
        for k, v in self.wal:
            latest[k] = v
        self.wal.clear()
        buckets: Dict[int, List[Tuple[int, int]]] = {}
        karr = np.fromiter(latest.keys(), np.int64)
        b1s = h1(karr, self.nb)
        for k, b in zip(karr, b1s):
            buckets.setdefault(int(b), []).append((int(k), latest[int(k)]))
        for b, items in buckets.items():
            self.stats.block_reads += 1          # read-modify-write
            for k, v in items:
                self._insert_now(k, v)
            self.stats.block_writes += 1

    def _insert_now(self, key: int, value: int):
        b1_, b2_ = (int(h1(np.asarray([key]), self.nb)[0]),
                    int(h2(np.asarray([key]), self.nb)[0]))
        # update in place if present
        for b in (b1_, b2_):
            hit = np.nonzero(self.keys[b] == key)[0]
            if len(hit):
                self.vals[b, hit[0]] = value
                self.stats.updates += 1
                return
        # insert into a free slot
        for b in (b1_, b2_):
            free = np.nonzero(self.keys[b] == 0)[0]
            if len(free):
                self.keys[b, free[0]] = key
                self.vals[b, free[0]] = value
                self.stats.inserts += 1
                return
        # displacement chain
        cur_k, cur_v, b = key, value, b1_
        for _ in range(self.max_chain):
            s = int(self.rng.integers(0, self.slots))
            cur_k, self.keys[b, s] = int(self.keys[b, s]), cur_k
            cur_v, self.vals[b, s] = int(self.vals[b, s]), cur_v
            self.stats.relocations += 1
            self.stats.block_reads += 1
            self.stats.block_writes += 1
            alt1, alt2 = (int(h1(np.asarray([cur_k]), self.nb)[0]),
                          int(h2(np.asarray([cur_k]), self.nb)[0]))
            b = alt2 if b == alt1 else alt1
            free = np.nonzero(self.keys[b] == 0)[0]
            if len(free):
                self.keys[b, free[0]] = cur_k
                self.vals[b, free[0]] = cur_v
                self.stats.inserts += 1
                return
        self.stats.failed_inserts += 1
        raise RuntimeError(
            f"cuckoo insert failed at load factor {self.load_factor():.3f}")

    # ----------------------------------------------------------------- misc
    def _cache_touch(self, key: int, val: int):
        if not self.cache_cap:
            return
        self.cache[key] = val
        while len(self.cache) > self.cache_cap:   # FIFO-ish eviction
            self.cache.pop(next(iter(self.cache)))

    def load_factor(self) -> float:
        return float((self.keys != 0).sum()) / self.keys.size

    def expected_chain_len(self) -> float:
        """Paper's estimate E[L] ~= alpha^(2B) / (1 - alpha^B)."""
        a = self.load_factor()
        B = self.slots
        return a ** (2 * B) / max(1.0 - a ** B, 1e-9)
