"""TieredStore — the paper's break-even analysis driving a live
HBM / host-DRAM / Storage-Next-flash object store.

On this container the tiers are emulated pools (numpy arrays + accounting)
with the calibrated cost/latency model attached from `repro.core`; the
decision logic, movement, hit/miss accounting and capacity pressure are
real. On a TPU host the same API fronts device HBM, host memory, and an
NVMe path.

Placement policy: `core.policy.TieringPolicy` (EMA of observed reuse
intervals vs the calibrated break-even thresholds, with hysteresis).
Capacity pressure triggers demotion of the stalest objects (the policy's
evict_candidates order), so each tier holds exactly the hot set S(T) the
paper's §V analysis prescribes.

Timing model (new in the async runtime): accesses are *transfers* on an
`AsyncTierRuntime`. Flash fetch latency derives from the calibrated
ssdsim queueing engine — it varies with queue depth instead of being a
fixed scalar — and `get_async` exposes the split issue/wait form so
callers (serving prefetch, expert streaming) can overlap fetches with
compute. All timing flows through an injectable clock (deterministic
`VirtualClock` by default; see `runtime.clock` for the testing contract).

Admission control (Flashield-style write shielding): when constructed
with `write_shield_depth=k`, a demotion's destination write is *deferred*
while the destination tier has >= k fetches in flight — the queue-depth
forecast says a read burst is underway and the write would inflate its
tail. The object moves structurally at once (capacity accounting is
immediate); only the queue charge parks in a deferred list, drained when
the read depth falls below the threshold (checked on every subsequent
store operation, or explicitly via `flush_deferred_writes`). Deferral
counts land in `TierStats.demotions_deferred` / `deferred_bytes`.

Capacity contract: an object larger than its target tier's capacity is
demoted straight to the first tier that can hold it (ultimately FLASH,
the capacity tier) instead of silently overcommitting; an object larger
than every tier raises ValueError.

Economic admission (autopilot): when the policy exposes `admit_tier`
(see `autopilot.gate.EconomicGate`), every `put` asks it where the
object should land — DRAM iff the tracked reuse-interval estimate
clears the calibrated break-even threshold — instead of honoring the
requested tier blindly. Plain `TieringPolicy` has no such hook and
keeps the seed behavior.

Readability gating (conservative rebalance pricing): an `ingest` whose
bytes are still on the wire (`not_before` = the NIC delivery time)
records that arrival horizon, and any fetch of the key issued before it
is gated on it — a mid-rebalance restore pays for the in-flight leg
instead of being served structurally-now at the destination.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.policy import Tier, TieringPolicy
from .async_engine import AsyncTierRuntime, Transfer
from .clock import ensure_clock


def lead_steps_from_estimate(est: float, step_time: float) -> int:
    """Decode steps a prefetch must lead by to cover a fetch estimate
    (`ceil(est / step_time)`, >= 1; 1 when step time is unknown). The
    single definition both the store and fabric lead sizing use."""
    if step_time <= 0:
        return 1
    return max(1, math.ceil(est / step_time))


@dataclasses.dataclass
class TierSpec:
    capacity_bytes: float
    read_bw: float              # bytes/s (for modeled latency accounting)
    read_latency: float         # seconds per access (fixed part)
    # write-path bandwidth when asymmetric (flash program vs read, the
    # pool's ingest lane); None inherits read_bw — the historic behavior
    write_bw: Optional[float] = None

    @property
    def effective_write_bw(self) -> float:
        return self.read_bw if self.write_bw is None else self.write_bw


@dataclasses.dataclass
class TierStats:
    hits: int = 0
    misses: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    modeled_time: float = 0.0
    stall_time: float = 0.0
    promotions: int = 0
    demotions: int = 0
    prefetch_hits: int = 0      # async fetch finished before wait
    prefetch_late: int = 0      # wait still had to block
    demotions_deferred: int = 0  # demotion writes parked by write shielding
    rebalance_deferred: int = 0  # rebalance ingest writes parked likewise
    deferred_bytes: int = 0      # bytes all parked writes will move

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


@dataclasses.dataclass
class PendingFetch:
    """Handle for an in-flight `get_async`; `wait()` yields the value and
    records only the *residual* stall (zero when the fetch overlapped).

    `external_done_t` lets a composing layer (the fabric's remote fetch:
    flash + NIC) extend the completion horizon so prefetch hit/late
    classification reflects the full composition, not just this leg."""
    store: "TieredStore"
    key: object
    tier: Tier
    transfer: Transfer
    value: np.ndarray
    external_done_t: Optional[float] = None

    def done(self) -> bool:
        done_t = self.transfer.done_t
        if self.external_done_t is not None:
            done_t = max(done_t, self.external_done_t)
        return self.store.clock.now() >= done_t - 1e-12

    def wait(self) -> np.ndarray:
        self.store._finish_fetch(self)
        return self.value


class TieredStore:
    """Key -> ndarray store spanning three tiers with policy movement."""

    def __init__(self, policy: TieringPolicy,
                 specs: Optional[Dict[Tier, TierSpec]] = None,
                 clock=None, runtime: Optional[AsyncTierRuntime] = None,
                 sim_cfg=None, write_shield_depth: Optional[int] = None,
                 obs=None, ledger=None, label: str = "host0"):
        # defaults: v5e-host-like HBM/DRAM plus a Storage-Next SSD tier
        self.specs = specs or {
            Tier.HBM: TierSpec(16e9, 819e9, 1e-7),
            Tier.DRAM: TierSpec(128e9, 45e9, 5e-7),
            Tier.FLASH: TierSpec(4e12, 7e9, 2e-5),
        }
        self.policy = policy
        if runtime is not None:
            self.runtime = runtime
            self.clock = runtime.clock
        else:
            self.clock = ensure_clock(clock)
            self.runtime = AsyncTierRuntime(clock=self.clock,
                                            specs=self.specs,
                                            sim_cfg=sim_cfg, obs=obs,
                                            ledger=ledger, label=label)
        # the store's observability is its runtime's (one ledger, one
        # label — the runtime is where stall materializes)
        self.obs = self.runtime.obs
        self.ledger = self.runtime.ledger
        self.label = self.runtime.label
        # iteration order is the *configured* tier set, hot-to-cold —
        # never `for t in Tier`: a store compiled without the fourth
        # tier must behave bit-identically whether or not the enum has
        # grown new members
        self.tiers: Tuple[Tier, ...] = tuple(sorted(self.specs))
        self._data: Dict[Tier, Dict[object, np.ndarray]] = {
            t: {} for t in self.tiers}
        self._used = {t: 0 for t in self.tiers}
        self.stats: Dict[Tier, TierStats] = {
            t: TierStats() for t in self.tiers}
        if write_shield_depth is not None and write_shield_depth < 1:
            raise ValueError("write_shield_depth must be >= 1 (a zero "
                             "threshold would shield forever)")
        self.write_shield_depth = write_shield_depth
        # parked (tier, key, nbytes, not_before) — the gate keeps a
        # shielded rebalance write behind its upstream NIC delivery
        self._deferred_writes: List[
            Tuple[Tier, object, int, Optional[float]]] = []
        # key -> wire-arrival horizon of an in-flight rebalance ingest;
        # reads issued before it are gated on it (readability gating)
        self._arrival_t: Dict[object, float] = {}

    # ----------------------------------------------------------------- util
    def tier_of(self, key) -> Optional[Tier]:
        for t in self.tiers:
            if key in self._data[t]:
                return t
        return None

    def used_bytes(self, tier: Tier) -> int:
        # .get: fleet-level callers sum over all Tier members; a tier
        # this store does not configure (gpu_flash, pool) holds nothing
        return self._used.get(tier, 0)

    def keys(self) -> List[object]:
        """All resident keys across tiers (hot-to-cold tier order)."""
        out: List[object] = []
        for t in self.tiers:
            out.extend(self._data[t])
        return out

    def nbytes_of(self, key) -> int:
        cur = self.tier_of(key)
        if cur is None:
            raise KeyError(key)
        return self._data[cur][key].nbytes

    def reset_stats(self):
        """Zero all per-tier `TierStats` and the runtime's `QueueStats`
        without touching structural state (residency, capacity, parked
        deferred writes, in-flight transfers). Benchmarks call this after
        their setup/warm-up phase so repetitions on a reused store don't
        inherit stale counters — the deferral counters in particular
        accumulate across reps otherwise."""
        self.stats = {t: TierStats() for t in self.tiers}
        self.runtime.reset_stats()

    def snapshot_stats(self) -> Dict[str, object]:
        """Per-tier `TierStats` plus the runtime's lane stats, as plain
        dicts (the `MetricsRegistry` snapshot/reset protocol)."""
        out: Dict[str, object] = {
            t.name: dataclasses.asdict(st) for t, st in self.stats.items()}
        out["runtime"] = self.runtime.snapshot_stats()
        return out

    # ------------------------------------------------------------------ api
    def put(self, key, value: np.ndarray, tier: Tier = Tier.DRAM):
        value = np.asarray(value)
        self.flush_deferred_writes()
        cur = self.tier_of(key)
        if cur is not None:
            self._remove(key, cur)
        admit = getattr(self.policy, "admit_tier", None)
        if admit is not None:
            # economic admission: the gate prices the object's tracked
            # reuse estimate against break-even and may demote the
            # requested landing tier (it never promotes past the ask)
            tier = admit(key, tier, now=self.clock.now())
        tier = self._fit_tier(tier, value.nbytes)
        self._ensure_room(tier, value.nbytes)
        self._data[tier][key] = value
        self._used[tier] += value.nbytes
        self.stats[tier].bytes_written += value.nbytes
        self.runtime.submit(tier, key, value.nbytes, kind="write")
        self.policy.observe(key, now=self.clock.now())

    def _issue_fetch(self, key) -> PendingFetch:
        self.flush_deferred_writes()
        cur = self.tier_of(key)
        if cur is None:
            raise KeyError(key)
        for t in self.tiers:
            if t == cur:
                self.stats[t].hits += 1
            elif t < min(cur, Tier.FLASH):
                # tiers warmer than the serving one record a miss; the
                # min() keeps GPU_FLASH from charging FLASH a miss —
                # they are parallel paths to the same NAND, not a
                # warmer/colder pair (no-op for 3-tier stores, where
                # cur never exceeds FLASH)
                self.stats[t].misses += 1
        value = self._data[cur][key]
        tr = self.runtime.submit(cur, key, value.nbytes, kind="fetch",
                                 not_before=self._arrival_gate(key))
        if cur == Tier.FLASH:
            # a flash restore of a key the gate priced out of DRAM is a
            # *policy* cost, not a media cost — the ledger attributes its
            # service seconds to gate_miss_restore
            priced_out = getattr(self.policy, "priced_out", None)
            if priced_out is not None and priced_out(key):
                tr.gate_miss = True
        self.stats[cur].bytes_read += value.nbytes
        return PendingFetch(store=self, key=key, tier=cur, transfer=tr,
                            value=value)

    def _finish_fetch(self, pf: PendingFetch, now: Optional[float] = None):
        st = self.stats[pf.tier]
        # a fetch only counts as a prefetch if compute time passed
        # between issue and wait; a same-instant wait is a plain
        # synchronous get and must not pollute the prefetch counters
        if self.clock.now() > pf.transfer.issue_t:
            if pf.done():
                st.prefetch_hits += 1
            else:
                st.prefetch_late += 1
        stall = self.runtime.wait(pf.transfer)
        st.stall_time += stall
        st.modeled_time += pf.transfer.done_t - pf.transfer.issue_t
        now = self.clock.now() if now is None else now
        want = self.policy.observe(pf.key, now=now)
        cur = self.tier_of(pf.key)
        if cur is not None and want != cur and not (
                want == Tier.FLASH and cur == Tier.GPU_FLASH):
            # a FLASH want is satisfied by GPU_FLASH residency: both are
            # the same NAND, and shuttling between the two paths is
            # never what the reuse interval asked for
            self._move(pf.key, cur, want)
        self.flush_deferred_writes()

    def get(self, key, now: Optional[float] = None) -> np.ndarray:
        """Synchronous fetch: blocks the clock for the full queueing-aware
        service time."""
        pf = self._issue_fetch(key)
        self._finish_fetch(pf, now=now)
        return pf.value

    def get_async(self, key) -> PendingFetch:
        """Issue a non-blocking fetch; the caller overlaps compute and
        calls `.wait()` when the value is actually needed."""
        return self._issue_fetch(key)

    def read_for_transfer(self, key, not_before: Optional[float] = None):
        """Raw outbound read for fabric rebalance streaming: occupies the
        resident tier's queue and counts bytes, but is neither a cache
        hit nor a policy observation (rebalance traffic must not promote
        keys or skew hit rates). `not_before` gates the read start (the
        fabric's pacing token bucket); a pending wire arrival of the key
        itself gates it as well. Returns (value, transfer)."""
        cur = self.tier_of(key)
        if cur is None:
            raise KeyError(key)
        value = self._data[cur][key]
        gate = self._arrival_gate(key)
        if not_before is not None:
            gate = not_before if gate is None else max(gate, not_before)
        tr = self.runtime.submit(cur, key, value.nbytes, kind="rebalance",
                                 not_before=gate)
        self.stats[cur].bytes_read += value.nbytes
        return value, tr

    def _arrival_gate(self, key) -> Optional[float]:
        """Readability gate: the NIC-delivery horizon of an in-flight
        rebalance ingest of `key`, if still in the future (entries are
        pruned once passed)."""
        t = self._arrival_t.get(key)
        if t is None:
            return None
        if self.clock.now() >= t - 1e-12:
            del self._arrival_t[key]
            return None
        return t

    def ingest(self, key, value: np.ndarray, tier: Tier = Tier.FLASH,
               not_before: Optional[float] = None):
        """Inbound rebalance placement: the object lands structurally at
        once, but the destination write is subject to write shielding
        exactly like a demotion — while this tier has a read burst in
        flight (depth >= `write_shield_depth`) the queue charge parks in
        the deferred list instead of inflating the burst's tail.
        `not_before` gates an unshielded write on the upstream NIC
        delivery, and also records the key's readability horizon: a
        fetch issued before the bytes arrive is gated on the delivery
        instead of being served structurally-now. No policy observation:
        arrival by rebalance is not a reuse event."""
        value = np.asarray(value)
        cur = self.tier_of(key)
        if cur is not None:
            self._remove(key, cur)
        tier = self._fit_tier(tier, value.nbytes)
        self._ensure_room(tier, value.nbytes)
        if not_before is not None and not_before > self.clock.now():
            self._arrival_t[key] = float(not_before)
        self._data[tier][key] = value
        self._used[tier] += value.nbytes
        st = self.stats[tier]
        st.bytes_written += value.nbytes
        if self._shielded(tier):
            # parked like a deferred demotion write (same flush path)
            # but counted separately so the Flashield stat stays pure;
            # the NIC gate parks with it so a flush after the burst
            # drains still cannot write bytes that have not arrived
            st.rebalance_deferred += 1
            st.deferred_bytes += value.nbytes
            self._deferred_writes.append((tier, key, value.nbytes,
                                          not_before))
            self._trace_deferral("rebalance_write_deferred", tier, key,
                                 value.nbytes)
        else:
            self.runtime.submit(tier, key, value.nbytes, kind="write",
                                not_before=not_before)

    def delete(self, key):
        cur = self.tier_of(key)
        if cur is not None:
            self._remove(key, cur)

    # ------------------------------------------------------------- movement
    def _remove(self, key, tier: Tier):
        v = self._data[tier].pop(key)
        self._used[tier] -= v.nbytes
        # a fresh copy supersedes any pending wire arrival of the key
        self._arrival_t.pop(key, None)
        # a parked deferred write for this key is now stale (the object
        # was deleted, overwritten or moved on): drop it so the shield
        # never submits a phantom write for data that no longer exists
        if self._deferred_writes:
            self._deferred_writes = [e for e in self._deferred_writes
                                     if e[1] != key]
        return v

    def move(self, key, dst: Tier):
        """Queue a movement of `key` to `dst` (non-blocking: structure
        updates now, the transfer streams in the background)."""
        src = self.tier_of(key)
        if src is None:
            raise KeyError(key)
        if src != dst:
            self._move(key, src, dst)

    def _move(self, key, src: Tier, dst: Tier):
        # a tier move does not materialize in-flight bytes: keep the
        # readability gate a pending rebalance ingest recorded
        arrival = self._arrival_t.get(key)
        v = self._remove(key, src)
        if arrival is not None:
            self._arrival_t[key] = arrival
        dst = self._fit_tier(dst, v.nbytes)
        if dst == src:
            # an oversized promotion target redirected back onto the
            # source tier: nothing to move
            self._data[src][key] = v
            self._used[src] += v.nbytes
            return
        self._ensure_room(dst, v.nbytes)
        self._data[dst][key] = v
        self._used[dst] += v.nbytes
        self.stats[dst].bytes_written += v.nbytes
        self.stats[src].bytes_read += v.nbytes
        demote = dst > src
        # movement occupies both queues: the read on the source tier
        # (a promotion out of flash contends with KV prefetches there)
        # and the write on the destination
        self.runtime.submit(src, key, v.nbytes,
                            kind="demote" if demote else "promote")
        if demote and self._shielded(dst):
            st = self.stats[dst]
            st.demotions_deferred += 1
            st.deferred_bytes += v.nbytes
            self._deferred_writes.append((dst, key, v.nbytes, None))
            self._trace_deferral("demotion_write_deferred", dst, key,
                                 v.nbytes)
        else:
            self.runtime.submit(dst, key, v.nbytes, kind="write")
        if demote:
            self.stats[dst].demotions += 1
        else:
            self.stats[dst].promotions += 1

    def _trace_deferral(self, name: str, tier: Tier, key,
                        nbytes: int) -> None:
        if self.obs is not None and self.obs.tracer is not None:
            t = self.obs.tracer
            t.instant(t.track(self.label, tier.name), name,
                      self.clock.now(), cat="shield",
                      args={"key": str(key), "nbytes": int(nbytes)})

    # ----------------------------------------------------- write shielding
    def _shielded(self, tier: Tier) -> bool:
        return (self.write_shield_depth is not None
                and self.runtime.read_depth(tier) >= self.write_shield_depth)

    def flush_deferred_writes(self) -> int:
        """Submit parked demotion writes whose destination read burst has
        drained; returns how many were flushed. Entries for a still-
        shielded tier stay parked (per-tier FIFO order preserved) without
        blocking writes bound for other, unshielded tiers."""
        flushed = 0
        keep: List[Tuple[Tier, object, int, Optional[float]]] = []
        for dst, key, nbytes, not_before in self._deferred_writes:
            if self._shielded(dst):
                keep.append((dst, key, nbytes, not_before))
            else:
                self.runtime.submit(dst, key, nbytes, kind="write",
                                    not_before=not_before)
                flushed += 1
        self._deferred_writes = keep
        return flushed

    @property
    def deferred_writes_pending(self) -> int:
        return len(self._deferred_writes)

    # ------------------------------------------------------------- capacity
    def _fit_tier(self, tier: Tier, nbytes: int) -> Tier:
        """First tier at or below `tier` whose capacity can hold the
        object; raises if even the capacity tier cannot. GPU_FLASH is
        only ever an *explicit* destination — capacity overflow from
        the warm tiers falls through to FLASH, never sideways into the
        accelerator-direct namespace."""
        for t in self.tiers:
            if t < tier or (t == Tier.GPU_FLASH and tier != Tier.GPU_FLASH):
                continue
            if nbytes <= self.specs[t].capacity_bytes:
                return t
        if (tier == Tier.GPU_FLASH and Tier.FLASH in self.specs
                and nbytes <= self.specs[Tier.FLASH].capacity_bytes):
            return Tier.FLASH
        raise ValueError(
            f"object of {nbytes} bytes exceeds every tier's capacity")

    def _ensure_room(self, tier: Tier, nbytes: int):
        """Demote stalest residents until `nbytes` fits (FLASH never
        evicts — it is the capacity tier). `_fit_tier` has already
        guaranteed the object fits an empty `tier`, so the loop always
        makes progress; the guard raise is defensive."""
        spec = self.specs[tier]
        while self._used[tier] + nbytes > spec.capacity_bytes \
                and tier not in (Tier.FLASH, Tier.GPU_FLASH):
            victims = [k for k in self.policy.evict_candidates(
                           tier, now=self.clock.now())
                       if k in self._data[tier]]
            if not victims:
                victims = list(self._data[tier])
            if not victims:
                raise RuntimeError(
                    f"cannot make room in {tier.name}: empty tier yet "
                    f"{nbytes} bytes exceed capacity {spec.capacity_bytes}")
            self._move(victims[0], tier, Tier(tier + 1))

    # ------------------------------------------------------- prefetch sizing
    def estimate_fetch_seconds(self, key) -> float:
        """Tail-aware estimate of a fetch of `key` issued now: occupancy
        at the tier's current depth plus the open-loop p99 access latency
        when the tier's service model calibrates one (flash), else the
        model's mean. This is what p99-sized prefetch leads are cut from
        — the mean under-sizes the lead exactly when the queue is deep."""
        cur = self.tier_of(key)
        if cur is None:
            raise KeyError(key)
        nbytes = self._data[cur][key].nbytes
        depth = self.runtime.queue_depth(cur) + 1
        model = self.runtime.models[cur]
        svc = model.service(nbytes, depth)
        p99 = getattr(model, "p99", None)
        lat = max(svc.latency, p99(depth)) if callable(p99) else svc.latency
        return svc.occupancy + lat

    def prefetch_lead_steps(self, key, step_time: float) -> int:
        """p99-sized prefetch lead: issue the restore
        `ceil(p99_fetch_estimate / step_time)` decode steps early (>= 1)
        instead of a fixed lead."""
        return lead_steps_from_estimate(self.estimate_fetch_seconds(key),
                                        step_time)

    # ---------------------------------------------------------------- report
    def report(self) -> str:
        lines = []
        for t in self.tiers:
            st = self.stats[t]
            lines.append(
                f"{t.name:6s} used={self._used[t]/2**20:9.1f}MiB "
                f"objs={len(self._data[t]):6d} hit_rate={st.hit_rate:.3f} "
                f"read={st.bytes_read/2**20:9.1f}MiB "
                f"t_model={st.modeled_time*1e3:8.2f}ms "
                f"stall={st.stall_time*1e3:8.2f}ms "
                f"promo={st.promotions} demo={st.demotions}")
        return "\n".join(lines)
