"""TieredStore — the paper's break-even analysis driving a live
HBM / host-DRAM / Storage-Next-flash object store.

On this container the tiers are emulated pools (numpy arrays + accounting)
with the calibrated cost/latency model attached from `repro.core`; the
decision logic, movement, hit/miss accounting and capacity pressure are
real. On a TPU host the same API fronts device HBM, host memory, and an
NVMe path.

Placement policy: `core.policy.TieringPolicy` (EMA of observed reuse
intervals vs the calibrated break-even thresholds, with hysteresis).
Capacity pressure triggers demotion of the stalest objects (the policy's
evict_candidates order), so each tier holds exactly the hot set S(T) the
paper's §V analysis prescribes.

Timing model (new in the async runtime): accesses are *transfers* on an
`AsyncTierRuntime`. Flash fetch latency derives from the calibrated
ssdsim queueing engine — it varies with queue depth instead of being a
fixed scalar — and `get_async` exposes the split issue/wait form so
callers (serving prefetch, expert streaming) can overlap fetches with
compute. All timing flows through an injectable clock (deterministic
`VirtualClock` by default; see `runtime.clock` for the testing contract).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..core.policy import Tier, TieringPolicy
from .async_engine import AsyncTierRuntime, Transfer
from .clock import ensure_clock


@dataclasses.dataclass
class TierSpec:
    capacity_bytes: float
    read_bw: float              # bytes/s (for modeled latency accounting)
    read_latency: float         # seconds per access (fixed part)


@dataclasses.dataclass
class TierStats:
    hits: int = 0
    misses: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    modeled_time: float = 0.0
    stall_time: float = 0.0
    promotions: int = 0
    demotions: int = 0
    prefetch_hits: int = 0      # async fetch finished before wait
    prefetch_late: int = 0      # wait still had to block

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


@dataclasses.dataclass
class PendingFetch:
    """Handle for an in-flight `get_async`; `wait()` yields the value and
    records only the *residual* stall (zero when the fetch overlapped)."""
    store: "TieredStore"
    key: object
    tier: Tier
    transfer: Transfer
    value: np.ndarray

    def done(self) -> bool:
        return self.transfer.is_done(self.store.clock.now())

    def wait(self) -> np.ndarray:
        self.store._finish_fetch(self)
        return self.value


class TieredStore:
    """Key -> ndarray store spanning three tiers with policy movement."""

    def __init__(self, policy: TieringPolicy,
                 specs: Optional[Dict[Tier, TierSpec]] = None,
                 clock=None, runtime: Optional[AsyncTierRuntime] = None,
                 sim_cfg=None):
        # defaults: v5e-host-like HBM/DRAM plus a Storage-Next SSD tier
        self.specs = specs or {
            Tier.HBM: TierSpec(16e9, 819e9, 1e-7),
            Tier.DRAM: TierSpec(128e9, 45e9, 5e-7),
            Tier.FLASH: TierSpec(4e12, 7e9, 2e-5),
        }
        self.policy = policy
        if runtime is not None:
            self.runtime = runtime
            self.clock = runtime.clock
        else:
            self.clock = ensure_clock(clock)
            self.runtime = AsyncTierRuntime(clock=self.clock,
                                            specs=self.specs,
                                            sim_cfg=sim_cfg)
        self._data: Dict[Tier, Dict[object, np.ndarray]] = {
            t: {} for t in Tier}
        self._used = {t: 0 for t in Tier}
        self.stats: Dict[Tier, TierStats] = {t: TierStats() for t in Tier}

    # ----------------------------------------------------------------- util
    def tier_of(self, key) -> Optional[Tier]:
        for t in Tier:
            if key in self._data[t]:
                return t
        return None

    def used_bytes(self, tier: Tier) -> int:
        return self._used[tier]

    # ------------------------------------------------------------------ api
    def put(self, key, value: np.ndarray, tier: Tier = Tier.DRAM):
        value = np.asarray(value)
        cur = self.tier_of(key)
        if cur is not None:
            self._remove(key, cur)
        self._ensure_room(tier, value.nbytes)
        self._data[tier][key] = value
        self._used[tier] += value.nbytes
        self.stats[tier].bytes_written += value.nbytes
        self.runtime.submit(tier, key, value.nbytes, kind="write")
        self.policy.observe(key, now=self.clock.now())

    def _issue_fetch(self, key) -> PendingFetch:
        cur = self.tier_of(key)
        if cur is None:
            raise KeyError(key)
        for t in Tier:
            if t == cur:
                self.stats[t].hits += 1
            elif t < cur:
                self.stats[t].misses += 1
        value = self._data[cur][key]
        tr = self.runtime.submit(cur, key, value.nbytes, kind="fetch")
        self.stats[cur].bytes_read += value.nbytes
        return PendingFetch(store=self, key=key, tier=cur, transfer=tr,
                            value=value)

    def _finish_fetch(self, pf: PendingFetch, now: Optional[float] = None):
        st = self.stats[pf.tier]
        # a fetch only counts as a prefetch if compute time passed
        # between issue and wait; a same-instant wait is a plain
        # synchronous get and must not pollute the prefetch counters
        if self.clock.now() > pf.transfer.issue_t:
            if pf.done():
                st.prefetch_hits += 1
            else:
                st.prefetch_late += 1
        stall = self.runtime.wait(pf.transfer)
        st.stall_time += stall
        st.modeled_time += pf.transfer.done_t - pf.transfer.issue_t
        now = self.clock.now() if now is None else now
        want = self.policy.observe(pf.key, now=now)
        cur = self.tier_of(pf.key)
        if cur is not None and want != cur:
            self._move(pf.key, cur, want)

    def get(self, key, now: Optional[float] = None) -> np.ndarray:
        """Synchronous fetch: blocks the clock for the full queueing-aware
        service time."""
        pf = self._issue_fetch(key)
        self._finish_fetch(pf, now=now)
        return pf.value

    def get_async(self, key) -> PendingFetch:
        """Issue a non-blocking fetch; the caller overlaps compute and
        calls `.wait()` when the value is actually needed."""
        return self._issue_fetch(key)

    def delete(self, key):
        cur = self.tier_of(key)
        if cur is not None:
            self._remove(key, cur)

    # ------------------------------------------------------------- movement
    def _remove(self, key, tier: Tier):
        v = self._data[tier].pop(key)
        self._used[tier] -= v.nbytes
        return v

    def move(self, key, dst: Tier):
        """Queue a movement of `key` to `dst` (non-blocking: structure
        updates now, the transfer streams in the background)."""
        src = self.tier_of(key)
        if src is None:
            raise KeyError(key)
        if src != dst:
            self._move(key, src, dst)

    def _move(self, key, src: Tier, dst: Tier):
        v = self._remove(key, src)
        self._ensure_room(dst, v.nbytes)
        self._data[dst][key] = v
        self._used[dst] += v.nbytes
        self.stats[dst].bytes_written += v.nbytes
        self.stats[src].bytes_read += v.nbytes
        kind = "promote" if dst < src else "demote"
        # movement occupies both queues: the read on the source tier
        # (a promotion out of flash contends with KV prefetches there)
        # and the write on the destination
        self.runtime.submit(src, key, v.nbytes, kind=kind)
        self.runtime.submit(dst, key, v.nbytes, kind="write")
        if dst < src:
            self.stats[dst].promotions += 1
        else:
            self.stats[dst].demotions += 1

    def _ensure_room(self, tier: Tier, nbytes: int):
        """Demote stalest residents until `nbytes` fits (FLASH never
        evicts — it is the capacity tier)."""
        spec = self.specs[tier]
        while self._used[tier] + nbytes > spec.capacity_bytes \
                and tier != Tier.FLASH:
            victims = [k for k in self.policy.evict_candidates(
                           tier, now=self.clock.now())
                       if k in self._data[tier]]
            if not victims:
                victims = list(self._data[tier])
            if not victims:
                break
            self._move(victims[0], tier, Tier(tier + 1))

    # ---------------------------------------------------------------- report
    def report(self) -> str:
        lines = []
        for t in Tier:
            st = self.stats[t]
            lines.append(
                f"{t.name:6s} used={self._used[t]/2**20:9.1f}MiB "
                f"objs={len(self._data[t]):6d} hit_rate={st.hit_rate:.3f} "
                f"read={st.bytes_read/2**20:9.1f}MiB "
                f"t_model={st.modeled_time*1e3:8.2f}ms "
                f"stall={st.stall_time*1e3:8.2f}ms "
                f"promo={st.promotions} demo={st.demotions}")
        return "\n".join(lines)
