"""TieredStore — the paper's break-even analysis driving a live
HBM / host-DRAM / Storage-Next-flash object store.

On this container the tiers are emulated pools (numpy arrays + accounting)
with the calibrated cost/latency model attached from `repro.core`; the
decision logic, movement, hit/miss accounting and capacity pressure are
real. On a TPU host the same API fronts device HBM, host memory, and an
NVMe path.

Placement policy: `core.policy.TieringPolicy` (EMA of observed reuse
intervals vs the calibrated break-even thresholds, with hysteresis).
Capacity pressure triggers demotion of the stalest objects (the policy's
evict_candidates order), so each tier holds exactly the hot set S(T) the
paper's §V analysis prescribes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import numpy as np

from ..core.policy import Tier, TieringPolicy


@dataclasses.dataclass
class TierSpec:
    capacity_bytes: float
    read_bw: float              # bytes/s (for modeled latency accounting)
    read_latency: float         # seconds per access (fixed part)


@dataclasses.dataclass
class TierStats:
    hits: int = 0
    misses: int = 0
    bytes_read: int = 0
    bytes_written: int = 0
    modeled_time: float = 0.0
    promotions: int = 0
    demotions: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class TieredStore:
    """Key -> ndarray store spanning three tiers with policy movement."""

    def __init__(self, policy: TieringPolicy,
                 specs: Optional[Dict[Tier, TierSpec]] = None,
                 clock: Callable[[], float] = None):
        # defaults: v5e-host-like HBM/DRAM plus a Storage-Next SSD tier
        self.specs = specs or {
            Tier.HBM: TierSpec(16e9, 819e9, 1e-7),
            Tier.DRAM: TierSpec(128e9, 45e9, 5e-7),
            Tier.FLASH: TierSpec(4e12, 7e9, 2e-5),
        }
        self.policy = policy
        self.clock = clock or time.monotonic
        self._data: Dict[Tier, Dict[object, np.ndarray]] = {
            t: {} for t in Tier}
        self._used = {t: 0 for t in Tier}
        self.stats: Dict[Tier, TierStats] = {t: TierStats() for t in Tier}

    # ----------------------------------------------------------------- util
    def tier_of(self, key) -> Optional[Tier]:
        for t in Tier:
            if key in self._data[t]:
                return t
        return None

    def used_bytes(self, tier: Tier) -> int:
        return self._used[tier]

    def _charge_read(self, tier: Tier, nbytes: int):
        st = self.stats[tier]
        st.bytes_read += nbytes
        st.modeled_time += self.specs[tier].read_latency \
            + nbytes / self.specs[tier].read_bw

    # ------------------------------------------------------------------ api
    def put(self, key, value: np.ndarray, tier: Tier = Tier.DRAM):
        value = np.asarray(value)
        cur = self.tier_of(key)
        if cur is not None:
            self._remove(key, cur)
        self._ensure_room(tier, value.nbytes)
        self._data[tier][key] = value
        self._used[tier] += value.nbytes
        self.stats[tier].bytes_written += value.nbytes
        self.policy.observe(key, now=self.clock())

    def get(self, key, now: Optional[float] = None) -> np.ndarray:
        now = self.clock() if now is None else now
        cur = self.tier_of(key)
        if cur is None:
            raise KeyError(key)
        for t in Tier:
            if t == cur:
                self.stats[t].hits += 1
            elif t < cur:
                self.stats[t].misses += 1
        value = self._data[cur][key]
        self._charge_read(cur, value.nbytes)
        want = self.policy.observe(key, now=now)
        if want != cur:
            self._move(key, cur, want)
        return value

    def delete(self, key):
        cur = self.tier_of(key)
        if cur is not None:
            self._remove(key, cur)

    # ------------------------------------------------------------- movement
    def _remove(self, key, tier: Tier):
        v = self._data[tier].pop(key)
        self._used[tier] -= v.nbytes
        return v

    def _move(self, key, src: Tier, dst: Tier):
        v = self._remove(key, src)
        self._ensure_room(dst, v.nbytes)
        self._data[dst][key] = v
        self._used[dst] += v.nbytes
        self.stats[dst].bytes_written += v.nbytes
        if dst < src:
            self.stats[dst].promotions += 1
        else:
            self.stats[dst].demotions += 1

    def _ensure_room(self, tier: Tier, nbytes: int):
        """Demote stalest residents until `nbytes` fits (FLASH never
        evicts — it is the capacity tier)."""
        spec = self.specs[tier]
        while self._used[tier] + nbytes > spec.capacity_bytes \
                and tier != Tier.FLASH:
            victims = [k for k in self.policy.evict_candidates(tier)
                       if k in self._data[tier]]
            if not victims:
                victims = list(self._data[tier])
            if not victims:
                break
            self._move(victims[0], tier, Tier(tier + 1))

    # ---------------------------------------------------------------- report
    def report(self) -> str:
        lines = []
        for t in Tier:
            st = self.stats[t]
            lines.append(
                f"{t.name:6s} used={self._used[t]/2**20:9.1f}MiB "
                f"objs={len(self._data[t]):6d} hit_rate={st.hit_rate:.3f} "
                f"read={st.bytes_read/2**20:9.1f}MiB "
                f"t_model={st.modeled_time*1e3:8.2f}ms "
                f"promo={st.promotions} demo={st.demotions}")
        return "\n".join(lines)
