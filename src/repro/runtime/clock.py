"""Clocks for the async tiering runtime.

The runtime's testing contract is **clock injection**: every time-dependent
component (`AsyncTierRuntime`, `TieredStore`, `DecodeEngine`, the tiering
policy's EMA) reads time from an injected clock object instead of
`time.time()`. Tests and benchmarks inject a `VirtualClock` and advance it
explicitly, which makes queueing behavior, promotion/demotion hysteresis
and prefetch overlap fully deterministic and instantaneous to simulate;
production paths inject a `WallClock` so the same code runs against real
time. Wall-clock only ever appears at this edge — nothing below the
runtime API calls `time.*` directly.

All clocks are also callable (returning `now()`) so they satisfy the
legacy `Callable[[], float]` clock parameter of `TieredStore`.
"""
from __future__ import annotations

import time


class VirtualClock:
    """Deterministic simulated clock; time moves only via `advance*`."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance by {dt}")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        self._t = max(self._t, float(t))
        return self._t

    def __call__(self) -> float:
        return self._t

    def __repr__(self):
        return f"VirtualClock(t={self._t:.6f})"


class WallClock:
    """Real time. `advance` is a no-op: wall time passes on its own, so a
    blocking wait is represented by the caller actually blocking, not by
    moving the clock."""

    def now(self) -> float:
        return time.monotonic()

    def advance(self, dt: float) -> float:
        return self.now()

    def advance_to(self, t: float) -> float:
        return self.now()

    def __call__(self) -> float:
        return self.now()


class CallableClock:
    """Adapter for an externally-driven `Callable[[], float]` clock (the
    legacy `TieredStore(clock=...)` form). The owner of the callable moves
    time; `advance` therefore cannot and does not."""

    def __init__(self, fn):
        self._fn = fn

    def now(self) -> float:
        return float(self._fn())

    def advance(self, dt: float) -> float:
        return self.now()

    def advance_to(self, t: float) -> float:
        return self.now()

    def __call__(self) -> float:
        return self.now()


def ensure_clock(clock):
    """Normalize None / callable / clock-object into a clock object."""
    if clock is None:
        return VirtualClock()
    if hasattr(clock, "now") and hasattr(clock, "advance"):
        return clock
    if callable(clock):
        return CallableClock(clock)
    raise TypeError(f"not a clock: {clock!r}")
