"""Per-tier service-time models for the async tiering runtime.

The seed `TieredStore` charged a fixed `read_latency + nbytes/bw` per
access, which cannot represent queueing — the entire reason the paper's
§IV utilization cap and the MQSim-Next simulator exist. Here the flash
tier's service times come from the calibrated `repro.ssdsim` discrete-
event engine instead: `SsdQueueModel` runs the simulator once per config
at a ladder of queue depths (closed-loop saturation, 4KiB-granular reads)
and interpolates (mean latency, achieved IOPS) between them. A fetch of
`nbytes` at in-flight depth `d` then costs

    occupancy = ceil(nbytes / 4KiB) / IOPS(d)      # throughput share
    latency   = occupancy + mean_read_latency(d)   # access time overlaps

The runtime serializes occupancies (deeper queue -> longer waits) while
latencies pipeline — exactly the behavior the DES exhibits, at a cost
the serving hot loop can afford. DRAM/HBM keep the fixed-latency model
(no deep queues at microsecond scales worth modeling here).
`NetQueueModel` extends the same occupancy/latency split to the
cross-host NIC tier of the sharded fabric (`runtime.fabric`): fixed RTT
latency, wire occupancy at the bandwidth share the link sustains at the
current in-flight depth.

Calibration is deterministic (fixed sim seed) and cached per SimConfig,
so tests pay it once per process. Set the `REPRO_SSDSIM_CACHE` env var
to a directory to also persist calibration across processes (CI caches
it between steps); cache files are keyed by a digest of the SimConfig,
op count, depth ladder and a format version.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import pathlib
import tempfile
from typing import Dict, Optional, Tuple

import numpy as np

from ..ssdsim.config import SimConfig
from ..ssdsim.engine import simulate_latency, simulate_peak_iops

CACHE_ENV = "REPRO_SSDSIM_CACHE"
_CAL_VERSION = 2            # bump when the cached-file schema changes


@dataclasses.dataclass(frozen=True)
class Service:
    """One scheduled access: how long the tier stays occupied, and the
    additional pipelined latency before the data is usable."""
    occupancy: float
    latency: float

    @property
    def total(self) -> float:
        return self.occupancy + self.latency


class FixedLatencyModel:
    """Seed-style model for HBM/DRAM: latency + size/bandwidth."""

    def __init__(self, read_latency: float, read_bw: float):
        self.read_latency = read_latency
        self.read_bw = read_bw

    def service(self, nbytes: int, queue_depth: int) -> Service:
        return Service(occupancy=nbytes / self.read_bw,
                       latency=self.read_latency)


@dataclasses.dataclass(frozen=True)
class FabricTopology:
    """Rack/spine topology descriptor for the fabric's transfer tier.

    Hosts are packed `hosts_per_rack` to a rack; a pair in the same rack
    talks through the ToR switch (short RTT, full NIC bandwidth), a pair
    in different racks crosses the spine (longer RTT, and an
    oversubscribed share of the uplink). `incast_degree` is the fan-in a
    destination host absorbs at line rate; beyond it the senders split
    the receiver's ingress (the classic incast collapse, modeled as a
    linear bandwidth division so degradation is monotone in fan-in).
    """
    hosts_per_rack: int = 4
    rack_rtt: float = 15e-6
    spine_rtt: float = 40e-6
    rack_bandwidth: float = 12.5e9      # 100 Gb/s within the rack
    spine_bandwidth: float = 6.25e9     # 2:1 oversubscribed uplink share
    incast_degree: int = 2

    def __post_init__(self):
        if (self.hosts_per_rack < 1 or self.incast_degree < 1
                or self.rack_rtt < 0 or self.spine_rtt < self.rack_rtt
                or self.rack_bandwidth <= 0
                or self.spine_bandwidth <= 0):
            raise ValueError("invalid topology parameters")

    def rack_of(self, host: int) -> int:
        return int(host) // self.hosts_per_rack

    def same_rack(self, src: int, dst: int) -> bool:
        return self.rack_of(src) == self.rack_of(dst)

    def rtt(self, src: int, dst: int) -> float:
        return self.rack_rtt if self.same_rack(src, dst) else self.spine_rtt

    def bandwidth(self, src: int, dst: int) -> float:
        return (self.rack_bandwidth if self.same_rack(src, dst)
                else self.spine_bandwidth)

    def incast_factor(self, fan_in: int) -> float:
        """Ingress bandwidth divisor at `fan_in` concurrent senders:
        1.0 up to `incast_degree`, then linear — monotone in fan-in."""
        return max(1.0, float(fan_in) / self.incast_degree)


class NetQueueModel:
    """Cross-host NIC link service for the sharded fabric's transfer tier.

    Same occupancy/latency split as `SsdQueueModel`, with the NIC's
    queueing shape instead of flash's:

      occupancy = nbytes / eff_bw(depth)   # wire time at the bandwidth
                                           # share `depth` streams sustain
      latency   = rtt                      # fixed propagation + protocol

    A single stream is window-limited and cannot saturate the link;
    aggregate effective bandwidth ramps linearly until `sat_depth`
    concurrent transfers fill the pipe (the NIC analog of flash IOPS
    rising with queue depth). Occupancies serialize on the link in the
    runtime's queueing; RTT latencies pipeline. Defaults model a
    100 Gb/s fleet NIC at ~25us intra-cluster RTT.

    Topology mode: construct with `topology=FabricTopology(...)` and
    `service` becomes per-pair — the fabric passes `src`/`dst` host ids
    and the destination's current sender fan-in, so an intra-rack hop
    gets the short RTT at full bandwidth, a spine hop the longer RTT at
    the oversubscribed share, and high fan-in into one destination
    divides its ingress bandwidth (incast). Without a topology the
    uniform single-link behavior is unchanged (extra context ignored).
    """

    def __init__(self, rtt: float = 25e-6, bandwidth: float = 12.5e9,
                 sat_depth: int = 4,
                 topology: Optional[FabricTopology] = None):
        if rtt < 0 or bandwidth <= 0 or sat_depth < 1:
            raise ValueError("invalid NIC parameters")
        self.rtt = rtt
        self.bandwidth = bandwidth
        self.sat_depth = sat_depth
        self.topology = topology

    def service(self, nbytes: int, queue_depth: int,
                src: Optional[int] = None, dst: Optional[int] = None,
                fan_in: int = 1) -> Service:
        rtt, bw = self.rtt, self.bandwidth
        topo = self.topology
        if topo is not None and src is not None and dst is not None:
            rtt, bw = topo.rtt(src, dst), topo.bandwidth(src, dst)
            bw /= topo.incast_factor(max(1, int(fan_in)))
        d = max(1, min(int(queue_depth), self.sat_depth))
        eff_bw = bw * (d / self.sat_depth)
        return Service(occupancy=nbytes / eff_bw, latency=rtt)


class PoolLaneModel:
    """Per-host lane to the fleet-shared far-memory pool.

    Same occupancy/latency split as `NetQueueModel` — fixed RTT, a
    bandwidth share that ramps with in-flight depth — plus the write
    asymmetry the pool's ingest path needs: pooled DRAM is behind a
    fabric port whose egress (host reads) and ingress (host writes /
    demotions into the pool) can be provisioned differently. Each
    attached host owns one lane; occupancies serialize per lane in the
    runtime while the RTT pipelines, so one host's demotion burst
    queues on *its* lane without touching its neighbors'.
    """

    def __init__(self, rtt: float = 2e-6, read_bw: float = 40e9,
                 write_bw: Optional[float] = None, sat_depth: int = 4):
        if rtt < 0 or read_bw <= 0 or sat_depth < 1:
            raise ValueError("invalid pool-lane parameters")
        if write_bw is not None and write_bw <= 0:
            raise ValueError("invalid pool-lane write bandwidth")
        self.rtt = rtt
        self.read_bw = read_bw
        self.write_bw = read_bw if write_bw is None else write_bw
        self.sat_depth = sat_depth

    def service(self, nbytes: int, queue_depth: int,
                write: bool = False) -> Service:
        bw = self.write_bw if write else self.read_bw
        d = max(1, min(int(queue_depth), self.sat_depth))
        eff_bw = bw * (d / self.sat_depth)
        return Service(occupancy=nbytes / eff_bw, latency=self.rtt)


class GpuDirectQueueModel:
    """BaM-style GPU-direct flash path over the calibrated flash ladder.

    Same NAND as `SsdQueueModel` — the calibration is reused, not
    re-run — but a different *path*: the accelerator's submission
    engine enqueues straight into the device SQ from thousands of
    threads, so the device sees a deep queue even when the logical
    in-flight count is small. That is BaM's core performance claim and
    it is what `boost_depth` models: the IOPS/latency ladder is read at
    `max(queue_depth, boost_depth)`, i.e. the device always operates at
    or past the depth where its internal parallelism saturates. On top
    of the device service the path pays only a fixed submission latency
    (`submit_latency`, a doorbell write + completion poll — no host
    DRAM bounce, no host CPU in the loop).

    The economics mirror: `break_even_components_gpu_direct` drops the
    host-CPU and host-DRAM-wire terms from Eq. 1 for the same reason
    this model never touches a host lane.
    """

    def __init__(self, ssd: "SsdQueueModel", *, boost_depth: int = 32,
                 submit_latency: float = 3e-6):
        if boost_depth < 1 or submit_latency < 0:
            raise ValueError("invalid GPU-direct parameters")
        self.ssd = ssd
        self.boost_depth = boost_depth
        self.submit_latency = submit_latency

    def _depth(self, queue_depth: int) -> int:
        return max(int(queue_depth), self.boost_depth)

    def service(self, nbytes: int, queue_depth: int) -> Service:
        base = self.ssd.service(nbytes, self._depth(queue_depth))
        return Service(occupancy=base.occupancy,
                       latency=base.latency + self.submit_latency)

    def p99(self, queue_depth: int) -> float:
        return self.ssd.p99(self._depth(queue_depth)) + self.submit_latency


class SsdQueueModel:
    """Queue-depth-dependent flash service times from the ssdsim DES."""

    DEPTHS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)
    PAGE = 4096

    _cache: Dict[object, "SsdQueueModel"] = {}

    def __init__(self, sim_cfg: Optional[SimConfig] = None,
                 n_ops: int = 2500):
        # 4KiB-granular batched reads are the KV/expert fetch unit
        self.cfg = sim_cfg or SimConfig(l_blk=self.PAGE, read_frac=0.9)
        self.n_ops = n_ops
        self._iops: Optional[np.ndarray] = None
        self._lat: Optional[np.ndarray] = None
        self._p99: Optional[np.ndarray] = None

    @classmethod
    def shared(cls, sim_cfg: Optional[SimConfig] = None) -> "SsdQueueModel":
        key = sim_cfg  # SimConfig is a frozen dataclass -> hashable
        if key not in cls._cache:
            cls._cache[key] = cls(sim_cfg)
        return cls._cache[key]

    # ------------------------------------------------------------ disk cache
    def _cache_path(self) -> Optional[pathlib.Path]:
        root = os.environ.get(CACHE_ENV)
        if not root:
            return None
        spec = repr((self.cfg, self.n_ops, self.DEPTHS, _CAL_VERSION))
        digest = hashlib.blake2b(spec.encode(), digest_size=12).hexdigest()
        return pathlib.Path(root) / f"ssdcal-{digest}.json"

    def _load_cached(self) -> bool:
        path = self._cache_path()
        if path is None or not path.is_file():
            return False
        try:
            blob = json.loads(path.read_text())
            iops = np.asarray(blob["iops"], float)
            lat = np.asarray(blob["lat"], float)
            if len(iops) != len(self.DEPTHS) or len(lat) != len(self.DEPTHS):
                return False
            p99 = blob.get("p99")
            if p99 is not None:
                p99 = np.asarray(p99, float)
                if len(p99) != len(self.DEPTHS):
                    p99 = None
        except (ValueError, KeyError, TypeError, OSError):
            # a corrupt or foreign file is a cache miss, never a crash
            return False
        self._iops = iops
        self._lat = lat
        self._p99 = p99
        return True

    def _save_cached(self):
        path = self._cache_path()
        if path is None:
            return
        blob = {"iops": [float(x) for x in self._iops],
                "lat": [float(x) for x in self._lat]}
        if self._p99 is not None:
            blob["p99"] = [float(x) for x in self._p99]
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f, sort_keys=True)
            os.replace(tmp, path)
        except OSError:
            pass                            # cache is best-effort only

    # ------------------------------------------------------------ calibrate
    def _calibrate(self):
        if self._load_cached():
            self._xs = np.log2(np.asarray(self.DEPTHS, float))
            return
        iops, lat = [], []
        for qd in self.DEPTHS:
            r = simulate_peak_iops(self.cfg, n_ops=self.n_ops,
                                   queue_depth=qd)
            # reads carry the fetch path; guard against degenerate mixes
            iops.append(max(r.iops * self.cfg.read_frac, 1.0))
            lat.append(max(r.mean_read_latency, 1e-9))
        # Queueing theory guarantees throughput and mean latency are
        # non-decreasing in offered depth; the finite-op DES can exhibit
        # sub-sample-noise dips, so enforce isotonicity on the ladder
        # (interpolated values then inherit the monotone property).
        self._iops = np.maximum.accumulate(np.asarray(iops))
        self._lat = np.maximum.accumulate(np.asarray(lat))
        self._xs = np.log2(np.asarray(self.DEPTHS, float))
        self._save_cached()

    def _calibrate_p99(self):
        """Open-loop tail percentiles per calibrated depth (the p99-aware
        prefetch-lead prerequisite): drive the DES with Poisson arrivals
        at the utilization each depth achieves (rho_d = IOPS(d)/IOPS(max))
        and take the observed p99 read latency — the M/D/1-like tail at
        that load, which the closed-loop mean cannot show."""
        if self._iops is None:
            self._calibrate()
        if self._p99 is not None:
            return
        peak_total = float(self._iops[-1]) / max(self.cfg.read_frac, 1e-9)
        p99 = []
        for iops_d in self._iops:
            rho = float(np.clip(iops_d / self._iops[-1], 0.02, 0.95))
            r = simulate_latency(self.cfg, rho, n_ops=self.n_ops,
                                 peak_iops=peak_total)
            p99.append(max(r.p99_read_latency, 1e-9))
        self._p99 = np.maximum.accumulate(np.asarray(p99))
        self._save_cached()

    def calibration(self) -> Dict[int, Tuple[float, float, float]]:
        """(IOPS, mean latency, open-loop p99 latency) per calibrated
        depth — for reports and prefetch-lead sizing."""
        if self._iops is None:
            self._calibrate()
        if self._p99 is None:
            self._calibrate_p99()
        return {d: (float(i), float(l), float(p)) for d, i, l, p in
                zip(self.DEPTHS, self._iops, self._lat, self._p99)}

    def p99(self, queue_depth: int) -> float:
        """Interpolated open-loop p99 read latency at `queue_depth` — the
        tail the p99-sized prefetch lead must cover (`service().latency`
        is the closed-loop mean, which under-sizes the lead exactly when
        queueing matters)."""
        if self._p99 is None:
            self._calibrate_p99()
        d = float(np.clip(queue_depth, self.DEPTHS[0], self.DEPTHS[-1]))
        return float(np.interp(math.log2(d), self._xs, self._p99))

    def service(self, nbytes: int, queue_depth: int) -> Service:
        if self._iops is None:
            self._calibrate()
        d = float(np.clip(queue_depth, self.DEPTHS[0], self.DEPTHS[-1]))
        x = math.log2(d)
        iops = float(np.interp(x, self._xs, self._iops))
        lat = float(np.interp(x, self._xs, self._lat))
        pages = max(1, math.ceil(nbytes / self.PAGE))
        return Service(occupancy=pages / iops, latency=lat)

    def service_total_batch(self, nbytes: int, depths) -> np.ndarray:
        """Vectorized `service(nbytes, d).total` over an array of queue
        depths — one interp over the calibrated ladder instead of a
        Python call per access. Matches the scalar path value-for-value;
        this is how a control plane prices thousands of queued fetches
        per step without re-entering the model per key."""
        if self._iops is None:
            self._calibrate()
        d = np.clip(np.asarray(depths, float),
                    self.DEPTHS[0], self.DEPTHS[-1])
        x = np.log2(d)
        iops = np.interp(x, self._xs, self._iops)
        lat = np.interp(x, self._xs, self._lat)
        pages = max(1, math.ceil(nbytes / self.PAGE))
        return pages / iops + lat
