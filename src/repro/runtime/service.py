"""Per-tier service-time models for the async tiering runtime.

The seed `TieredStore` charged a fixed `read_latency + nbytes/bw` per
access, which cannot represent queueing — the entire reason the paper's
§IV utilization cap and the MQSim-Next simulator exist. Here the flash
tier's service times come from the calibrated `repro.ssdsim` discrete-
event engine instead: `SsdQueueModel` runs the simulator once per config
at a ladder of queue depths (closed-loop saturation, 4KiB-granular reads)
and interpolates (mean latency, achieved IOPS) between them. A fetch of
`nbytes` at in-flight depth `d` then costs

    occupancy = ceil(nbytes / 4KiB) / IOPS(d)      # throughput share
    latency   = occupancy + mean_read_latency(d)   # access time overlaps

The runtime serializes occupancies (deeper queue -> longer waits) while
latencies pipeline — exactly the behavior the DES exhibits, at a cost
the serving hot loop can afford. DRAM/HBM keep the fixed-latency model
(no deep queues at microsecond scales worth modeling here).

Calibration is deterministic (fixed sim seed) and cached per SimConfig,
so tests pay it once per process.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from ..ssdsim.config import SimConfig
from ..ssdsim.engine import simulate_peak_iops


@dataclasses.dataclass(frozen=True)
class Service:
    """One scheduled access: how long the tier stays occupied, and the
    additional pipelined latency before the data is usable."""
    occupancy: float
    latency: float

    @property
    def total(self) -> float:
        return self.occupancy + self.latency


class FixedLatencyModel:
    """Seed-style model for HBM/DRAM: latency + size/bandwidth."""

    def __init__(self, read_latency: float, read_bw: float):
        self.read_latency = read_latency
        self.read_bw = read_bw

    def service(self, nbytes: int, queue_depth: int) -> Service:
        return Service(occupancy=nbytes / self.read_bw,
                       latency=self.read_latency)


class SsdQueueModel:
    """Queue-depth-dependent flash service times from the ssdsim DES."""

    DEPTHS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128)
    PAGE = 4096

    _cache: Dict[object, "SsdQueueModel"] = {}

    def __init__(self, sim_cfg: Optional[SimConfig] = None,
                 n_ops: int = 2500):
        # 4KiB-granular batched reads are the KV/expert fetch unit
        self.cfg = sim_cfg or SimConfig(l_blk=self.PAGE, read_frac=0.9)
        self.n_ops = n_ops
        self._iops: Optional[np.ndarray] = None
        self._lat: Optional[np.ndarray] = None

    @classmethod
    def shared(cls, sim_cfg: Optional[SimConfig] = None) -> "SsdQueueModel":
        key = sim_cfg  # SimConfig is a frozen dataclass -> hashable
        if key not in cls._cache:
            cls._cache[key] = cls(sim_cfg)
        return cls._cache[key]

    def _calibrate(self):
        iops, lat = [], []
        for qd in self.DEPTHS:
            r = simulate_peak_iops(self.cfg, n_ops=self.n_ops,
                                   queue_depth=qd)
            # reads carry the fetch path; guard against degenerate mixes
            iops.append(max(r.iops * self.cfg.read_frac, 1.0))
            lat.append(max(r.mean_read_latency, 1e-9))
        self._iops = np.asarray(iops)
        self._lat = np.asarray(lat)
        self._xs = np.log2(np.asarray(self.DEPTHS, float))

    def calibration(self) -> Dict[int, Tuple[float, float]]:
        """(IOPS, mean latency) per calibrated depth — for reports."""
        if self._iops is None:
            self._calibrate()
        return {d: (float(i), float(l)) for d, i, l in
                zip(self.DEPTHS, self._iops, self._lat)}

    def service(self, nbytes: int, queue_depth: int) -> Service:
        if self._iops is None:
            self._calibrate()
        d = float(np.clip(queue_depth, self.DEPTHS[0], self.DEPTHS[-1]))
        x = math.log2(d)
        iops = float(np.interp(x, self._xs, self._iops))
        lat = float(np.interp(x, self._xs, self._lat))
        pages = max(1, math.ceil(nbytes / self.PAGE))
        return Service(occupancy=pages / iops, latency=lat)
