"""PooledStore — the fleet-shared disaggregated far-memory tier.

The paper's hierarchy is per-host: every byte not in local DRAM is a
flash fetch away, so each host provisions DRAM for its *own* peak.
A CXL/far-memory pool breaks that coupling: one fleet-level slab of
DRAM-class memory sits between local DRAM and remote flash, rented at
a *discount* to local DRAM because uncorrelated per-host peaks
statistically multiplex onto one shared provision (the
`break_even_components_pool` column in `core.economics` prices exactly
this). What the pool costs instead of rent is *distance*: every access
crosses a per-host fabric lane with an RTT and a bandwidth share
(`runtime.service.PoolLaneModel`), and those seconds land in the stall
ledger's ``pool_rtt`` component.

Topology and fate-sharing:

  * The pool itself is fleet-level infrastructure: it survives
    `fail_host` (its residency and capacity accounting are untouched).
  * Each attached host owns one lane to the pool; the lane dies with
    its host (`detach_host`) exactly like the host's NIC. In-flight
    transfers on a dead lane are never waited — the requester died.
  * One shared `VirtualClock` and one shared `StallLedger` with the
    rest of the fleet, so pooled stall obeys the same conservation
    invariant as every other component.

Mechanics mirror `TieredStore` where the concepts transfer:

  * `put` records a readability horizon (the ingest write's delivery
    time); a `get_async` issued before the bytes arrive gates on it —
    the same conservative pricing as rebalance ingest.
  * Capacity pressure evicts the least-recently-used resident back to
    its owner's flash through the `on_evict` callback the fabric
    installs (the pool never silently drops bytes).
  * `byte_seconds()` integrates resident bytes over time so benches
    can price pool rent (`rent_factor` x the local DRAM rate) the same
    way they price local DRAM rent.

`ShardedTieredStore` consults the pool between the local-DRAM hit and
the remote-flash composition; admission into the pool is the economic
gate's call (`pool_admit`), not the store's.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..obs.ledger import StallLedger
from .async_engine import AsyncTierRuntime, Transfer
from .clock import ensure_clock
from .service import PoolLaneModel

# lane-key prefix: lanes are ("POOL", host) tuples, which is what the
# runtime's stall attribution keys the pool_rtt component on
POOL_LANE = "POOL"


@dataclasses.dataclass
class PoolStats:
    puts: int = 0
    gets: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    evictions: int = 0
    evicted_bytes: int = 0
    promotions: int = 0          # pool -> local DRAM (fabric-driven)
    stall_time: float = 0.0


@dataclasses.dataclass
class PooledFetch:
    """Handle for an in-flight pool read; duck-types `PendingFetch`
    (`done()` / `wait()` -> value) so engine/scheduler code paths treat
    a pool restore like any other fetch. `on_wait` is the fabric's
    post-fetch hook (reuse observation + possible promotion out of the
    pool)."""
    pool: "PooledStore"
    key: object
    transfer: Transfer
    value: np.ndarray
    on_wait: Optional[Callable[["PooledFetch"], None]] = None

    def done(self) -> bool:
        return self.transfer.is_done(self.pool.clock.now())

    def wait(self) -> np.ndarray:
        stall = self.pool.runtime.wait(self.transfer)
        self.pool.stats.stall_time += stall
        if self.on_wait is not None:
            cb, self.on_wait = self.on_wait, None
            cb(self)
        return self.value


class PooledStore:
    """One fleet-shared far-memory slab with per-host RTT lanes."""

    def __init__(self, capacity_bytes: float, *, read_bw: float = 40e9,
                 write_bw: Optional[float] = None, rtt: float = 2e-6,
                 sat_depth: int = 4, rent_factor: float = 0.5,
                 clock=None, obs=None, ledger: Optional[StallLedger] = None,
                 label: str = "pool"):
        if capacity_bytes <= 0:
            raise ValueError("pool capacity must be positive")
        self.capacity_bytes = float(capacity_bytes)
        self.rent_factor = float(rent_factor)
        self.lane_model = PoolLaneModel(rtt=rtt, read_bw=read_bw,
                                        write_bw=write_bw,
                                        sat_depth=sat_depth)
        self.clock = ensure_clock(clock)
        self.runtime = AsyncTierRuntime(clock=self.clock,
                                        service_models={}, obs=obs,
                                        ledger=ledger, label=label)
        self.obs = self.runtime.obs
        self.ledger = self.runtime.ledger
        self.label = label
        self.stats = PoolStats()
        self._data: Dict[object, np.ndarray] = {}
        self._used = 0
        self._owner: Dict[object, int] = {}      # host that pooled the key
        self._lru: Dict[object, float] = {}      # key -> last access time
        self._seq = 0                            # LRU tie-break (puts at
        self._lru_seq: Dict[object, int] = {}    # the same instant)
        # key -> wire-arrival horizon of an in-flight ingest; reads
        # issued before it gate on it (readability gating)
        self._arrival_t: Dict[object, float] = {}
        # host id -> lane key, active lanes only; dead lanes keep their
        # runtime queue history (like retired NICs) but route nothing
        self.lanes: Dict[int, Tuple[str, int]] = {}
        # fabric-installed spill path: (key, value, owner_host) -> None;
        # capacity pressure is a *demotion back to flash*, never a drop
        self.on_evict: Optional[Callable[[object, np.ndarray, int],
                                         None]] = None
        # resident byte-seconds integral (pool rent accounting)
        self._bs_accum = 0.0
        self._bs_last_t = self.clock.now()

    # ---------------------------------------------------------------- lanes
    def attach_host(self, host: int) -> None:
        if host in self.lanes:
            return
        lane = (POOL_LANE, int(host))
        self.lanes[host] = lane
        if lane not in self.runtime.models:
            self.runtime.add_lane(lane, self.lane_model)

    def detach_host(self, host: int) -> None:
        """The host's lane dies with the host; pool residency survives.
        The lane's queue history stays on the runtime (stats), it just
        stops being routable."""
        self.lanes.pop(host, None)

    def _lane(self, host: int) -> Tuple[str, int]:
        lane = self.lanes.get(host)
        if lane is None:
            raise KeyError(f"host {host} has no pool lane (not attached "
                           f"or failed)")
        return lane

    # ------------------------------------------------------------ accounting
    def _accrue(self) -> None:
        now = self.clock.now()
        self._bs_accum += self._used * (now - self._bs_last_t)
        self._bs_last_t = now

    def byte_seconds(self) -> float:
        """Resident byte-seconds to date — what pool rent is priced on
        (at `rent_factor` x the local DRAM rate)."""
        self._accrue()
        return self._bs_accum

    def _touch(self, key) -> None:
        self._lru[key] = self.clock.now()
        self._seq += 1
        self._lru_seq[key] = self._seq

    # ------------------------------------------------------------------ api
    def has(self, key) -> bool:
        return key in self._data

    def keys(self) -> List[object]:
        return list(self._data)

    @property
    def used_bytes(self) -> int:
        return self._used

    def nbytes_of(self, key) -> int:
        return self._data[key].nbytes

    def owner_of(self, key) -> Optional[int]:
        return self._owner.get(key)

    def put(self, key, value: np.ndarray, from_host: int) -> Transfer:
        """Place `key` in the pool over `from_host`'s lane (ingest
        write at the lane's write bandwidth). Records the readability
        horizon: a read issued before the bytes arrive gates on the
        write's delivery."""
        value = np.asarray(value)
        lane = self._lane(from_host)
        self._accrue()
        if key in self._data:
            self._remove(key)
        self._ensure_room(value.nbytes, exclude=key)
        tr = self.runtime.submit(lane, key, value.nbytes, kind="write",
                                 ctx={"write": True})
        self._data[key] = value
        self._used += value.nbytes
        self._owner[key] = int(from_host)
        self._touch(key)
        if tr.done_t > self.clock.now():
            self._arrival_t[key] = tr.done_t
        self.stats.puts += 1
        self.stats.bytes_in += value.nbytes
        return tr

    def get_async(self, key, from_host: int,
                  on_wait: Optional[Callable[[PooledFetch], None]] = None
                  ) -> PooledFetch:
        if key not in self._data:
            raise KeyError(key)
        lane = self._lane(from_host)
        value = self._data[key]
        tr = self.runtime.submit(lane, key, value.nbytes, kind="fetch",
                                 not_before=self._arrival_gate(key))
        self._touch(key)
        self.stats.gets += 1
        self.stats.bytes_out += value.nbytes
        return PooledFetch(pool=self, key=key, transfer=tr, value=value,
                           on_wait=on_wait)

    def get(self, key, from_host: int) -> np.ndarray:
        return self.get_async(key, from_host).wait()

    def delete(self, key) -> None:
        if key in self._data:
            self._accrue()
            self._remove(key)

    def _remove(self, key) -> np.ndarray:
        v = self._data.pop(key)
        self._used -= v.nbytes
        self._owner.pop(key, None)
        self._lru.pop(key, None)
        self._lru_seq.pop(key, None)
        self._arrival_t.pop(key, None)
        return v

    def _arrival_gate(self, key) -> Optional[float]:
        t = self._arrival_t.get(key)
        if t is None:
            return None
        if self.clock.now() >= t - 1e-12:
            del self._arrival_t[key]
            return None
        return t

    # ------------------------------------------------------------- capacity
    def _ensure_room(self, nbytes: int, exclude=None) -> None:
        if nbytes > self.capacity_bytes:
            raise ValueError(
                f"object of {nbytes} bytes exceeds the pool capacity "
                f"{self.capacity_bytes:.0f}")
        while self._used + nbytes > self.capacity_bytes:
            victim = min(
                (k for k in self._data if k != exclude),
                key=lambda k: (self._lru[k], self._lru_seq[k]),
                default=None)
            if victim is None:
                raise RuntimeError("pool cannot make room: no victims")
            owner = self._owner.get(victim, 0)
            value = self._remove(victim)
            self.stats.evictions += 1
            self.stats.evicted_bytes += value.nbytes
            if self.on_evict is not None:
                self.on_evict(victim, value, owner)

    # ---------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        self.stats = PoolStats()
        self.runtime.reset_stats()

    def snapshot_stats(self) -> Dict[str, object]:
        out: Dict[str, object] = dataclasses.asdict(self.stats)
        out["keys"] = len(self._data)
        out["used_bytes"] = int(self._used)
        out["lanes"] = self.runtime.snapshot_stats()
        return out

    def drain(self) -> float:
        return self.runtime.drain()

    def report(self) -> str:
        st = self.stats
        return (f"POOL   used={self._used/2**20:9.1f}MiB "
                f"objs={len(self._data):6d} puts={st.puts:6d} "
                f"gets={st.gets:6d} evict={st.evictions:5d} "
                f"promo={st.promotions:5d} "
                f"stall={st.stall_time*1e3:8.2f}ms")
