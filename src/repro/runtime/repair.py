"""RepairLoop — background re-replication after unplanned host failure.

`ShardedTieredStore.fail_host` removes a host with no drain: replicated
keys survive on their other holders but drop below their declared
replication degree, and the ring change can leave surviving copies on
hosts that are no longer placement targets. The repair loop walks the
fabric's `under_replicated()` set in deterministic hash order and
streams each missing copy exactly like a planned rebalance — a
`read_for_transfer` on the best surviving holder (ring-preference
order), the sender's egress NIC gated on the read, and a destination
`ingest` whose write is subject to the destination's write shield and
readability gating — all under the fabric's `rebalance_rate` token
bucket, so repair traffic is paced like rebalance traffic and serving
continues throughout (it only queues behind the repair streams).

`step()` repairs one bounded batch (background operation, interleaved
with serving); `run()` loops until no key is under-replicated or
misplaced. `RepairStats.t_done` is the wire horizon of the last repair
stream, so recovery time for a failure is
`t_done - FailureReport.t_fail` — what the failover benchmark reports
per replication factor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .fabric import ShardedTieredStore


@dataclasses.dataclass
class RepairStats:
    """One repair pass: what re-replication actually moved."""
    t_start: float
    t_done: float = 0.0         # wire horizon of the last repair stream
    keys_scanned: int = 0       # under-replicated/misplaced keys visited
    keys_repaired: int = 0
    bytes_repaired: int = 0
    nic_transfers: int = 0
    copies_dropped: int = 0     # surplus copies on non-target hosts

    @property
    def duration(self) -> float:
        """Seconds from pass start to the last stream's delivery."""
        return max(0.0, self.t_done - self.t_start)

    def as_dict(self) -> Dict[str, float]:
        return {
            "t_start": float(self.t_start),
            "t_done": float(self.t_done),
            "duration": float(self.duration),
            "keys_scanned": float(self.keys_scanned),
            "keys_repaired": float(self.keys_repaired),
            "bytes_repaired": float(self.bytes_repaired),
            "nic_transfers": float(self.nic_transfers),
            "copies_dropped": float(self.copies_dropped),
        }


class RepairLoop:
    """Paced re-replication of a fabric's under-replicated keys."""

    def __init__(self, fabric: ShardedTieredStore, batch_keys: int = 64):
        if batch_keys < 1:
            raise ValueError("batch_keys must be >= 1")
        self.fabric = fabric
        self.batch_keys = batch_keys
        # per-source token bucket (same shape as the rebalance pacer);
        # persists across step() calls so interleaved batches share one
        # budget instead of resetting the bucket every batch
        self._pace: Dict[int, float] = {}

    def pending(self) -> List[object]:
        """Keys still needing repair, in deterministic stream order."""
        return self.fabric.under_replicated()

    def _repair_key(self, key, stats: RepairStats):
        fab = self.fabric
        targets = fab._targets(key)
        held = fab.holders(key)
        stats.keys_scanned += 1
        if set(held) == set(targets):
            return
        src = held[0]               # best surviving holder, ring order
        nbytes = fab.hosts[src].nbytes_of(key)
        src_tier = fab.hosts[src].tier_of(key)
        for dst in targets:
            if dst in held:
                continue
            release = None
            if fab.rebalance_rate is not None:
                now = fab.clock.now()
                release = max(now, self._pace.get(src, now))
                self._pace[src] = release + nbytes / fab.rebalance_rate
            value, tr = fab.hosts[src].read_for_transfer(
                key, not_before=release)
            nic_tr = fab._nic_submit(src, dst, key, nbytes,
                                     kind="repair", not_before=tr.done_t)
            fab.hosts[dst].ingest(key, value, tier=src_tier,
                                  not_before=nic_tr.done_t)
            stats.bytes_repaired += nbytes
            stats.nic_transfers += 1
            stats.t_done = max(stats.t_done, nic_tr.done_t)
        for h in held:
            if h not in targets:
                fab.hosts[h].delete(key)
                stats.copies_dropped += 1
        stats.keys_repaired += 1

    def run(self, max_keys: Optional[int] = None) -> RepairStats:
        """Repair until nothing is under-replicated or misplaced (or up
        to `max_keys` keys). Re-scans between batches: an `ingest` is a
        structural placement, so repaired keys leave the pending set
        immediately and the loop converges."""
        now = self.fabric.clock.now()
        stats = RepairStats(t_start=now, t_done=now)
        while True:
            pending = self.pending()
            if not pending:
                break
            if max_keys is not None:
                pending = pending[:max(0, max_keys - stats.keys_scanned)]
                if not pending:
                    break
            for key in pending[:self.batch_keys]:
                self._repair_key(key, stats)
        if stats.keys_repaired:
            self.fabric._policy_instant("repair_pass", stats.as_dict())
        return stats

    def step(self) -> RepairStats:
        """One bounded batch of repairs (`batch_keys`), for interleaving
        with serving traffic."""
        return self.run(max_keys=self.batch_keys)
