"""AsyncTierRuntime — event-driven, queueing-aware tier movement engine.

This is the shared movement engine behind all three paper workloads
(LLM session KV via `serving.engine`, MoE experts via
`tiering.expert_store`, the KV store via `kvstore.tiered`). It turns
tier accesses into *transfers* with explicit issue/start/done timestamps
on an injectable clock (see `runtime.clock` for the clock-injection
testing contract):

  * `submit` schedules a transfer: the tier's service model (calibrated
    from the ssdsim DES for flash — see `runtime.service`) yields an
    occupancy and a pipelined latency for the current queue depth;
    occupancies serialize on the tier (queueing), latencies overlap.
  * `wait` blocks the virtual clock until the transfer completes and
    returns the stall actually incurred — zero when enough compute time
    was overlapped after `submit` (that difference is the whole point of
    async prefetch).
  * `advance` models compute proceeding while transfers stream in the
    background (a decode step, a training step, host work).

Per-tier `QueueStats` record stall time and miss-under-miss occupancy so
benchmarks can report modeled per-token stall under load.

Queues are keyed by *lane*: any hashable key with a service model. The
single-host `TieredStore` uses the `Tier` enum; the sharded fabric
(`runtime.fabric`) adds per-host NIC lanes with a `NetQueueModel` on the
same engine. `submit(..., not_before=t)` lets a transfer's start be
gated on an upstream completion, which is how a remote fetch composes
the remote host's flash service with the network service (the NIC
transfer cannot start before the flash read delivers the bytes).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

from ..core.policy import Tier
from ..obs.ledger import StallLedger, tenant_of_key
from .clock import ensure_clock
from .service import (FixedLatencyModel, GpuDirectQueueModel, Service,
                      SsdQueueModel)


@dataclasses.dataclass
class Transfer:
    key: object
    nbytes: int
    tier: object                 # lane key: a Tier, or e.g. a NIC lane
    kind: str                    # "fetch" | "promote" | "demote" | "write"
    issue_t: float
    start_t: float
    done_t: float
    depth_at_issue: int
    seq: int
    # ---- stall-ledger attribution (set at submit / by the fabric) ----
    gate_t: Optional[float] = None   # upstream not_before horizon
    behind_interference: bool = False  # queued behind rebalance/repair
    incast_frac: float = 0.0         # NIC: share of service due to fan-in
    gate_miss: bool = False          # flash restore of a priced-out key

    def is_done(self, now: float) -> bool:
        return now >= self.done_t - 1e-12


@dataclasses.dataclass
class QueueStats:
    submitted: int = 0
    completed_waits: int = 0
    stall_time: float = 0.0
    busy_time: float = 0.0
    bytes_moved: int = 0
    miss_under_miss: int = 0     # submits issued while others in flight
    max_depth: int = 0


class AsyncTierRuntime:
    # v5e-host-like defaults, matching TieredStore's default TierSpecs
    DEFAULT_MODELS = {
        Tier.HBM: FixedLatencyModel(1e-7, 819e9),
        Tier.DRAM: FixedLatencyModel(5e-7, 45e9),
    }

    def __init__(self, clock=None, service_models=None,
                 sim_cfg=None, specs=None, obs=None, ledger=None,
                 label: str = "host0"):
        self.clock = ensure_clock(clock)
        if service_models is None:
            service_models = dict(self.DEFAULT_MODELS)
            if specs:
                for t, spec in specs.items():
                    service_models[t] = FixedLatencyModel(
                        spec.read_latency, spec.read_bw)
            # flash service always derives from the ssdsim queueing
            # engine unless the caller explicitly injected a model
            service_models[Tier.FLASH] = SsdQueueModel.shared(sim_cfg)
            if specs and Tier.GPU_FLASH in specs:
                # the BaM-style path reuses the same calibrated NAND
                # ladder behind an accelerator submission queue — a
                # different lane on the same engine, never contending
                # with the host-flash lane's queue
                service_models[Tier.GPU_FLASH] = GpuDirectQueueModel(
                    SsdQueueModel.shared(sim_cfg))
        self.models = service_models
        lanes = list(self.models)
        self._free: Dict[object, float] = {t: 0.0 for t in lanes}
        self._inflight: Dict[object, List[Transfer]] = {t: []
                                                        for t in lanes}
        self.qstats: Dict[object, QueueStats] = {t: QueueStats()
                                                 for t in lanes}
        self._seq = itertools.count()
        # observability: the ledger is always on (every stalled second
        # `wait` materializes is attributed — the conservation law in
        # obs.ledger depends on no wait bypassing it); tracer/metrics
        # only when an Observability is attached
        self.obs = obs
        self.ledger: StallLedger = (
            ledger if ledger is not None
            else (obs.ledger if obs is not None else StallLedger()))
        self.label = label

    # ----------------------------------------------------------------- lanes
    def add_lane(self, lane, model) -> None:
        """Register a new lane (key + service model) on a live runtime —
        how the far-memory pool attaches a per-host lane when a host
        joins the fleet. Re-registering an existing lane key is a
        programming error (it would silently reset its queue)."""
        if lane in self.models:
            raise ValueError(f"lane {lane!r} already registered")
        self.models[lane] = model
        self._free[lane] = 0.0
        self._inflight[lane] = []
        self.qstats[lane] = QueueStats()

    # ----------------------------------------------------------------- time
    def now(self) -> float:
        return self.clock.now()

    def advance(self, dt: float) -> float:
        """Model `dt` seconds of compute overlapping in-flight transfers."""
        return self.clock.advance(dt)

    # ---------------------------------------------------------------- queue
    def _prune(self, tier):
        now = self.clock.now()
        self._inflight[tier] = [tr for tr in self._inflight[tier]
                                if not tr.is_done(now)]

    def queue_depth(self, tier) -> int:
        self._prune(tier)
        return len(self._inflight[tier])

    def read_depth(self, tier) -> int:
        """In-flight fetches on `tier` — the queue-depth forecast behind
        write shielding (a fetch not yet done will still be contending
        when a write submitted now would start)."""
        self._prune(tier)
        return sum(1 for tr in self._inflight[tier] if tr.kind == "fetch")

    # --------------------------------------------------------------- submit
    def submit(self, tier, key, nbytes: int, kind: str = "fetch",
               not_before: Optional[float] = None,
               ctx: Optional[dict] = None) -> Transfer:
        now = self.clock.now()
        depth = self.queue_depth(tier)
        # `ctx` carries service context a model may be keyed on beyond
        # queue depth (the topology-aware NIC model's src/dst/fan_in);
        # models that don't take it are simply never handed one
        if ctx:
            svc: Service = self.models[tier].service(nbytes, depth + 1,
                                                     **ctx)
        else:
            svc = self.models[tier].service(nbytes, depth + 1)
        start = max(now, self._free[tier])
        if not_before is not None:
            # gate on an upstream completion (cross-host composition:
            # the NIC transfer starts when the remote flash read is done)
            start = max(start, float(not_before))
        done = start + svc.occupancy + svc.latency
        self._free[tier] = start + svc.occupancy
        # queued behind rebalance/repair traffic already on this lane:
        # any later stall in the queue window is interference, not the
        # lane's own service — recorded now, while the culprits are
        # still observable in flight
        behind = any(t.kind in ("rebalance", "repair")
                     for t in self._inflight[tier])
        tr = Transfer(key=key, nbytes=int(nbytes), tier=tier, kind=kind,
                      issue_t=now, start_t=start, done_t=done,
                      depth_at_issue=depth, seq=next(self._seq),
                      gate_t=(None if not_before is None
                              else float(not_before)),
                      behind_interference=behind)
        self._inflight[tier].append(tr)
        st = self.qstats[tier]
        st.submitted += 1
        st.busy_time += svc.occupancy
        st.bytes_moved += int(nbytes)
        if depth > 0:
            st.miss_under_miss += 1
        st.max_depth = max(st.max_depth, depth + 1)
        if self.obs is not None:
            self._observe_submit(tr, depth)
        return tr

    def _lane_name(self, tier) -> str:
        return getattr(tier, "name", str(tier))

    def _observe_submit(self, tr: Transfer, depth: int) -> None:
        lane = self._lane_name(tr.tier)
        m = self.obs.metrics
        if m is not None:
            m.counter("transfers").inc((self.label, lane, tr.kind))
            m.counter("bytes_moved").inc((self.label, lane), tr.nbytes)
        t = self.obs.tracer
        if t is not None:
            track = t.track(self.label, lane)
            t.complete(track, tr.kind, tr.start_t,
                       tr.done_t - tr.start_t, cat="transfer",
                       args={"key": str(tr.key), "nbytes": tr.nbytes,
                             "depth": depth, "issue_t": tr.issue_t})

    # ----------------------------------------------------------------- wait
    def wait(self, tr: Transfer) -> float:
        """Block until `tr` completes; returns the stall incurred (zero if
        it already finished in the background)."""
        now = self.clock.now()
        stall = max(0.0, tr.done_t - now)
        if stall:
            self.clock.advance_to(tr.done_t)
            self._attribute_stall(tr, now, stall)
        st = self.qstats[tr.tier]
        st.completed_waits += 1
        st.stall_time += stall
        return stall

    def _attribute_stall(self, tr: Transfer, now: float,
                         stall: float) -> None:
        """Decompose the residual wait [now, done_t] into Eq. 1 ledger
        components. The cut points are clamped and monotone, so the
        three pieces telescope to exactly `stall` — that exactness is
        what the conservation test leans on."""
        # gate window: waiting for an upstream horizon (write-shield /
        # ingest readability, rebalance pacing) — interference. For a
        # transfer gated on another transfer's completion that was
        # itself waited first (the remote-fetch NIC leg), the clock is
        # already at gate_t and this window is empty.
        c1 = min(max(tr.gate_t, now), tr.done_t) \
            if tr.gate_t is not None else now
        # queue window: waiting for the lane to go free
        c2 = min(max(tr.start_t, c1), tr.done_t)
        gate_piece = c1 - now
        queue_piece = c2 - c1
        service_piece = tr.done_t - c2
        if isinstance(tr.tier, Tier):
            if tr.tier == Tier.FLASH:
                lane_comp = ("gate_miss_restore" if tr.gate_miss
                             else "flash_service")
            elif tr.tier == Tier.GPU_FLASH:
                # the accelerator-direct path never rides the host
                # flash lane, so none of its seconds may land under
                # flash_service — its own Eq. 1 column
                lane_comp = "gpu_direct_service"
            else:
                lane_comp = "other"          # DRAM/HBM residuals
        elif isinstance(tr.tier, tuple) and tr.tier \
                and tr.tier[0] == "POOL":
            lane_comp = "pool_rtt"           # per-host far-memory lanes
        else:
            lane_comp = "nic_queue"          # NIC (or future) lanes
        tenant = tenant_of_key(tr.key)
        led = self.ledger
        if gate_piece:
            led.add("interference", gate_piece, tenant)
        if queue_piece:
            led.add("interference" if tr.behind_interference
                    else lane_comp, queue_piece, tenant)
        if service_piece:
            inc = service_piece * tr.incast_frac
            if inc:
                led.add("incast", inc, tenant)
            led.add(lane_comp, service_piece - inc, tenant)
        obs = self.obs
        if obs is not None and obs.tracer is not None:
            track = obs.tracer.track(self.label, self._lane_name(tr.tier))
            obs.tracer.instant(
                track, "stall", now, cat="stall",
                args={"key": str(tr.key), "stall": stall,
                      "gate": gate_piece, "queue": queue_piece,
                      "service": service_piece, "component": lane_comp})
        if obs is not None and obs.metrics is not None:
            obs.metrics.histogram("stall_seconds").observe(
                stall, (self.label, self._lane_name(tr.tier)))

    def drain(self, tier=None) -> float:
        """Advance to the completion of all in-flight transfers."""
        tiers = [tier] if tier is not None else list(self._inflight)
        t_done = self.clock.now()
        for t in tiers:
            for tr in self._inflight[t]:
                t_done = max(t_done, tr.done_t)
        self.clock.advance_to(t_done)
        for t in tiers:
            self._prune(t)
        return t_done

    def reset_stats(self):
        """Fresh `QueueStats` on every lane; in-flight transfers and lane
        free times are structural state and stay untouched."""
        self.qstats = {t: QueueStats() for t in self.qstats}

    def snapshot_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-lane `QueueStats` as plain dicts (the
        `MetricsRegistry` snapshot/reset protocol)."""
        return {self._lane_name(t): dataclasses.asdict(st)
                for t, st in self.qstats.items()}

    # --------------------------------------------------------------- report
    def report(self) -> str:
        lines = []
        for t in self._inflight:
            st = self.qstats[t]
            name = getattr(t, "name", str(t))
            lines.append(
                f"{name:6s} xfers={st.submitted:6d} "
                f"stall={st.stall_time*1e3:9.3f}ms "
                f"busy={st.busy_time*1e3:9.3f}ms "
                f"mum={st.miss_under_miss:5d} maxQ={st.max_depth:3d}")
        return "\n".join(lines)
