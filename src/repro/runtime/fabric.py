"""ShardedTieredStore — the multi-host tiering fabric (scale-out of the
paper's five-second rule to fleet serving).

The hot set S(T) of millions of sessions does not fit one host: keys are
sharded by consistent hashing over N per-host `TieredStore` instances,
each with its own `AsyncTierRuntime` and HBM/DRAM/flash queues, so
queueing on one host's Storage-Next SSD never perturbs another's. All
hosts — and every per-host NIC lane — are driven by ONE shared clock
(deterministic `VirtualClock` under test): a single `advance` models
compute on the serving host while transfers stream concurrently on
every host's flash and NIC queues, which is what makes cross-host
prefetch overlap simulable and byte-reproducible.

Network-tier service model: each host owns a NIC lane (an
`AsyncTierRuntime` whose only service model is `NetQueueModel`) with the
same occupancy/latency split as the flash tier — occupancy is the wire
time at the bandwidth share the link sustains at the current in-flight
depth (a single window-limited stream cannot saturate it), latency is
the fixed cluster RTT. Occupancies serialize on the lane, RTTs pipeline.
A remote fetch *composes* the two tiers: the owner host's flash read is
issued normally, and the NIC transfer is issued in the same instant but
gated with `not_before=flash.done_t` — it occupies a NIC queue slot
immediately (depth-dependent bandwidth share, FIFO link order) yet
cannot put bytes on the wire before the flash read delivers them. Data
always crosses the *sender's* egress NIC: the owner's for fetches, the
writing host's for cross-host puts.

Topology mode: pass `topology=FabricTopology(...)` (or a `net_model`
with one attached) and the single uniform link becomes per-pair — an
intra-rack hop gets the short ToR RTT at full NIC bandwidth, a
cross-rack hop the longer spine RTT at the oversubscribed uplink share,
and high fan-in into one destination divides its ingress bandwidth (the
incast penalty). The fabric tracks in-flight flows per destination and
hands the model `src`/`dst`/`fan_in` on every NIC submit.

Elasticity: `add_host()` / `remove_host(h)` recompute the consistent-
hash ring (vnodes keep the remap at ~1/N of resident keys) and stream
only the remapped keys as background rebalance transfers on the shared
clock — a flash read on a current holder, the sender's egress NIC
(gated on the read), and a destination placement whose write charge is
subject to the destination's write shielding exactly like a demotion.
Each call returns a `RebalanceStats` (keys/bytes moved vs resident) so
benchmarks can price the rebalance tax in stall per token; serving
continues throughout, it only queues behind the rebalance traffic.
`rebalance_rate=` caps the streams with a per-source token bucket
(bytes/s): each stream's flash read is released only after the bucket
drains the previous streams, bounding the tax under short prefetch
leads. Mid-rebalance restores at the destination are priced
conservatively: the destination store gates reads of a streamed key on
its NIC delivery time (readability gating, see `TieredStore.ingest`).

Unplanned failure: `fail_host(h)` is `remove_host` without the
courtesy — no drain, no retired queues. Keys resident only on h are
lost; replicated keys survive and reads route around the dead holder
(`holders()` only ever lists active hosts — degraded reads need no
special path), with `RemoteFetch.wait()` falling back to a surviving
holder when the sender died mid-flight. `under_replicated()` lists the
keys whose copy set no longer matches their target placement;
`repro.runtime.repair.RepairLoop` streams them back to their declared
degree under the same `rebalance_rate` token bucket as planned
rebalance.

Admission control rides in from `TieredStore`: pass
`write_shield_depth=k` and each host defers demotion writes while its
flash tier has >= k fetches in flight (Flashield-style write shielding;
deferral stats in each host's `TierStats`).

Replication: `put(..., replicas=r)` places copies on the r distinct
ring-successor hosts, and `get_async(..., from_host=h)` serves from h
itself when it holds a replica (no network), else from the first
replica in ring order — how `ExpertStore` shards replicated cold
experts so popular ones are usually a local flash read. The requested
`r` is remembered per key, so rebalancing after a join can restore a
replication degree the old host count could not hold.

Locality-aware scheduling: `preferred_host(key)` answers "where should
this session resume / this expert be fetched" — the least-loaded
current holder (resident-tier + NIC queue depth, ties in ring order),
which turns the remote NIC + remote-flash composition into a plain
local read and spreads hot replicated keys across their holders.
`prefetch_lead_steps` sizes the prefetch lead from the owner flash
tier's calibrated open-loop p99 (plus the NIC leg for remote fetches)
instead of a fixed step count.

Heterogeneous hosts: pass `host_specs=[{Tier: TierSpec, ...}, ...]`
(one entry per host; None entries take the shared default) and
`weights=[...]` and each host gets its own tier capacities/bandwidths
while the consistent-hash ring places `round(vnodes * weight)` virtual
nodes per host — a host with twice the capacity weight owns ~twice the
keys. Equal weights reproduce the unweighted ring bit-for-bit (the same
`host{h}/vn{v}` points), so homogeneous fleets are unchanged.
`add_host(specs=, weight=)` extends an elastic fleet with a
non-template host. The declarative front door for all of this is
`repro.platform.HierarchySpec` -> `Platform.compile`.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.policy import Tier, TieringPolicy
from ..obs.ledger import StallLedger
from .async_engine import AsyncTierRuntime, Transfer
from .clock import ensure_clock
from .pool import PooledFetch, PooledStore
from .service import NetQueueModel
from .tiers import (PendingFetch, TierSpec, TieredStore,
                    lead_steps_from_estimate)

NIC = "NIC"                     # lane key on each host's NIC runtime


def _key_digest(data: bytes) -> int:
    return int.from_bytes(
        hashlib.blake2b(data, digest_size=8).digest(), "big")


@dataclasses.dataclass
class RemoteFetch:
    """Handle for a cross-host fetch: the owner host's flash/DRAM read
    composed with the NIC transfer that starts when the read is done.
    `wait()` yields the value after blocking on the *unfinished* part of
    both stages — zero stall when enough compute overlapped.

    Degraded reads under unplanned failure: a *retired* owner
    (`remove_host`) keeps its queues alive until in-flight egress
    resolves, but a *failed* owner (`fail_host`) vanishes with the bytes
    still on the wire. `wait()` then falls back to a fresh fetch from a
    surviving holder — paying that full fetch as stall — or raises
    `KeyError` when the key died with the host."""
    fabric: "ShardedTieredStore"
    pf: PendingFetch
    nic_tr: Transfer
    owner: int
    dst: int = 0

    def _owner_failed_in_flight(self) -> bool:
        t_fail = self.fabric.failed.get(self.owner)
        return t_fail is not None and self.nic_tr.done_t > t_fail + 1e-12

    def done(self) -> bool:
        if self._owner_failed_in_flight():
            return False
        return self.nic_tr.is_done(self.fabric.clock.now())

    def wait(self) -> np.ndarray:
        if self._owner_failed_in_flight():
            # the sender died before delivery: degraded re-read from a
            # surviving holder (raises KeyError when the key was lost)
            fab = self.fabric
            if fab.obs is not None and fab.obs.tracer is not None:
                t = fab.obs.tracer
                t.instant(t.track("fabric", "failures"), "degraded_read",
                          fab.clock.now(), cat="policy",
                          args={"key": str(self.pf.key),
                                "dead_owner": self.owner,
                                "dst": self.dst})
            if fab.obs is not None and fab.obs.metrics is not None:
                fab.obs.metrics.counter("degraded_reads").inc(
                    (f"host{self.dst}",))
            return fab.get(self.pf.key, from_host=self.dst)
        if self.owner in self.fabric.failed:
            # both legs delivered before the failure instant; the dead
            # host's queues are gone, so skip its bookkeeping entirely
            return self.pf.value
        value = self.pf.wait()          # owner-store stats + policy move
        # the owner may have left the fleet since issue; its NIC lane
        # lives on in the retired map until the transfer resolves
        self.fabric._nic_of(self.owner).wait(self.nic_tr)
        return value


@dataclasses.dataclass
class FailureReport:
    """One unplanned host failure: what died with the host.

    `keys_lost` counts keys whose only copy lived on the failed host
    (committed data gone — their values and `_key_replicas` bookkeeping
    are purged, and `on_key_loss` subscribers fire). `keys_degraded`
    counts keys that survive on a replica but now sit below their
    declared replication degree until the repair loop restores it."""
    host: int
    t_fail: float
    keys_resident: int = 0      # keys the host held at the instant
    keys_lost: int = 0          # only copy was on the host
    bytes_lost: int = 0
    keys_degraded: int = 0      # survive on a replica, under-replicated
    lost_keys: Tuple = ()

    def as_dict(self) -> Dict[str, float]:
        return {
            "host": float(self.host),
            "t_fail": float(self.t_fail),
            "keys_resident": float(self.keys_resident),
            "keys_lost": float(self.keys_lost),
            "bytes_lost": float(self.bytes_lost),
            "keys_degraded": float(self.keys_degraded),
        }


@dataclasses.dataclass
class RebalanceStats:
    """One host join/leave: what the elastic remap actually moved.

    `bytes_resident` counts one copy per resident key at rebalance time
    (the fleet's unique payload), `bytes_moved` the rebalance streams —
    on a join of host N+1 their ratio should sit near 1/(N+1), the
    consistent-hash promise, measured rather than assumed. The stall tax
    is *not* in here: it lands in the ordinary tier/NIC queue stats of
    whatever serving traffic ran concurrently, and benchmarks price it
    as (churn stall - baseline stall) per token."""
    action: str                 # "join" | "leave"
    host: int                   # host id that joined / left
    t_start: float
    keys_resident: int = 0
    bytes_resident: int = 0
    keys_moved: int = 0
    bytes_moved: int = 0
    nic_transfers: int = 0

    @property
    def moved_fraction(self) -> float:
        return self.bytes_moved / max(self.bytes_resident, 1)

    def as_dict(self) -> Dict[str, float]:
        return {
            "action": self.action,
            "host": float(self.host),
            "t_start": float(self.t_start),
            "keys_resident": float(self.keys_resident),
            "bytes_resident": float(self.bytes_resident),
            "keys_moved": float(self.keys_moved),
            "bytes_moved": float(self.bytes_moved),
            "nic_transfers": float(self.nic_transfers),
            "moved_fraction": float(self.moved_fraction),
        }


class HostView:
    """One host's façade over the fabric, duck-typing `TieredStore` so
    `DecodeEngine` / `ExpertStore` run unmodified: every access routes
    through the fabric with this host as `from_host` (and this view's
    replication factor for puts)."""

    def __init__(self, fabric: "ShardedTieredStore", host: int,
                 replicas: int = 1):
        self.fabric = fabric
        self.host = host
        self.replicas = replicas

    @property
    def clock(self):
        return self.fabric.clock

    @property
    def runtime(self) -> AsyncTierRuntime:
        return self.fabric.hosts[self.host].runtime

    @property
    def stats(self):
        return self.fabric.hosts[self.host].stats

    @property
    def obs(self):
        return self.fabric.obs

    @property
    def ledger(self):
        return self.fabric.ledger

    @property
    def label(self) -> str:
        return f"host{self.host}"

    def put(self, key, value, tier: Tier = Tier.DRAM):
        self.fabric.put(key, value, tier=tier, from_host=self.host,
                        replicas=self.replicas)

    def get(self, key):
        return self.fabric.get(key, from_host=self.host)

    def get_async(self, key):
        return self.fabric.get_async(key, from_host=self.host)

    def tier_of(self, key) -> Optional[Tier]:
        return self.fabric.tier_of(key)

    def move(self, key, dst: Tier):
        self.fabric.move(key, dst)

    def delete(self, key):
        self.fabric.delete(key)

    def estimate_fetch_seconds(self, key) -> float:
        return self.fabric.estimate_fetch_seconds(key,
                                                  from_host=self.host)

    def prefetch_lead_steps(self, key, step_time: float) -> int:
        return self.fabric.prefetch_lead_steps(key, step_time,
                                               from_host=self.host)


class ShardedTieredStore:
    """Consistent-hash-sharded multi-host TieredStore on one clock,
    elastic under host join/leave."""

    def __init__(self, n_hosts: Optional[int] = None, *,
                 policy_factory=None,
                 specs: Optional[Dict[Tier, TierSpec]] = None,
                 host_specs: Optional[
                     List[Optional[Dict[Tier, TierSpec]]]] = None,
                 weights: Optional[List[float]] = None,
                 clock=None, sim_cfg=None,
                 net_model: Optional[NetQueueModel] = None,
                 write_shield_depth: Optional[int] = None,
                 vnodes: int = 64, topology=None,
                 rebalance_rate: Optional[float] = None,
                 obs=None, pool: Optional[PooledStore] = None):
        if host_specs is not None:
            if n_hosts is not None and n_hosts != len(host_specs):
                raise ValueError(
                    f"n_hosts={n_hosts} but {len(host_specs)} host_specs "
                    f"given; pass one or make them agree")
            n_hosts = len(host_specs)
        if n_hosts is None or n_hosts < 1:
            raise ValueError("need at least one host")
        if weights is not None:
            if len(weights) != n_hosts:
                raise ValueError(
                    f"{len(weights)} ring weights for {n_hosts} hosts")
            if any(w <= 0 for w in weights):
                raise ValueError("ring weights must be positive")
        if rebalance_rate is not None and rebalance_rate <= 0:
            raise ValueError("rebalance_rate must be positive bytes/s")
        self.clock = ensure_clock(clock)
        if policy_factory is None:
            policy_factory = lambda h: TieringPolicy(  # noqa: E731
                tau_hot=0.05, tau_be=5.0)
        # construction recipe, reused verbatim by add_host()
        self._policy_factory = policy_factory
        self._specs = specs
        self._sim_cfg = sim_cfg
        self._write_shield_depth = write_shield_depth
        self.vnodes = vnodes
        # token-bucket cap on rebalance streams, bytes/s per source host
        # (None = stream at full rate, the pre-pacing behavior)
        self.rebalance_rate = rebalance_rate
        if net_model is None:
            net_model = NetQueueModel(topology=topology)
        elif topology is not None:
            # ambiguous: the model's own topology (even None) would
            # silently win over the explicit argument
            raise ValueError(
                "pass the topology on the net_model, not alongside it")
        self.net_model = net_model
        # one observability plane (and ONE stall ledger) shared by every
        # host runtime and NIC lane — cross-host stall lands in the same
        # conservation-checked ledger as local stall
        self.obs = obs
        self.ledger: StallLedger = (obs.ledger if obs is not None
                                    else StallLedger())
        # the fleet-shared far-memory pool (None = the 3-tier fleet):
        # lanes attach per host in _new_host, capacity pressure spills
        # back to the owner's flash, and failure semantics are split —
        # the pool survives fail_host, the host's lane does not
        self.pool = pool
        if pool is not None:
            pool.on_evict = self._pool_evict
        self.hosts: Dict[int, TieredStore] = {}
        self.nic: Dict[int, AsyncTierRuntime] = {}
        self.host_ids: List[int] = []
        self._next_host = 0
        # per-host tier specs and ring weight (heterogeneous fleets);
        # a None spec entry means "the shared default"
        self._host_specs: Dict[int, Optional[Dict[Tier, TierSpec]]] = {}
        self._weights: Dict[int, float] = {}
        for i in range(n_hosts):
            self._new_host(
                specs=host_specs[i] if host_specs is not None else None,
                weight=weights[i] if weights is not None else 1.0)
        self._rebuild_ring()
        # in-flight NIC flows (transfer, src, dst) — destination fan-in
        # for the topology model's incast penalty
        self._nic_flows: List[Tuple[Transfer, int, int]] = []
        # requested replication degree per key (pre-clamp, so a join can
        # restore a degree the old host count could not hold)
        self._key_replicas: Dict[object, int] = {}
        # hosts removed but still carrying queue history (and possibly
        # in-flight egress) for drain/stats and late RemoteFetch waits
        self.retired: Dict[int, Tuple[TieredStore, AsyncTierRuntime]] = {}
        # hosts lost to unplanned failure: host -> failure time. Unlike
        # retirement nothing survives — in-flight egress is dead and
        # RemoteFetch handles fall back to a surviving holder.
        self.failed: Dict[int, float] = {}
        self.failures: List[FailureReport] = []
        # subscriber for lost keys (fabric-external bookkeeping: session
        # tables, benchmarks); per-host policies with a `forget_keys`
        # hook are notified regardless
        self.on_key_loss = None
        self.rebalances: List[RebalanceStats] = []
        # fabric-level counters
        self.local_fetches = 0
        self.remote_fetches = 0
        self.remote_puts = 0
        self.pool_fetches = 0
        self.pool_puts = 0

    @property
    def n_hosts(self) -> int:
        return len(self.host_ids)

    # ------------------------------------------------------------- topology
    def _new_host(self, specs: Optional[Dict[Tier, TierSpec]] = None,
                  weight: float = 1.0) -> int:
        if weight <= 0:
            raise ValueError("ring weight must be positive")
        h = self._next_host
        self._next_host += 1
        self._host_specs[h] = specs
        self._weights[h] = float(weight)
        self.hosts[h] = TieredStore(
            self._policy_factory(h),
            specs=specs if specs is not None else self._specs,
            clock=self.clock, sim_cfg=self._sim_cfg,
            write_shield_depth=self._write_shield_depth,
            obs=self.obs, ledger=self.ledger, label=f"host{h}")
        self.nic[h] = AsyncTierRuntime(
            clock=self.clock, service_models={NIC: self.net_model},
            obs=self.obs, ledger=self.ledger, label=f"host{h}")
        # attach the gate's decision tracer (policy instants ride on the
        # same tracer as the transfer spans)
        policy = self.hosts[h].policy
        if hasattr(policy, "obs"):
            policy.obs = self.obs
        if self.pool is not None:
            self.pool.attach_host(h)
        self.host_ids.append(h)
        return h

    def _rebuild_ring(self):
        # consistent-hash ring: `round(vnodes * weight)` points per host
        # keep the key split proportional to capacity weight (uniform
        # weights: exactly `vnodes` each — the unweighted ring bit-for-
        # bit) and make host count changes remap only ~weight/total keys
        points: List[Tuple[int, int]] = []
        for h in self.host_ids:
            n_pts = max(1, int(round(self.vnodes * self._weights[h])))
            for v in range(n_pts):
                points.append((_key_digest(f"host{h}/vn{v}".encode()), h))
        points.sort()
        self._ring_points = [p for p, _ in points]
        self._ring_hosts = [h for _, h in points]
        # array mirror of the ring for the batched control plane:
        # `owner_batch` searchsorts the uint64 point array instead of
        # bisecting per key (the digest covers the full uint64 range, so
        # the dtype is exact for every blake2b-8 point)
        self._ring_points_arr = np.asarray(self._ring_points, np.uint64)
        self._ring_hosts_arr = np.asarray(self._ring_hosts, np.int64)

    def _nic_of(self, host: int) -> AsyncTierRuntime:
        if host in self.nic:
            return self.nic[host]
        return self.retired[host][1]

    def _all_stores(self) -> List[TieredStore]:
        """Active then retired stores — every surface that aggregates or
        drains must include retired hosts until their queues resolve."""
        return list(self.hosts.values()) + [s for s, _ in
                                            self.retired.values()]

    def _all_nics(self) -> List[AsyncTierRuntime]:
        return list(self.nic.values()) + [n for _, n in
                                          self.retired.values()]

    def _nic_submit(self, src: int, dst: int, key, nbytes: int,
                    kind: str, not_before=None) -> Transfer:
        """Egress-NIC submit with per-pair topology context: the model is
        handed src/dst (rack vs spine RTT and bandwidth) and the
        destination's live sender fan-in (incast). Uniform models get the
        plain depth-only call."""
        ctx = None
        incast_frac = 0.0
        if self.net_model.topology is not None:
            now = self.clock.now()
            self._nic_flows = [f for f in self._nic_flows
                               if not f[0].is_done(now)]
            senders = {s for t, s, d in self._nic_flows if d == dst}
            senders.add(src)
            ctx = {"src": src, "dst": dst, "fan_in": len(senders)}
            if len(senders) > 1:
                # the share of this transfer's service the incast
                # penalty is responsible for: compare against the same
                # hop at fan_in=1 (the ledger splits the service window
                # into `incast` vs `nic_queue` by this fraction)
                d = self.nic[src].queue_depth(NIC) + 1
                act = self.net_model.service(nbytes, d, **ctx)
                base = self.net_model.service(nbytes, d, src=src,
                                              dst=dst, fan_in=1)
                if act.total > 0:
                    incast_frac = max(0.0, 1.0 - base.total / act.total)
        tr = self.nic[src].submit(NIC, key, nbytes, kind=kind,
                                  not_before=not_before, ctx=ctx)
        tr.incast_frac = incast_frac
        if self.net_model.topology is not None:
            self._nic_flows.append((tr, src, dst))
        return tr

    def _policy_instant(self, name: str, args: Dict) -> None:
        """Fleet-level policy decision (join/leave/fail/rebalance) onto
        the shared tracer's fabric track."""
        if self.obs is not None and self.obs.tracer is not None:
            t = self.obs.tracer
            t.instant(t.track("fabric", "policy"), name,
                      self.clock.now(), cat="policy", args=args)

    # ------------------------------------------------------------- routing
    def _key_point(self, key) -> int:
        return _key_digest(repr(key).encode())

    def owner(self, key) -> int:
        return self.ring_hosts(key)[0]

    def key_digest_batch(self, keys) -> np.ndarray:
        """uint64 ring digests for a key batch. Hashing is the only
        per-key Python left on the batched routing path; reuse the
        returned digests across calls (`owner_batch(digests=...)`) when
        the key set is stable."""
        return np.fromiter(
            (_key_digest(repr(k).encode()) for k in keys),
            dtype=np.uint64, count=len(keys))

    def owner_batch(self, keys=None, *,
                    digests: Optional[np.ndarray] = None) -> np.ndarray:
        """First ring owner for a batch of keys in one `searchsorted` —
        the vectorized twin of `owner()` (same blake2b points, same
        `bisect_right` wrap semantics), for control planes routing 1e5+
        keys per step. Pass precomputed `digests` (from
        `key_digest_batch`) to amortize hashing across steps; host-count
        changes only rebuild the ring arrays, digests stay valid."""
        if digests is None:
            if keys is None:
                raise ValueError("owner_batch needs keys or digests")
            digests = self.key_digest_batch(keys)
        idx = np.searchsorted(self._ring_points_arr,
                              np.asarray(digests, np.uint64),
                              side="right")
        return self._ring_hosts_arr[idx % len(self._ring_hosts_arr)]

    def ring_hosts(self, key) -> List[int]:
        """All active hosts in ring order starting at the key's point
        (distinct, length n_hosts) — replica placement and
        fetch-preference order."""
        i = bisect.bisect_right(self._ring_points, self._key_point(key))
        seen: List[int] = []
        n = len(self._ring_hosts)
        for j in range(n):
            h = self._ring_hosts[(i + j) % n]
            if h not in seen:
                seen.append(h)
                if len(seen) == self.n_hosts:
                    break
        return seen

    def holders(self, key) -> List[int]:
        """Hosts currently holding `key`, in ring-preference order."""
        return [h for h in self.ring_hosts(key)
                if self.hosts[h].tier_of(key) is not None]

    def preferred_host(self, key,
                       default: Optional[int] = None) -> Optional[int]:
        """Locality-aware, replica-aware routing: the *least-loaded*
        current holder — serving there turns the remote NIC +
        remote-flash composition into a local read, and with replicas
        the read load spreads by live queue depth (the holder's resident
        tier plus its NIC lane) instead of always hammering the first
        ring owner. Ties break in ring order, so the single-replica
        behavior is unchanged. Returns `default` when nothing holds the
        key."""
        held = self.holders(key)
        if len(held) <= 1:
            return held[0] if held else default

        def load(pos_host):
            pos, h = pos_host
            store = self.hosts[h]
            depth = store.runtime.queue_depth(store.tier_of(key))
            return (depth + self.nic[h].queue_depth(NIC), pos)

        return min(enumerate(held), key=load)[1]

    def _targets(self, key) -> List[int]:
        r = self._key_replicas.get(key, 1)
        return self.ring_hosts(key)[:max(1, min(r, self.n_hosts))]

    # ------------------------------------------------------------------ api
    def put(self, key, value, tier: Tier = Tier.DRAM, from_host: int = 0,
            replicas: int = 1):
        """Place `key` on its `replicas` ring-owner hosts. A copy bound
        for a host other than `from_host` additionally streams over the
        writer's egress NIC (non-blocking, like tier writes)."""
        value = np.asarray(value)
        self._key_replicas[key] = max(1, int(replicas))
        if self._pool_admit(key, tier, from_host):
            # the gate priced the object into the pool: one fleet copy
            # behind the writer's pool lane, no per-host residency (the
            # pool is infrastructure — host replication does not apply)
            for h in self.holders(key):
                self.hosts[h].delete(key)
            self.pool.put(key, value, from_host=from_host)
            self.pool_puts += 1
            # same admit-then-observe order as TieredStore.put: the
            # write is a reuse event even though no host placed bytes
            self.hosts[from_host].policy.observe(
                key, now=self.clock.now())
            return
        targets = self._targets(key)
        # drop stale copies on hosts that are no longer targets
        for h in self.holders(key):
            if h not in targets:
                self.hosts[h].delete(key)
        for h in targets:
            self.hosts[h].put(key, value, tier=tier)
            if h != from_host:
                self._nic_submit(from_host, h, key, value.nbytes,
                                 kind="write")
                self.remote_puts += 1
        if self.pool is not None:
            # a host placement supersedes any stale pooled copy
            self.pool.delete(key)

    def get_async(self, key, from_host: int = 0):
        """Issue a non-blocking fetch. Local replica -> the plain
        single-host path; otherwise the remote composition of the owner
        host's flash service and its egress NIC service."""
        if self.hosts[from_host].tier_of(key) is not None:
            self.local_fetches += 1
            return self.hosts[from_host].get_async(key)
        if self.pool is not None and self.pool.has(key):
            # pooled copy: one hop over this host's pool lane — checked
            # between the local-DRAM miss and the remote-flash
            # composition, which is exactly where the tier sits
            self.pool_fetches += 1
            return self.pool.get_async(
                key, from_host=from_host,
                on_wait=lambda pf: self._after_pool_fetch(pf, from_host))
        held = self.holders(key)
        if not held:
            raise KeyError(key)
        owner = held[0]
        pf = self.hosts[owner].get_async(key)
        nic_tr = self._nic_submit(owner, from_host, key, pf.value.nbytes,
                                  kind="fetch",
                                  not_before=pf.transfer.done_t)
        # prefetch hit/late classification must see the COMPOSED
        # completion (flash + NIC), not just the flash leg
        pf.external_done_t = nic_tr.done_t
        self.remote_fetches += 1
        return RemoteFetch(fabric=self, pf=pf, nic_tr=nic_tr, owner=owner,
                           dst=from_host)

    def get(self, key, from_host: int = 0) -> np.ndarray:
        return self.get_async(key, from_host=from_host).wait()

    # ----------------------------------------------------------- pool hooks
    def _pool_admit(self, key, tier: Tier, from_host: int) -> bool:
        """Ask the writing host's gate whether `key` belongs in the
        fleet pool. Plain policies have no `pool_admit` hook and never
        pool; the decision is economic (tracked reuse vs the pool
        column's tau_be), not structural."""
        if self.pool is None:
            return False
        hook = getattr(self.hosts[from_host].policy, "pool_admit", None)
        if hook is None:
            return False
        return bool(hook(key, tier, now=self.clock.now()))

    def _pool_evict(self, key, value, owner: int) -> None:
        """Pool capacity pressure spills the LRU victim back to flash
        on its pooling host (or the ring owner when that host has since
        failed) — the pool never drops committed bytes."""
        h = owner if owner in self.hosts else self.owner(key)
        self.hosts[h].ingest(key, value, tier=Tier.FLASH)

    def _after_pool_fetch(self, pf: PooledFetch, from_host: int) -> None:
        """Post-wait hook on a pool read: the access is a reuse event
        (one policy observation), and an object the policy now wants
        warm is promoted into the reading host's hierarchy — placed via
        `ingest` (no re-admission round-trip) with the pooled copy
        retired."""
        policy = self.hosts[from_host].policy
        want = policy.observe(pf.key, now=self.clock.now())
        if want < Tier.FLASH:
            self.hosts[from_host].ingest(pf.key, pf.value, tier=want)
            self.pool.delete(pf.key)
            self.pool.stats.promotions += 1

    def tier_of(self, key) -> Optional[Tier]:
        for h in self.ring_hosts(key):
            t = self.hosts[h].tier_of(key)
            if t is not None:
                return t
        if self.pool is not None and self.pool.has(key):
            return Tier.POOL
        return None

    def move(self, key, dst: Tier):
        for h in self.holders(key):
            self.hosts[h].move(key, dst)

    def delete(self, key):
        for h in self.holders(key):
            self.hosts[h].delete(key)
        if self.pool is not None:
            self.pool.delete(key)
        self._key_replicas.pop(key, None)
        # a deleted key must leave the reuse bookkeeping too: a later
        # re-put is a first touch, not a measured "reuse" across the gap
        self._notify_key_loss([key])

    def host_view(self, host: int, replicas: int = 1) -> HostView:
        return HostView(self, host, replicas=replicas)

    # ------------------------------------------------------ prefetch sizing
    def estimate_fetch_seconds(self, key, from_host: int = 0) -> float:
        """Tail-aware fetch estimate from `from_host`'s vantage point: a
        local replica is the single-host p99 estimate; a remote fetch
        adds the owner's egress NIC service (per-pair under topology) on
        top of the owner's flash estimate."""
        if self.hosts[from_host].tier_of(key) is not None:
            return self.hosts[from_host].estimate_fetch_seconds(key)
        if self.pool is not None and self.pool.has(key):
            lane = self.pool.lanes.get(from_host)
            if lane is None:
                raise KeyError(key)
            nbytes = self.pool.nbytes_of(key)
            depth = self.pool.runtime.queue_depth(lane) + 1
            svc = self.pool.lane_model.service(nbytes, depth)
            return svc.occupancy + svc.latency
        held = self.holders(key)
        if not held:
            raise KeyError(key)
        owner = held[0]
        est = self.hosts[owner].estimate_fetch_seconds(key)
        nbytes = self.hosts[owner].nbytes_of(key)
        depth = self.nic[owner].queue_depth(NIC) + 1
        if self.net_model.topology is not None:
            svc = self.net_model.service(nbytes, depth, src=owner,
                                         dst=from_host, fan_in=1)
        else:
            svc = self.net_model.service(nbytes, depth)
        return est + svc.occupancy + svc.latency

    def prefetch_lead_steps(self, key, step_time: float,
                            from_host: int = 0) -> int:
        """p99-sized prefetch lead for restoring `key` on `from_host`:
        issue the fetch `ceil(estimate / step_time)` decode steps early
        (>= 1) instead of a fixed lead."""
        return lead_steps_from_estimate(
            self.estimate_fetch_seconds(key, from_host=from_host),
            step_time)

    # ---------------------------------------------------------- elasticity
    def add_host(self, specs: Optional[Dict[Tier, TierSpec]] = None,
                 weight: float = 1.0) -> RebalanceStats:
        """Join a new host: recompute the ring and stream only the
        remapped ~weight/total of resident keys to it as background
        rebalance transfers (source flash read -> source egress NIC ->
        destination placement, the write subject to the destination's
        write shield). Serving continues; it queues behind the rebalance
        traffic. `specs`/`weight` admit a non-template host into a
        heterogeneous fleet (defaults: the shared tier specs, weight 1)."""
        h = self._new_host(specs=specs, weight=weight)
        self._rebuild_ring()
        self._policy_instant("autoscale_add_host",
                             {"host": h, "weight": float(weight)})
        return self._rebalance("join", h)

    def remove_host(self, host: int) -> RebalanceStats:
        """Drain a leaving host: recompute the ring without it, stream
        every key it uniquely holds to the new owners (preferring a
        surviving replica as source), then retire its store and NIC.
        In-flight egress finishes in the background (`drain` still
        covers retired queues)."""
        if host not in self.host_ids:
            raise KeyError(f"host {host} is not active")
        if self.n_hosts == 1:
            raise ValueError("cannot remove the last host")
        self.host_ids.remove(host)
        self._rebuild_ring()
        self._policy_instant("autoscale_remove_host", {"host": host})
        rb = self._rebalance("leave", host, extra_sources=(host,))
        self.retired[host] = (self.hosts.pop(host), self.nic.pop(host))
        if self.pool is not None:
            self.pool.detach_host(host)
        return rb

    def fail_host(self, host: int) -> FailureReport:
        """Unplanned failure: the host vanishes NOW — no drain, no
        retired queues. Keys resident only on it are lost (values gone,
        `_key_replicas` bookkeeping purged, `on_key_loss` and per-host
        policy `forget_keys` hooks fire); replicated keys survive on
        their other holders, and reads route around the dead host via
        `holders()` ring order (degraded reads).

        Fate-sharing boundary for in-flight transfers: an egress leg of
        the dead host that had not delivered dies with it (`RemoteFetch`
        handles re-issue from a surviving holder on wait), while a
        destination placement already recorded by `ingest` is modeled as
        durable — once the structural placement exists the bytes are
        committed to the wire. Restoring the declared replication degree
        of the surviving under-replicated keys is the repair loop's job
        (`repro.runtime.repair.RepairLoop`)."""
        if host not in self.host_ids:
            raise KeyError(f"host {host} is not active")
        if self.n_hosts == 1:
            raise ValueError("cannot fail the last host")
        t_fail = self.clock.now()
        store = self.hosts.pop(host)
        self.nic.pop(host)
        self.host_ids.remove(host)
        self._rebuild_ring()
        self.failed[host] = t_fail
        if self.pool is not None:
            # the pool is fleet infrastructure and survives; only the
            # dead host's lane (and any bytes on it) dies
            self.pool.detach_host(host)
        # in-flight flows from the dead sender never arrive; stop
        # counting them toward any destination's incast fan-in
        self._nic_flows = [f for f in self._nic_flows if f[1] != host]
        dead_keys = store.keys()
        lost: List[object] = []
        bytes_lost = 0
        degraded = 0
        for key in dead_keys:
            if self.holders(key):
                degraded += 1
            else:
                lost.append(key)
                bytes_lost += store.nbytes_of(key)
                self._key_replicas.pop(key, None)
        report = FailureReport(
            host=host, t_fail=t_fail, keys_resident=len(dead_keys),
            keys_lost=len(lost), bytes_lost=bytes_lost,
            keys_degraded=degraded, lost_keys=tuple(lost))
        self.failures.append(report)
        self._policy_instant("fail_host", report.as_dict())
        if self.obs is not None and self.obs.metrics is not None:
            m = self.obs.metrics
            m.counter("host_failures").inc()
            m.counter("keys_lost").inc(v=float(len(lost)))
        self._notify_key_loss(lost)
        return report

    def under_replicated(self) -> List[object]:
        """Keys whose live copy set differs from their target placement:
        below the declared (clamped) replication degree after a failure,
        or left on non-target hosts by the ring change. Deterministic
        hash order — the repair loop's stream order."""
        resident = {k for s in self.hosts.values() for k in s.keys()}
        out: List[object] = []
        for key in sorted(resident,
                          key=lambda k: (self._key_point(k), repr(k))):
            if set(self.holders(key)) != set(self._targets(key)):
                out.append(key)
        return out

    def _notify_key_loss(self, keys: List[object]):
        """Fan lost/deleted keys out to every distinct per-host policy
        exposing `forget_keys` (ghost/EMA purge — see the satellite bug:
        stale last-seen entries turn a post-repair re-admission into a
        spurious measured reuse interval) and to the `on_key_loss`
        subscriber."""
        if not keys:
            return
        keys = list(keys)
        seen = set()
        for h in self.host_ids:
            policy = self.hosts[h].policy
            fk = getattr(policy, "forget_keys", None)
            if fk is not None and id(policy) not in seen:
                seen.add(id(policy))
                fk(keys)
        if self.on_key_loss is not None:
            self.on_key_loss(keys)

    def _rebalance(self, action: str, host: int,
                   extra_sources: Tuple[int, ...] = ()) -> RebalanceStats:
        rb = RebalanceStats(action=action, host=host,
                            t_start=self.clock.now())
        # rebalance pacing: per-source token bucket at `rebalance_rate`
        # bytes/s — each stream's flash read is released only when the
        # bucket has drained the previous streams' bytes, so the tax on
        # concurrent serving stays bounded even under short leads
        pace: Dict[int, float] = {}
        scan = list(self.host_ids) + [h for h in extra_sources
                                      if h not in self.host_ids]
        resident = {k for h in scan for k in self.hosts[h].keys()}
        # hash order makes the stream sequence independent of insertion
        # history (determinism across runs AND across equivalent states)
        for key in sorted(resident,
                          key=lambda k: (self._key_point(k), repr(k))):
            targets = self._targets(key)
            # ring-preference order, with leaving hosts last so a
            # surviving replica is preferred as the stream source
            held = [h for h in self.ring_hosts(key) + list(extra_sources)
                    if h in self.hosts
                    and self.hosts[h].tier_of(key) is not None]
            src = held[0]
            nbytes = self.hosts[src].nbytes_of(key)
            src_tier = self.hosts[src].tier_of(key)
            rb.keys_resident += 1
            rb.bytes_resident += nbytes
            moved = False
            for dst in targets:
                if dst in held:
                    continue
                release = None
                if self.rebalance_rate is not None:
                    release = max(self.clock.now(),
                                  pace.get(src, self.clock.now()))
                    pace[src] = release + nbytes / self.rebalance_rate
                value, tr = self.hosts[src].read_for_transfer(
                    key, not_before=release)
                nic_tr = self._nic_submit(src, dst, key, nbytes,
                                          kind="rebalance",
                                          not_before=tr.done_t)
                self.hosts[dst].ingest(key, value, tier=src_tier,
                                       not_before=nic_tr.done_t)
                rb.bytes_moved += nbytes
                rb.nic_transfers += 1
                moved = True
            if moved:
                rb.keys_moved += 1
            for h in held:
                if h not in targets:
                    self.hosts[h].delete(key)
        self.rebalances.append(rb)
        self._policy_instant("rebalance", rb.as_dict())
        return rb

    # ------------------------------------------------------------- control
    def drain(self) -> float:
        """Advance to the completion of every in-flight transfer on every
        host (tier queues and NICs, retired ones included), flushing
        shielded writes. Draining the tier queues completes the read
        bursts that shield deferred demotion writes, so flushing happens
        *after* each drain pass and the loop repeats until no transfer
        and no parked write remains."""
        t = self.clock.now()
        while True:
            stores, nics = self._all_stores(), self._all_nics()
            for store in stores:
                t = max(t, store.runtime.drain())
            for nic in nics:
                t = max(t, nic.drain())
            if self.pool is not None:
                t = max(t, self.pool.drain())
            if not any(store.flush_deferred_writes()
                       or store.deferred_writes_pending
                       for store in stores):
                return t

    def reset_stats(self):
        """Zero every per-host `TierStats`/`QueueStats`, every NIC lane's
        stats, and the fabric counters — not residency, parked writes,
        in-flight transfers, or recorded rebalances. Benchmarks call
        this between setup and the measured phase."""
        for store in self._all_stores():
            store.reset_stats()
        for nic in self._all_nics():
            nic.reset_stats()
        if self.pool is not None:
            self.pool.reset_stats()
        self.local_fetches = 0
        self.remote_fetches = 0
        self.remote_puts = 0
        self.pool_fetches = 0
        self.pool_puts = 0

    def snapshot_stats(self) -> Dict[str, object]:
        """Fleet-wide stats as plain dicts: per-host stores (retired
        included, keyed `retired{h}`), per-host NIC lanes, and the
        fabric counters (the `MetricsRegistry` snapshot/reset
        protocol)."""
        out: Dict[str, object] = {
            "hosts": {f"host{h}": self.hosts[h].snapshot_stats()
                      for h in self.host_ids},
            "nics": {f"host{h}": self.nic[h].snapshot_stats()
                     for h in self.host_ids},
            "retired": {f"retired{h}": s.snapshot_stats()
                        for h, (s, _) in sorted(self.retired.items())},
            "counters": {"local_fetches": self.local_fetches,
                         "remote_fetches": self.remote_fetches,
                         "remote_puts": self.remote_puts},
        }
        if self.pool is not None:
            out["pool"] = self.pool.snapshot_stats()
            out["counters"]["pool_fetches"] = self.pool_fetches
            out["counters"]["pool_puts"] = self.pool_puts
        return out

    def resident_bytes(self) -> int:
        """One copy per resident key (the fleet's unique payload)."""
        total = 0
        for key in {k for s in self.hosts.values() for k in s.keys()}:
            held = self.holders(key)
            if held:
                total += self.hosts[held[0]].nbytes_of(key)
        return total

    # --------------------------------------------------------------- stats
    def summary(self) -> Dict[str, float]:
        """Fabric-wide aggregates (plain floats — JSON/benchmark-ready)."""
        out = {
            "hosts": float(self.n_hosts),
            "local_fetches": float(self.local_fetches),
            "remote_fetches": float(self.remote_fetches),
            "remote_puts": float(self.remote_puts),
        }
        agg = {"prefetch_hits": 0, "prefetch_late": 0, "demotions": 0,
               "demotions_deferred": 0, "rebalance_deferred": 0,
               "deferred_bytes": 0}
        flash_stall = 0.0
        stores, nics = self._all_stores(), self._all_nics()
        for store in stores:
            for st in store.stats.values():
                for k in agg:
                    agg[k] += getattr(st, k)
            flash_stall += store.stats[Tier.FLASH].stall_time
        nic_stall = sum(n.qstats[NIC].stall_time for n in nics)
        nic_bytes = sum(n.qstats[NIC].bytes_moved for n in nics)
        out.update({k: float(v) for k, v in agg.items()})
        out["flash_stall"] = flash_stall
        out["nic_stall"] = nic_stall
        out["nic_bytes"] = float(nic_bytes)
        out["rebalances"] = float(len(self.rebalances))
        out["rebalance_keys_moved"] = float(
            sum(rb.keys_moved for rb in self.rebalances))
        out["rebalance_bytes_moved"] = float(
            sum(rb.bytes_moved for rb in self.rebalances))
        out["failed_hosts"] = float(len(self.failed))
        out["keys_lost"] = float(
            sum(r.keys_lost for r in self.failures))
        if self.pool is not None:
            ps = self.pool.stats
            out["pool_fetches"] = float(self.pool_fetches)
            out["pool_puts"] = float(self.pool_puts)
            out["pool_used_bytes"] = float(self.pool.used_bytes)
            out["pool_stall"] = float(ps.stall_time)
            out["pool_evictions"] = float(ps.evictions)
            out["pool_promotions"] = float(ps.promotions)
        return out

    def report(self) -> str:
        lines = []
        for h in self.host_ids:
            store, nst = self.hosts[h], self.nic[h].qstats[NIC]
            lines.append(f"host {h}:")
            lines.append(store.report())
            lines.append(
                f"NIC    xfers={nst.submitted:6d} "
                f"stall={nst.stall_time*1e3:9.3f}ms "
                f"bytes={nst.bytes_moved/2**20:9.1f}MiB "
                f"maxQ={nst.max_depth:3d}")
        s = self.summary()
        lines.append(
            f"fabric local={int(s['local_fetches'])} "
            f"remote={int(s['remote_fetches'])} "
            f"deferred_demotions={int(s['demotions_deferred'])} "
            f"rebalanced={s['rebalance_bytes_moved']/2**20:.1f}MiB "
            f"in {int(s['rebalances'])} events")
        return "\n".join(lines)
