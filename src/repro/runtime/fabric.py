"""ShardedTieredStore — the multi-host tiering fabric (scale-out of the
paper's five-second rule to fleet serving).

The hot set S(T) of millions of sessions does not fit one host: keys are
sharded by consistent hashing over N per-host `TieredStore` instances,
each with its own `AsyncTierRuntime` and HBM/DRAM/flash queues, so
queueing on one host's Storage-Next SSD never perturbs another's. All
hosts — and every per-host NIC lane — are driven by ONE shared clock
(deterministic `VirtualClock` under test): a single `advance` models
compute on the serving host while transfers stream concurrently on
every host's flash and NIC queues, which is what makes cross-host
prefetch overlap simulable and byte-reproducible.

Network-tier service model: each host owns a NIC lane (an
`AsyncTierRuntime` whose only service model is `NetQueueModel`) with the
same occupancy/latency split as the flash tier — occupancy is the wire
time at the bandwidth share the link sustains at the current in-flight
depth (a single window-limited stream cannot saturate it), latency is
the fixed cluster RTT. Occupancies serialize on the lane, RTTs pipeline.
A remote fetch *composes* the two tiers: the owner host's flash read is
issued normally, and the NIC transfer is issued in the same instant but
gated with `not_before=flash.done_t` — it occupies a NIC queue slot
immediately (depth-dependent bandwidth share, FIFO link order) yet
cannot put bytes on the wire before the flash read delivers them. Data
always crosses the *sender's* egress NIC: the owner's for fetches, the
writing host's for cross-host puts.

Admission control rides in from `TieredStore`: pass
`write_shield_depth=k` and each host defers demotion writes while its
flash tier has >= k fetches in flight (Flashield-style write shielding;
deferral stats in each host's `TierStats`).

Replication: `put(..., replicas=r)` places copies on the r distinct
ring-successor hosts, and `get_async(..., from_host=h)` serves from h
itself when it holds a replica (no network), else from the first
replica in ring order — how `ExpertStore` shards replicated cold
experts so popular ones are usually a local flash read.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.policy import Tier, TieringPolicy
from .async_engine import AsyncTierRuntime, Transfer
from .clock import ensure_clock
from .service import NetQueueModel
from .tiers import PendingFetch, TierSpec, TieredStore

NIC = "NIC"                     # lane key on each host's NIC runtime


def _key_digest(data: bytes) -> int:
    return int.from_bytes(
        hashlib.blake2b(data, digest_size=8).digest(), "big")


@dataclasses.dataclass
class RemoteFetch:
    """Handle for a cross-host fetch: the owner host's flash/DRAM read
    composed with the NIC transfer that starts when the read is done.
    `wait()` yields the value after blocking on the *unfinished* part of
    both stages — zero stall when enough compute overlapped."""
    fabric: "ShardedTieredStore"
    pf: PendingFetch
    nic_tr: Transfer
    owner: int

    def done(self) -> bool:
        return self.nic_tr.is_done(self.fabric.clock.now())

    def wait(self) -> np.ndarray:
        value = self.pf.wait()          # owner-store stats + policy move
        self.fabric.nic[self.owner].wait(self.nic_tr)
        return value


class HostView:
    """One host's façade over the fabric, duck-typing `TieredStore` so
    `DecodeEngine` / `ExpertStore` run unmodified: every access routes
    through the fabric with this host as `from_host` (and this view's
    replication factor for puts)."""

    def __init__(self, fabric: "ShardedTieredStore", host: int,
                 replicas: int = 1):
        self.fabric = fabric
        self.host = host
        self.replicas = replicas

    @property
    def clock(self):
        return self.fabric.clock

    @property
    def runtime(self) -> AsyncTierRuntime:
        return self.fabric.hosts[self.host].runtime

    @property
    def stats(self):
        return self.fabric.hosts[self.host].stats

    def put(self, key, value, tier: Tier = Tier.DRAM):
        self.fabric.put(key, value, tier=tier, from_host=self.host,
                        replicas=self.replicas)

    def get(self, key):
        return self.fabric.get(key, from_host=self.host)

    def get_async(self, key):
        return self.fabric.get_async(key, from_host=self.host)

    def tier_of(self, key) -> Optional[Tier]:
        return self.fabric.tier_of(key)

    def move(self, key, dst: Tier):
        self.fabric.move(key, dst)

    def delete(self, key):
        self.fabric.delete(key)


class ShardedTieredStore:
    """Consistent-hash-sharded multi-host TieredStore on one clock."""

    def __init__(self, n_hosts: int, *, policy_factory=None,
                 specs: Optional[Dict[Tier, TierSpec]] = None,
                 clock=None, sim_cfg=None,
                 net_model: Optional[NetQueueModel] = None,
                 write_shield_depth: Optional[int] = None,
                 vnodes: int = 64):
        if n_hosts < 1:
            raise ValueError("need at least one host")
        self.n_hosts = n_hosts
        self.clock = ensure_clock(clock)
        if policy_factory is None:
            policy_factory = lambda h: TieringPolicy(  # noqa: E731
                tau_hot=0.05, tau_be=5.0)
        self.hosts: List[TieredStore] = [
            TieredStore(policy_factory(h), specs=specs, clock=self.clock,
                        sim_cfg=sim_cfg,
                        write_shield_depth=write_shield_depth)
            for h in range(n_hosts)]
        net_model = net_model or NetQueueModel()
        self.nic: List[AsyncTierRuntime] = [
            AsyncTierRuntime(clock=self.clock,
                             service_models={NIC: net_model})
            for _ in range(n_hosts)]
        # consistent-hash ring: `vnodes` points per host keep the key
        # split even and make host count changes remap only ~1/N of keys
        points: List[Tuple[int, int]] = []
        for h in range(n_hosts):
            for v in range(vnodes):
                points.append((_key_digest(f"host{h}/vn{v}".encode()), h))
        points.sort()
        self._ring_points = [p for p, _ in points]
        self._ring_hosts = [h for _, h in points]
        # fabric-level counters
        self.local_fetches = 0
        self.remote_fetches = 0
        self.remote_puts = 0

    # ------------------------------------------------------------- routing
    def _key_point(self, key) -> int:
        return _key_digest(repr(key).encode())

    def owner(self, key) -> int:
        return self.ring_hosts(key)[0]

    def ring_hosts(self, key) -> List[int]:
        """All hosts in ring order starting at the key's point (distinct,
        length n_hosts) — replica placement and fetch-preference order."""
        i = bisect.bisect_right(self._ring_points, self._key_point(key))
        seen: List[int] = []
        n = len(self._ring_hosts)
        for j in range(n):
            h = self._ring_hosts[(i + j) % n]
            if h not in seen:
                seen.append(h)
                if len(seen) == self.n_hosts:
                    break
        return seen

    def holders(self, key) -> List[int]:
        """Hosts currently holding `key`, in ring-preference order."""
        return [h for h in self.ring_hosts(key)
                if self.hosts[h].tier_of(key) is not None]

    # ------------------------------------------------------------------ api
    def put(self, key, value, tier: Tier = Tier.DRAM, from_host: int = 0,
            replicas: int = 1):
        """Place `key` on its `replicas` ring-owner hosts. A copy bound
        for a host other than `from_host` additionally streams over the
        writer's egress NIC (non-blocking, like tier writes)."""
        value = np.asarray(value)
        targets = self.ring_hosts(key)[:max(1, min(replicas,
                                                   self.n_hosts))]
        # drop stale copies on hosts that are no longer targets
        for h in self.holders(key):
            if h not in targets:
                self.hosts[h].delete(key)
        for h in targets:
            self.hosts[h].put(key, value, tier=tier)
            if h != from_host:
                self.nic[from_host].submit(NIC, key, value.nbytes,
                                           kind="write")
                self.remote_puts += 1

    def get_async(self, key, from_host: int = 0):
        """Issue a non-blocking fetch. Local replica -> the plain
        single-host path; otherwise the remote composition of the owner
        host's flash service and its egress NIC service."""
        if self.hosts[from_host].tier_of(key) is not None:
            self.local_fetches += 1
            return self.hosts[from_host].get_async(key)
        holders = self.holders(key)
        if not holders:
            raise KeyError(key)
        owner = holders[0]
        pf = self.hosts[owner].get_async(key)
        nic_tr = self.nic[owner].submit(NIC, key, pf.value.nbytes,
                                        kind="fetch",
                                        not_before=pf.transfer.done_t)
        # prefetch hit/late classification must see the COMPOSED
        # completion (flash + NIC), not just the flash leg
        pf.external_done_t = nic_tr.done_t
        self.remote_fetches += 1
        return RemoteFetch(fabric=self, pf=pf, nic_tr=nic_tr, owner=owner)

    def get(self, key, from_host: int = 0) -> np.ndarray:
        return self.get_async(key, from_host=from_host).wait()

    def tier_of(self, key) -> Optional[Tier]:
        for h in self.ring_hosts(key):
            t = self.hosts[h].tier_of(key)
            if t is not None:
                return t
        return None

    def move(self, key, dst: Tier):
        for h in self.holders(key):
            self.hosts[h].move(key, dst)

    def delete(self, key):
        for h in self.holders(key):
            self.hosts[h].delete(key)

    def host_view(self, host: int, replicas: int = 1) -> HostView:
        return HostView(self, host, replicas=replicas)

    # ------------------------------------------------------------- control
    def drain(self) -> float:
        """Advance to the completion of every in-flight transfer on every
        host (tier queues and NICs), flushing shielded writes. Draining
        the tier queues completes the read bursts that shield deferred
        demotion writes, so flushing happens *after* each drain pass and
        the loop repeats until no transfer and no parked write remains."""
        t = self.clock.now()
        while True:
            for store in self.hosts:
                t = max(t, store.runtime.drain())
            for nic in self.nic:
                t = max(t, nic.drain())
            if not any(store.flush_deferred_writes()
                       or store.deferred_writes_pending
                       for store in self.hosts):
                return t

    # --------------------------------------------------------------- stats
    def summary(self) -> Dict[str, float]:
        """Fabric-wide aggregates (plain floats — JSON/benchmark-ready)."""
        out = {
            "hosts": float(self.n_hosts),
            "local_fetches": float(self.local_fetches),
            "remote_fetches": float(self.remote_fetches),
            "remote_puts": float(self.remote_puts),
        }
        agg = {"prefetch_hits": 0, "prefetch_late": 0, "demotions": 0,
               "demotions_deferred": 0, "deferred_bytes": 0}
        flash_stall = 0.0
        for store in self.hosts:
            for st in store.stats.values():
                for k in agg:
                    agg[k] += getattr(st, k)
            flash_stall += store.stats[Tier.FLASH].stall_time
        nic_stall = sum(n.qstats[NIC].stall_time for n in self.nic)
        nic_bytes = sum(n.qstats[NIC].bytes_moved for n in self.nic)
        out.update({k: float(v) for k, v in agg.items()})
        out["flash_stall"] = flash_stall
        out["nic_stall"] = nic_stall
        out["nic_bytes"] = float(nic_bytes)
        return out

    def report(self) -> str:
        lines = []
        for h, store in enumerate(self.hosts):
            nst = self.nic[h].qstats[NIC]
            lines.append(f"host {h}:")
            lines.append(store.report())
            lines.append(
                f"NIC    xfers={nst.submitted:6d} "
                f"stall={nst.stall_time*1e3:9.3f}ms "
                f"bytes={nst.bytes_moved/2**20:9.1f}MiB "
                f"maxQ={nst.max_depth:3d}")
        s = self.summary()
        lines.append(
            f"fabric local={int(s['local_fetches'])} "
            f"remote={int(s['remote_fetches'])} "
            f"deferred_demotions={int(s['demotions_deferred'])}")
        return "\n".join(lines)
