from .async_engine import AsyncTierRuntime, QueueStats, Transfer  # noqa
from .clock import CallableClock, VirtualClock, WallClock, ensure_clock  # noqa
from .fabric import (NIC, FailureReport, HostView,  # noqa
                     RebalanceStats, RemoteFetch, ShardedTieredStore)
from .pool import PoolStats, PooledFetch, PooledStore  # noqa
from .repair import RepairLoop, RepairStats  # noqa
from .service import (FabricTopology, FixedLatencyModel,  # noqa
                      GpuDirectQueueModel, NetQueueModel, PoolLaneModel,
                      Service, SsdQueueModel)
from .tiers import PendingFetch, TierSpec, TierStats, TieredStore  # noqa
