from .async_engine import AsyncTierRuntime, QueueStats, Transfer  # noqa
from .clock import CallableClock, VirtualClock, WallClock, ensure_clock  # noqa
from .service import FixedLatencyModel, Service, SsdQueueModel  # noqa
from .tiers import PendingFetch, TierSpec, TierStats, TieredStore  # noqa
