from .async_engine import AsyncTierRuntime, QueueStats, Transfer  # noqa
from .clock import CallableClock, VirtualClock, WallClock, ensure_clock  # noqa
from .fabric import (NIC, HostView, RebalanceStats, RemoteFetch,  # noqa
                     ShardedTieredStore)
from .service import (FabricTopology, FixedLatencyModel,  # noqa
                      NetQueueModel, Service, SsdQueueModel)
from .tiers import PendingFetch, TierSpec, TierStats, TieredStore  # noqa
