from .tiers import TierSpec, TierStats, TieredStore  # noqa
