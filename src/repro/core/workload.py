"""Workload-aware thresholds (paper §V, RQ3).

A workload is a population of N_blk blocks of size l_blk with per-block mean
access intervals {tau_i}. Caching policy is threshold-T: cache exactly
S(T) = {i : tau_i <= T}. Aggregate throughputs:

  Psi_c(T) = l * sum_{i in S(T)} 1/tau_i     (served from DRAM)
  Psi_d(T) = l * sum_{i not in S(T)} 1/tau_i (served from SSD)

Zero-copy miss path: one DMA + one processor read => DRAM bandwidth demand
B_use(T) = Psi_c + 2 Psi_d = 2*Theta - Psi_c (strictly decreasing in T).

Three thresholds (all closed-form for log-normal profiles):
  T_B = min{T : B_use(T) <= B_DRAM}      (DRAM bandwidth)
  T_S = min{T : Psi_d(T) <= B_SSD}       (usable SSD bandwidth)
  T_C = max{T : |S(T)| * l <= C_DRAM}    (DRAM capacity)

Viability: max(T_B, T_S) <= T_C. Economics-optimal operation:
tau_break_even in [max(T_B,T_S), T_C].
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax.scipy.special import ndtri
from jax.scipy.stats import norm


# ---------------------------------------------------------------------------
# Log-normal access-interval profile (closed forms)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LogNormalWorkload:
    """tau_i ~ LogNormal(mu, sigma^2); N_blk blocks of l_blk bytes."""

    mu: float
    sigma: float
    n_blk: float
    l_blk: float

    # ---- constructors ------------------------------------------------------
    @classmethod
    def from_total_throughput(cls, throughput: float, sigma: float,
                              n_blk: float, l_blk: float):
        """Pin E[aggregate throughput] = throughput (bytes/s)."""
        mu = sigma ** 2 / 2.0 + math.log(n_blk * l_blk / throughput)
        return cls(mu=mu, sigma=sigma, n_blk=n_blk, l_blk=l_blk)

    # ---- aggregates ----------------------------------------------------------
    @property
    def total_bytes(self) -> float:
        return self.n_blk * self.l_blk

    @property
    def total_throughput(self) -> float:
        """Theta = l * N * E[1/tau]."""
        return float(self.n_blk * self.l_blk
                     * math.exp(-self.mu + self.sigma ** 2 / 2.0))

    def cached_block_fraction(self, T):
        """|S(T)| / N."""
        x = (jnp.log(jnp.asarray(T, jnp.float64)) - self.mu) / self.sigma
        return norm.cdf(x)

    def cached_bytes(self, T):
        return self.cached_block_fraction(T) * self.total_bytes

    def psi_c(self, T):
        """Cached (DRAM-served) throughput at threshold T, bytes/s."""
        x = (jnp.log(jnp.asarray(T, jnp.float64)) - self.mu
             + self.sigma ** 2) / self.sigma
        return self.total_throughput * norm.cdf(x)

    def psi_d(self, T):
        return self.total_throughput - self.psi_c(T)

    def dram_bw_use(self, T):
        """B_use(T) = Psi_c + 2 Psi_d (zero-copy miss path, Eq. 4)."""
        return 2.0 * self.total_throughput - self.psi_c(T)

    def hit_rate_for_capacity(self, c_dram):
        """Fraction of accesses served from DRAM when the C/l hottest blocks
        are cached: Phi(Phi^{-1}(q) + sigma), q = C / (N l)."""
        q = jnp.clip(jnp.asarray(c_dram, jnp.float64) / self.total_bytes,
                     0.0, 1.0)
        z = ndtri(jnp.clip(q, 1e-300, 1.0 - 1e-16))
        rate = norm.cdf(z + self.sigma)
        return jnp.where(q >= 1.0, 1.0, jnp.where(q <= 0.0, 0.0, rate))

    def capacity_threshold(self, c_dram):
        """T_C: largest T whose cached set fits in c_dram bytes."""
        q = float(c_dram) / self.total_bytes
        if q >= 1.0:
            return float("inf")
        if q <= 0.0:
            return 0.0
        return float(jnp.exp(self.mu + self.sigma * ndtri(q)))

    def _invert_psi_c(self, target_psi_c) -> float:
        """Smallest T with Psi_c(T) >= target (bytes/s)."""
        theta = self.total_throughput
        r = float(target_psi_c) / theta
        if r <= 0.0:
            return 0.0
        if r >= 1.0:
            return float("inf")
        z = float(ndtri(r))
        return float(math.exp(self.mu - self.sigma ** 2 + self.sigma * z))

    def bandwidth_threshold(self, b_dram) -> float:
        """T_B: existence requires B_DRAM >= Theta."""
        need = 2.0 * self.total_throughput - float(b_dram)
        return self._invert_psi_c(need)

    def ssd_threshold(self, b_ssd) -> float:
        """T_S: Psi_d(T) <= B_SSD."""
        need = self.total_throughput - float(b_ssd)
        return self._invert_psi_c(need)

    def sample_intervals(self, n: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return np.exp(rng.normal(self.mu, self.sigma, size=n))


# ---------------------------------------------------------------------------
# Empirical profile (sorted interval array) — used for traces & property tests
# ---------------------------------------------------------------------------


class EmpiricalWorkload:
    """Same interface, computed from an explicit interval sample."""

    def __init__(self, intervals, l_blk: float, n_blk: Optional[float] = None):
        tau = np.sort(np.asarray(intervals, dtype=np.float64))
        if tau.size == 0 or np.any(tau <= 0):
            raise ValueError("intervals must be positive and non-empty")
        self.tau = tau
        self.l_blk = float(l_blk)
        # the sample may represent a larger population; scale counts/rates
        self.scale = float(n_blk) / tau.size if n_blk else 1.0
        self._rate_prefix = np.concatenate(
            [[0.0], np.cumsum(1.0 / tau)]) * self.scale

    @property
    def n_blk(self) -> float:
        return self.tau.size * self.scale

    @property
    def total_bytes(self) -> float:
        return self.n_blk * self.l_blk

    @property
    def total_throughput(self) -> float:
        return self.l_blk * self._rate_prefix[-1]

    def _k(self, T) -> int:
        return int(np.searchsorted(self.tau, T, side="right"))

    def cached_block_fraction(self, T):
        return self._k(T) / self.tau.size

    def cached_bytes(self, T):
        return self.cached_block_fraction(T) * self.total_bytes

    def psi_c(self, T):
        return self.l_blk * self._rate_prefix[self._k(T)]

    def psi_d(self, T):
        return self.total_throughput - self.psi_c(T)

    def dram_bw_use(self, T):
        return 2.0 * self.total_throughput - self.psi_c(T)

    def hit_rate_for_capacity(self, c_dram):
        k = min(int(float(c_dram) / (self.l_blk * self.scale)), self.tau.size)
        return self.l_blk * self._rate_prefix[k] / self.total_throughput

    def capacity_threshold(self, c_dram) -> float:
        k = int(float(c_dram) / (self.l_blk * self.scale))
        if k >= self.tau.size:
            return float("inf")
        if k < 1:
            return 0.0
        return float(self.tau[k - 1])

    def _invert_psi_c(self, target) -> float:
        if target <= 0:
            return 0.0
        if target > self.total_throughput:
            return float("inf")
        # smallest k with l * prefix[k] >= target
        k = int(np.searchsorted(self._rate_prefix, target / self.l_blk,
                                side="left"))
        if k < 1:
            return 0.0
        if k > self.tau.size:
            return float("inf")
        return float(self.tau[k - 1])

    def bandwidth_threshold(self, b_dram) -> float:
        return self._invert_psi_c(2.0 * self.total_throughput - float(b_dram))

    def ssd_threshold(self, b_ssd) -> float:
        return self._invert_psi_c(self.total_throughput - float(b_ssd))


# ---------------------------------------------------------------------------
# Combined threshold report
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Thresholds:
    t_b: float                 # DRAM-bandwidth threshold (s)
    t_s: float                 # SSD-bandwidth threshold (s)
    t_c: float                 # DRAM-capacity threshold (s); inf if C unset
    t_v: float                 # viability threshold max(t_b, t_s)

    @property
    def viable(self) -> bool:
        return self.t_v <= self.t_c

    def optimal(self, tau_break_even: float) -> bool:
        return self.viable and self.t_v <= tau_break_even <= self.t_c


def thresholds(workload, b_dram: float, b_ssd: float,
               c_dram: Optional[float] = None) -> Thresholds:
    t_b = float(workload.bandwidth_threshold(b_dram))
    t_s = float(workload.ssd_threshold(b_ssd))
    t_c = (float("inf") if c_dram is None
           else float(workload.capacity_threshold(c_dram)))
    return Thresholds(t_b=t_b, t_s=t_s, t_c=t_c, t_v=max(t_b, t_s))
