"""Constraint-aware usable IOPS (paper §IV, RQ2).

Each NAND channel is modeled as an M/D/1 queue: Poisson arrivals,
deterministic service, one request in service per channel. With per-channel
service time S = N_CH / IOPS_peak and utilization rho:

  mean read latency:  tau_mean(rho) = S * rho / (2 (1 - rho)) + tau_sense
  p-tail latency:     tau_p(rho)    = S * rho / (2 (1 - rho)) * ln(1/(1-p))
                                      + tau_sense        (Kingman exponential)

Both are monotone in rho, so the largest admissible utilization has the
closed form rho = 2c / (1 + 2c) with c = (tau_hat - tau_sense) / (S * k),
k = ln(1/(1-p)) for the tail constraint and k = 1 for the mean constraint.

Usable SSD IOPS then also respects the host budget:
  IOPS_ssd = min(rho_max * IOPS_peak, IOPS_proc / N_ssd).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LatencyTargets:
    """Application-level read-latency constraints (None = unconstrained)."""

    mean: Optional[float] = None       # seconds
    tail: Optional[float] = None       # seconds
    tail_percentile: float = 0.99


def _queue_time(rho, n_ch, iops_peak):
    service = n_ch / jnp.asarray(iops_peak, jnp.float64)
    rho = jnp.asarray(rho, jnp.float64)
    return service * rho / (2.0 * (1.0 - rho))


def mean_read_latency(rho, n_ch, iops_peak, tau_sense):
    return _queue_time(rho, n_ch, iops_peak) + tau_sense


def tail_read_latency(rho, n_ch, iops_peak, tau_sense, p=0.99):
    k = jnp.log(1.0 / (1.0 - p))
    return _queue_time(rho, n_ch, iops_peak) * k + tau_sense


def _rho_closed_form(tau_hat, tau_sense, service, k):
    """Largest rho with S * rho/(2(1-rho)) * k <= tau_hat - tau_sense."""
    headroom = jnp.asarray(tau_hat, jnp.float64) - tau_sense
    c = headroom / (service * k)
    rho = 2.0 * c / (1.0 + 2.0 * c)
    # no headroom -> cannot admit load at all
    return jnp.clip(jnp.where(headroom <= 0.0, 0.0, rho), 0.0, 1.0)


def rho_max_for_targets(targets: LatencyTargets, n_ch, iops_peak, tau_sense):
    """Largest channel utilization meeting both latency targets."""
    service = n_ch / jnp.asarray(iops_peak, jnp.float64)
    rho = jnp.asarray(1.0, jnp.float64)
    if targets.mean is not None:
        rho = jnp.minimum(rho, _rho_closed_form(
            targets.mean, tau_sense, service, 1.0))
    if targets.tail is not None:
        k = jnp.log(1.0 / (1.0 - targets.tail_percentile))
        rho = jnp.minimum(rho, _rho_closed_form(
            targets.tail, tau_sense, service, k))
    return rho


def usable_iops(iops_peak, rho_max, iops_proc, n_ssd=1):
    """Feasibility-capped SSD IOPS (paper §IV final expression)."""
    return jnp.minimum(jnp.asarray(rho_max, jnp.float64) * iops_peak,
                       jnp.asarray(iops_proc, jnp.float64) / n_ssd)
