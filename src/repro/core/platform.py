"""Platform-level viability analysis and provisioning advisor (paper §V).

Combines the calibrated economics (economics.py), feasibility-capped SSD
IOPS (constraints.py) and workload thresholds (workload.py) into a single
report with an explicit verdict and an upgrade recommendation — the
"actionable provisioning guidance" the paper argues the classical rule
lacks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from . import units
from .constraints import LatencyTargets, rho_max_for_targets, usable_iops
from .economics import CPU_DDR, GPU_GDDR, HostConfig, break_even
from .ssd_model import SsdConfig, iops_ssd_peak, storage_next_ssd
from .workload import Thresholds, thresholds


@dataclasses.dataclass(frozen=True)
class PlatformConfig:
    """A concrete host + storage deployment (paper §V-B set-up)."""

    name: str
    host: HostConfig
    ssd: SsdConfig
    n_ssd: int = 4
    b_dram_total: float = 540e9    # aggregate host-DRAM bandwidth (B/s)
    iops_proc: float = 100e6       # total host IOPS budget
    c_dram_total: Optional[float] = None  # None => capacity is a free variable


# §V-B reference platforms: 12ch DDR5-5600 (540 GB/s) / 8ch GDDR6-20 (640 GB/s)
CPU_PLATFORM = PlatformConfig(
    name="CPU+DDR", host=CPU_DDR, ssd=storage_next_ssd(),
    n_ssd=4, b_dram_total=540e9, iops_proc=100e6)
GPU_PLATFORM = PlatformConfig(
    name="GPU+GDDR", host=GPU_GDDR, ssd=storage_next_ssd(),
    n_ssd=4, b_dram_total=640e9, iops_proc=400e6)


@dataclasses.dataclass(frozen=True)
class PlatformReport:
    platform: str
    l_blk: int
    iops_ssd_peak: float        # per SSD, device physics
    rho_max: float              # latency-admissible utilization
    iops_ssd_usable: float      # per SSD after rho_max and host budget
    host_limited: bool          # host budget (not device) is the cap
    tau_break_even: float       # calibrated economics (s)
    th: Thresholds
    c_dram_viable: float        # min DRAM bytes for viability
    c_dram_optimal: float       # min DRAM bytes for economics-optimal point
    dram_bw_use_viable: float   # B_use at the viability threshold
    dram_bw_use_optimal: float
    verdict: str
    recommendation: str

    def summary(self) -> str:
        return (
            f"[{self.platform} @ {self.l_blk}B] usable "
            f"{units.human_rate(self.iops_ssd_usable)}/SSD "
            f"(rho_max={self.rho_max:.2f}"
            f"{', host-limited' if self.host_limited else ''}) | "
            f"tau_be={units.human_time(self.tau_break_even)} | "
            f"T_B={units.human_time(self.th.t_b)} "
            f"T_S={units.human_time(self.th.t_s)} "
            f"T_C={units.human_time(self.th.t_c)} | "
            f"C_viable={units.human_bytes(self.c_dram_viable)} "
            f"C_opt={units.human_bytes(self.c_dram_optimal)} | "
            f"{self.verdict}: {self.recommendation}")


def analyze_platform(platform: PlatformConfig, workload, l_blk: int,
                     targets: LatencyTargets = LatencyTargets(),
                     gamma_rw: float = 9.0,
                     phi_wa: float = 3.0) -> PlatformReport:
    """Full RQ1+RQ2+RQ3 pipeline for one platform/workload/block size."""
    ssd = platform.ssd
    peak = float(iops_ssd_peak(ssd, l_blk, gamma_rw, phi_wa))
    rho = float(rho_max_for_targets(targets, ssd.n_ch, peak,
                                    ssd.nand.tau_sense))
    per_ssd = float(usable_iops(peak, rho, platform.iops_proc,
                                platform.n_ssd))
    host_limited = platform.iops_proc / platform.n_ssd < rho * peak

    tau_be = float(break_even(platform.host, l_blk, ssd.cost, per_ssd))

    b_ssd_total = l_blk * per_ssd * platform.n_ssd
    th = thresholds(workload, platform.b_dram_total, b_ssd_total,
                    platform.c_dram_total)

    c_viable = float(workload.cached_bytes(th.t_v)) if th.t_v > 0 else 0.0
    t_o = max(tau_be, th.t_v)
    c_opt = float(workload.cached_bytes(t_o))

    bw_v = float(workload.dram_bw_use(th.t_v)) if th.t_v > 0 else \
        float(workload.dram_bw_use(1e-12))
    bw_o = float(workload.dram_bw_use(t_o))

    verdict, rec = _verdict(platform, th, tau_be, host_limited)
    return PlatformReport(
        platform=platform.name, l_blk=int(l_blk), iops_ssd_peak=peak,
        rho_max=rho, iops_ssd_usable=per_ssd, host_limited=host_limited,
        tau_break_even=tau_be, th=th, c_dram_viable=c_viable,
        c_dram_optimal=c_opt, dram_bw_use_viable=bw_v,
        dram_bw_use_optimal=bw_o, verdict=verdict, recommendation=rec)


def _verdict(platform: PlatformConfig, th: Thresholds, tau_be: float,
             host_limited: bool):
    """Paper §V-A diagnosis tree."""
    if th.t_b == float("inf"):
        return ("infeasible",
                "DRAM bandwidth below workload throughput: B_DRAM must "
                "exceed l_blk * sum(1/tau_i); upgrade memory system")
    if th.t_s == float("inf"):
        return ("infeasible",
                "storage path cannot absorb the uncached stream even with "
                "maximal caching; add SSDs or raise host IOPS")
    if not th.viable:  # only possible when c_dram_total is fixed
        if th.t_b > th.t_c >= th.t_s:
            return ("dram-bandwidth-limited", "increase B_DRAM")
        if th.t_s > th.t_c >= th.t_b:
            rec = "raise aggregate SSD throughput (more/faster SSDs)"
            if host_limited:
                rec += " — host IOPS budget is the sub-limiter; raise it first"
            return ("storage-limited", rec)
        return ("jointly-insufficient",
                "increase C_DRAM until T_C >= max(T_B,T_S), or upgrade "
                "bandwidths per price priority")
    if th.optimal(tau_be):
        return ("viable-optimal",
                "operate at tau_break_even; provision "
                f"C_DRAM = |S(tau_be)| * l_blk")
    if tau_be > th.t_c:
        return ("viable-suboptimal",
                "break-even beyond capacity threshold: add DRAM capacity to "
                "reach the economics-optimal point")
    return ("viable-suboptimal",
            "break-even below viability threshold: feasibility forces "
            "caching more than economics alone would; bandwidth upgrades "
            "(SSD/host) would reclaim the gap")
