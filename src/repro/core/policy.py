"""TieringPolicy — the actionable output of the paper, packaged for the
runtime (RQ4).

The analytics produce a break-even interval tau_be between adjacent tiers.
The runtime (serving KV cache, MoE expert store, checkpoint manager) feeds
observed reuse intervals; the policy answers "which tier should this object
live in right now". Decisions use an EMA of observed inter-access times and
a hysteresis band to avoid thrash at the boundary.

Tiers: HBM (accelerator), DRAM (host), FLASH (Storage-Next SSD). The
HBM<->DRAM boundary uses the same Eq. 1 with HBM standing in as the
"memory" and DRAM+interconnect as the "storage"; the DRAM<->FLASH boundary
is the paper's headline threshold.

Clock contract: `observe` / `evict_candidates` take an explicit `now`.
Callers on the async runtime (TieredStore and friends) always pass their
injected clock's time so decisions are deterministic under test; the
`time.monotonic()` default is a convenience edge for ad-hoc use only.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, Optional

import jax.numpy as jnp

from .constraints import LatencyTargets, rho_max_for_targets, usable_iops
from .economics import HostConfig, break_even
from .platform import PlatformConfig
from .ssd_model import iops_ssd_peak


class Tier(enum.IntEnum):
    """Placement tiers, ordered coldward.

    The first three are the classic per-host hierarchy. ``GPU_FLASH``
    is the BaM-style accelerator-direct flash path (same NAND, its own
    submission queue, no host-DRAM bounce — a *path*, not a medium) and
    ``POOL`` is the fleet-shared far-memory pool. Stores that predate
    the fourth tier iterate their own configured spec keys, never
    ``for t in Tier``, so adding members here does not change their
    behavior."""
    HBM = 0
    DRAM = 1
    FLASH = 2
    GPU_FLASH = 3
    POOL = 4


@dataclasses.dataclass
class TieringPolicy:
    """Two-boundary placement policy with hysteresis.

    tau_hot:  reuse intervals below this belong in HBM.
    tau_be:   reuse intervals below this (but >= tau_hot) belong in DRAM;
              above it, flash is cheaper (the five-second rule).
    hysteresis: multiplicative band; an object must exceed tau * (1 + h) to
              be demoted and drop below tau / (1 + h) to be promoted.
    """

    tau_hot: float
    tau_be: float
    hysteresis: float = 0.25
    ema_alpha: float = 0.2

    def __post_init__(self):
        if self.tau_hot > self.tau_be:
            raise ValueError("tau_hot must be <= tau_be")
        self._ema: Dict[object, float] = {}
        self._last_seen: Dict[object, float] = {}
        self._tier: Dict[object, Tier] = {}

    # ---- stateless decisions ------------------------------------------------
    def tier_for_interval(self, interval) -> Tier:
        if interval < self.tau_hot:
            return Tier.HBM
        if interval < self.tau_be:
            return Tier.DRAM
        return Tier.FLASH

    def tiers_for_intervals(self, intervals):
        """Vectorized decision: int8 array of Tier values."""
        iv = jnp.asarray(intervals)
        return jnp.where(iv < self.tau_hot, jnp.int8(Tier.HBM),
                         jnp.where(iv < self.tau_be, jnp.int8(Tier.DRAM),
                                   jnp.int8(Tier.FLASH)))

    # ---- stateful (EMA + hysteresis) ---------------------------------------
    def observe(self, key, now: Optional[float] = None) -> Tier:
        """Record an access to `key`; returns the (possibly new) tier."""
        now = time.monotonic() if now is None else now
        last = self._last_seen.get(key)
        self._last_seen[key] = now
        if last is not None:
            iv = max(now - last, 1e-9)
            prev = self._ema.get(key)
            self._ema[key] = (iv if prev is None
                              else (1 - self.ema_alpha) * prev
                              + self.ema_alpha * iv)
        return self.tier_of(key)

    def tier_of(self, key) -> Tier:
        ema = self._ema.get(key)
        if ema is None:                      # never re-accessed yet
            return self._tier.setdefault(key, Tier.DRAM)
        cur = self._tier.get(key, Tier.DRAM)
        want = self.tier_for_interval(ema)
        if want == cur:
            self._tier[key] = cur
            return cur
        # hysteresis: demotion needs interval above band, promotion below it
        h = 1.0 + self.hysteresis
        boundary = self.tau_hot if min(want, cur) == Tier.HBM else self.tau_be
        if want > cur and ema > boundary * h:
            cur = Tier(cur + 1)
        elif want < cur and ema < boundary / h:
            cur = Tier(cur - 1)
        self._tier[key] = cur
        return cur

    def forget_keys(self, keys) -> None:
        """Drop all state for `keys` — wired into delete and unplanned
        key-loss paths. A key wiped by a host failure must look like a
        first touch when it comes back: keeping the stale EMA/last-seen
        would price its re-admission off an interval the object never
        actually survived to exhibit."""
        for key in keys:
            self._ema.pop(key, None)
            self._last_seen.pop(key, None)
            self._tier.pop(key, None)

    def evict_candidates(self, tier: Tier, now: Optional[float] = None,
                         limit: int = 0):
        """Keys in `tier` with the stalest EMA — demotion order."""
        now = time.monotonic() if now is None else now
        keys = [k for k, t in self._tier.items() if t == tier]

        def staleness(k):
            # explicit None check: `ema or fallback` would treat a
            # legitimate 0.0 EMA (maximally hot) as "no EMA" and rank
            # the key by its idle gap — i.e. evict it first
            ema = self._ema.get(k)
            return ema if ema is not None \
                else now - self._last_seen.get(k, now)

        keys.sort(key=lambda k: -staleness(k))
        return keys[:limit] if limit else keys

    # ---- constructors --------------------------------------------------------
    @classmethod
    def from_platform(cls, platform: PlatformConfig, l_blk: int,
                      targets: LatencyTargets = LatencyTargets(),
                      gamma_rw: float = 9.0, phi_wa: float = 3.0,
                      hbm: Optional[HostConfig] = None, **kw):
        """Derive both boundaries from the calibrated analytics."""
        ssd = platform.ssd
        peak = float(iops_ssd_peak(ssd, l_blk, gamma_rw, phi_wa))
        rho = float(rho_max_for_targets(targets, ssd.n_ch, peak,
                                        ssd.nand.tau_sense))
        per_ssd = float(usable_iops(peak, rho, platform.iops_proc,
                                    platform.n_ssd))
        tau_be = float(break_even(platform.host, l_blk, ssd.cost, per_ssd))
        if hbm is None:
            # HBM "rent" vs DRAM fetch: HBM ~4x DRAM cost/byte, PCIe/NVLink
            # class fetch path modeled as a very high-IOPS low-cost device.
            tau_hot = tau_be / 50.0
        else:
            # treat DRAM as the storage tier: cost=die cost, IOPS=B/l
            dram_iops = platform.host.b_h_dram_die / l_blk
            tau_hot = float(break_even(hbm, l_blk, platform.host.alpha_h_dram,
                                       dram_iops))
        return cls(tau_hot=min(tau_hot, tau_be), tau_be=tau_be, **kw)
