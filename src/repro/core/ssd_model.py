"""First-principles SSD performance and cost model (paper §III-B, Eq. 2 family).

Peak SSD IOPS is the min of four architectural bounds:

  * the NAND-die bound        (sense/program timing x multi-plane parallelism)
  * the channel bound         (bus occupancy with SCA command timing)
  * the FTL translation bound (SSD-internal DRAM bandwidth / entry size)
  * the PCIe bound            (link bandwidth and root-complex packet rate)

scaled by the host-visible fraction (Gamma+1)/(Gamma+2*Phi_WA-1) that
accounts for garbage-collection write amplification competing with host I/O.

Everything is written in jnp so configurations can be swept with jax.vmap;
plain Python floats work too (weak-typed scalars).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp

from .units import GB, KiB, NS, US, MS


# ---------------------------------------------------------------------------
# Configuration dataclasses (paper Table I / Fig. 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NandConfig:
    """Per-die NAND characteristics."""

    name: str
    tau_sense: float          # array sensing latency (s)
    tau_prog: float           # page program latency (s)
    page_bytes: int           # physical page size l_PG
    n_plane: int              # independently readable planes per die
    die_bytes: float          # capacity per die C_NAND
    cost: float = 1.0         # normalized die cost (NAND die == 1.0)


# Table I rows.
SLC = NandConfig("SLC", tau_sense=5 * US, tau_prog=50 * US,
                 page_bytes=4 * KiB, n_plane=6, die_bytes=32 * GB)
PSLC = NandConfig("pSLC", tau_sense=20 * US, tau_prog=150 * US,
                  page_bytes=16 * KiB, n_plane=4, die_bytes=42 * GB)
TLC = NandConfig("TLC", tau_sense=40 * US, tau_prog=1 * MS,
                 page_bytes=16 * KiB, n_plane=4, die_bytes=128 * GB)

NAND_TYPES = {"slc": SLC, "pslc": PSLC, "tlc": TLC}


@dataclasses.dataclass(frozen=True)
class SsdConfig:
    """Whole-device architecture (paper Fig. 2 + Table I bottom row)."""

    nand: NandConfig
    n_ch: int = 20                   # channels
    n_nand: int = 4                  # dies per channel
    b_ch: float = 3.6e9              # channel bandwidth (B/s)
    tau_cmd: float = 150 * NS        # per-command bus occupancy (SCA)
    # FTL / controller
    ftl_entry_bytes: float = 8.0
    b_ssd_dram: float = 40e9         # SSD-internal DRAM bandwidth
    s_dram_die_bytes: float = 3 * GB # capacity per internal DRAM die
    # PCIe
    b_pcie: float = 64e9             # effective link bandwidth (Gen7 x4)
    pps_host: float = 200e6          # root-complex packet rate
    pkts_per_io: int = 2             # transactions per request (cmd + data)
    # normalized component costs (Table III)
    alpha_ctrl: float = 15.0
    alpha_s_dram: float = 1.0
    # "Normal" SSDs have 4KB-oriented ECC/controller: sub-4KB requests are
    # served as 4KB reads internally, flattening small-block IOPS.
    min_access_bytes: int = 512

    # ---- derived ----------------------------------------------------------
    @property
    def total_nand_bytes(self) -> float:
        return self.n_ch * self.n_nand * self.nand.die_bytes

    @property
    def ftl_bytes(self) -> float:
        # one entry per 512B of media (finest mapping granularity)
        return self.total_nand_bytes / 512.0 * self.ftl_entry_bytes

    @property
    def n_s_dram(self) -> int:
        return int(math.ceil(self.ftl_bytes / self.s_dram_die_bytes))

    @property
    def cost(self) -> float:
        """Normalized capital cost (NAND die == 1)."""
        return (self.alpha_ctrl
                + self.n_ch * self.n_nand * self.nand.cost
                + self.n_s_dram * self.alpha_s_dram)


def storage_next_ssd(nand: NandConfig = SLC, **kw) -> SsdConfig:
    """Storage-Next SSD: fine-grained (512B) ECC, SCA command timing."""
    return SsdConfig(nand=nand, min_access_bytes=512, **kw)


def normal_ssd(nand: NandConfig = SLC, **kw) -> SsdConfig:
    """Conventional SSD: 4KB ECC codewords -> sub-4KB reads cost a full 4KB."""
    kw.setdefault("tau_cmd", 1.2 * US)   # conventional 8-bit CMD/ADDR bus
    return SsdConfig(nand=nand, min_access_bytes=4 * KiB, **kw)


# ---------------------------------------------------------------------------
# Workload mix helpers
# ---------------------------------------------------------------------------


def rw_fractions(gamma_rw, phi_wa):
    """Internal read/write operation fractions (paper §III-B).

    gamma_rw: host read:write ratio (reads per write). May be jnp.inf for
      read-only workloads.
    phi_wa:  intra-SSD write amplification (>= 1).
    Returns (R_r, R_w, host_fraction) where host_fraction =
      (gamma+1)/(gamma+2*phi-1) converts internal op rate to host-visible
      IOPS.
    """
    gamma_rw = jnp.asarray(gamma_rw, dtype=jnp.float64)
    phi_wa = jnp.asarray(phi_wa, dtype=jnp.float64)
    inf = jnp.isinf(gamma_rw)
    g = jnp.where(inf, 1.0, gamma_rw)  # placeholder to avoid inf arithmetic
    denom = g + 2.0 * phi_wa - 1.0
    r_r = jnp.where(inf, 1.0, (g + phi_wa - 1.0) / denom)
    r_w = jnp.where(inf, 0.0, phi_wa / denom)
    host_frac = jnp.where(inf, 1.0, (g + 1.0) / denom)
    return r_r, r_w, host_frac


def gamma_from_mix(read_pct: float, write_pct: float) -> float:
    """90:10 -> 9.0; 100:0 -> inf."""
    if write_pct == 0:
        return float("inf")
    return read_pct / write_pct


# ---------------------------------------------------------------------------
# Per-component IOPS bounds (paper §III-B)
# ---------------------------------------------------------------------------


def effective_block(cfg: SsdConfig, l_blk):
    """Internal access size: normal SSDs round sub-4KB up to the codeword."""
    return jnp.maximum(jnp.asarray(l_blk, jnp.float64), cfg.min_access_bytes)


def iops_nand_peak(cfg: SsdConfig, l_blk, r_r, r_w):
    """Per-die IOPS bound from sense/program timing and plane parallelism."""
    nand = cfg.nand
    l_eff = effective_block(cfg, l_blk)
    reads = nand.n_plane / nand.tau_sense
    writes = nand.n_plane * nand.page_bytes / (nand.tau_prog * l_eff)
    return r_r * reads + r_w * writes


def iops_ch_peak(cfg: SsdConfig, l_blk, r_r, r_w):
    """Per-channel IOPS bound from bus occupancy (SCA command + transfer)."""
    nand = cfg.nand
    l_eff = effective_block(cfg, l_blk)
    tau_r = cfg.tau_cmd + l_eff / cfg.b_ch
    # a program moves a full page but commits page/l_blk host blocks
    tau_w_per_blk = (l_eff / nand.page_bytes) * cfg.tau_cmd + l_eff / cfg.b_ch
    return r_r / tau_r + r_w / tau_w_per_blk


def iops_xlat_peak(cfg: SsdConfig):
    """FTL translation bound: internal-DRAM bandwidth / entry size."""
    return cfg.b_ssd_dram / cfg.ftl_entry_bytes


def iops_pcie_peak(cfg: SsdConfig, l_blk):
    """Interconnect bound: link bandwidth and packet-processing rate (Eq. 3)."""
    l_blk = jnp.asarray(l_blk, jnp.float64)
    return jnp.minimum(cfg.b_pcie / l_blk, cfg.pps_host / cfg.pkts_per_io)


def iops_dev_peak(cfg: SsdConfig, l_blk, gamma_rw, phi_wa):
    """Memory-device-limited IOPS (die/channel mins, host-visible)."""
    r_r, r_w, host_frac = rw_fractions(gamma_rw, phi_wa)
    per_die = iops_nand_peak(cfg, l_blk, r_r, r_w)
    per_ch = iops_ch_peak(cfg, l_blk, r_r, r_w)
    internal = cfg.n_ch * jnp.minimum(cfg.n_nand * per_die, per_ch)
    return host_frac * internal


def iops_ssd_peak(cfg: SsdConfig, l_blk, gamma_rw=9.0, phi_wa=3.0):
    """Overall peak SSD IOPS (paper Eq. 2)."""
    dev = iops_dev_peak(cfg, l_blk, gamma_rw, phi_wa)
    return jnp.minimum(jnp.minimum(dev, iops_xlat_peak(cfg)),
                       iops_pcie_peak(cfg, l_blk))


def bottleneck(cfg: SsdConfig, l_blk, gamma_rw=9.0, phi_wa=3.0) -> str:
    """Which architectural bound limits the device at this operating point."""
    r_r, r_w, _ = rw_fractions(gamma_rw, phi_wa)
    terms = {
        "nand_die": float(cfg.n_ch * cfg.n_nand
                          * iops_nand_peak(cfg, l_blk, r_r, r_w)),
        "channel": float(cfg.n_ch * iops_ch_peak(cfg, l_blk, r_r, r_w)),
        "ftl_xlat": float(iops_xlat_peak(cfg)),
        "pcie": float(iops_pcie_peak(cfg, l_blk)),
    }
    return min(terms, key=terms.get)


# ---------------------------------------------------------------------------
# Convenience: classical datasheet-style summary
# ---------------------------------------------------------------------------


def describe(cfg: SsdConfig, l_blks=(512, 1024, 2048, 4096),
             gamma_rw=9.0, phi_wa=3.0) -> dict:
    out = {
        "name": f"{cfg.nand.name} x {cfg.n_ch}ch x {cfg.n_nand}die",
        "capacity_bytes": cfg.total_nand_bytes,
        "cost": cfg.cost,
        "n_s_dram": cfg.n_s_dram,
    }
    for l in l_blks:
        out[f"iops@{l}"] = float(iops_ssd_peak(cfg, l, gamma_rw, phi_wa))
        out[f"bound@{l}"] = bottleneck(cfg, l, gamma_rw, phi_wa)
    return out
