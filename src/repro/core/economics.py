"""Calibrated economic break-even model (paper §III-A, Eq. 1) plus the
classical Gray/Putzolu form it reduces to.

Costs are normalized to the NAND-die cost (Table III). Host DRAM cost and
bandwidth/capacity are per-die figures; the break-even interval only depends
on the per-die ratios, so totals are not needed here (they enter the
feasibility analysis in platform.py instead).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .ssd_model import SsdConfig, iops_ssd_peak


@dataclasses.dataclass(frozen=True)
class HostConfig:
    """Host-side cost/performance parameters (paper Table III row)."""

    name: str
    alpha_h_dram: float       # normalized cost per host-DRAM die
    b_h_dram_die: float       # bandwidth per DRAM die (B/s)
    c_h_dram_die: float       # capacity per DRAM die (bytes)
    alpha_core: float         # normalized cost per core / SM
    iops_core: float          # sustainable IOPS per core / SM


CPU_DDR = HostConfig("CPU+DDR", alpha_h_dram=1.0, b_h_dram_die=3e9,
                     c_h_dram_die=3e9, alpha_core=4.0, iops_core=1e6)
GPU_GDDR = HostConfig("GPU+GDDR", alpha_h_dram=2.0, b_h_dram_die=80e9,
                      c_h_dram_die=2e9, alpha_core=3.0, iops_core=4e6)


def break_even_components(host: HostConfig, l_blk, ssd_cost, iops_ssd):
    """Per-term contributions to the break-even interval, in seconds.

    Returns dict with 'host', 'dram_bw', 'ssd' components; their sum is the
    calibrated break-even interval (Eq. 1).
    """
    l_blk = jnp.asarray(l_blk, dtype=jnp.float64)
    # $ per I/O for each resource
    c_host_io = host.alpha_core / host.iops_core
    c_dram_io = l_blk * host.alpha_h_dram / host.b_h_dram_die
    c_ssd_io = jnp.asarray(ssd_cost, jnp.float64) / jnp.asarray(
        iops_ssd, jnp.float64)
    # DRAM rent rate: $ per second to hold the block resident
    rent_rate = l_blk * host.alpha_h_dram / host.c_h_dram_die
    return {
        "host": c_host_io / rent_rate,
        "dram_bw": c_dram_io / rent_rate,
        "ssd": c_ssd_io / rent_rate,
    }


def break_even(host: HostConfig, l_blk, ssd_cost, iops_ssd):
    """Calibrated break-even interval tau_be (seconds), Eq. 1."""
    c = break_even_components(host, l_blk, ssd_cost, iops_ssd)
    return c["host"] + c["dram_bw"] + c["ssd"]


def break_even_components_gpu_direct(host: HostConfig, l_blk, ssd_cost,
                                     iops_ssd, *, alpha_submit: float = 0.5,
                                     iops_submit: float = 2e7):
    """Eq. 1 column for the BaM-style GPU-direct flash *path*.

    Same NAND as the host-flash column, different path: the accelerator
    submits IOs straight to the device queue, so the host-CPU term
    (`alpha_core/iops_core`) and the host-DRAM wire term both vanish.
    What replaces them is a (much cheaper) accelerator submission-engine
    term — a few SMs drive millions of IOPS, so
    `alpha_submit/iops_submit` is orders of magnitude below the host
    per-IO cost. The denominator is unchanged (the question is still
    "is DRAM residency worth the rent"), so tau_be drops structurally:
    the DRAM-vs-storage threshold tightens when the storage path stops
    paying host rent.

    Returns {'submit', 'ssd'} components; their sum is tau_be for the
    gpu_flash column.
    """
    l_blk = jnp.asarray(l_blk, dtype=jnp.float64)
    c_submit = alpha_submit / iops_submit
    c_ssd_io = jnp.asarray(ssd_cost, jnp.float64) / jnp.asarray(
        iops_ssd, jnp.float64)
    rent_rate = l_blk * host.alpha_h_dram / host.c_h_dram_die
    return {
        "submit": c_submit / rent_rate,
        "ssd": c_ssd_io / rent_rate,
    }


def break_even_gpu_direct(host: HostConfig, l_blk, ssd_cost, iops_ssd,
                          **kw):
    """tau_be for the GPU-direct flash column (seconds)."""
    c = break_even_components_gpu_direct(host, l_blk, ssd_cost, iops_ssd,
                                         **kw)
    return c["submit"] + c["ssd"]


def break_even_components_pool(host: HostConfig, l_blk, *,
                               pool_bw: float = 12.5e9,
                               pool_rtt: float = 25e-6,
                               rent_factor: float = 0.5,
                               alpha_net: float = 2.0):
    """Eq. 1 column for the fleet-shared far-memory pool.

    The pool is DRAM-medium, so moving a block out of local DRAM does
    not stop the rent — it *discounts* it: pooled capacity is rented at
    `rent_factor` of the local rate because uncorrelated per-host peaks
    statistically multiplex onto one shared provision. The break-even
    interval therefore divides the fetch cost by the rent
    *differential* `rent_dram * (1 - rent_factor)`, not the full rent:

        tau_be_pool = c_pool_io / (rent_dram * (1 - rent_factor))

    c_pool_io has a fabric wire term (`l_blk * alpha_net / pool_bw`)
    and an RTT term (`alpha_net * pool_rtt` — the lane is held for one
    round trip per IO, priced at the port's capital-as-rent rate).

    Returns {'pool_wire', 'pool_rtt'} components; their sum is tau_be
    for the pool column.
    """
    if not 0.0 <= rent_factor < 1.0:
        raise ValueError(
            f"rent_factor must be in [0, 1) (got {rent_factor}): at 1.0 "
            "the pool rents at the local-DRAM rate and can never win")
    l_blk = jnp.asarray(l_blk, dtype=jnp.float64)
    rent_dram = l_blk * host.alpha_h_dram / host.c_h_dram_die
    rent_saved = rent_dram * (1.0 - rent_factor)
    c_wire = l_blk * alpha_net / pool_bw
    c_rtt = alpha_net * pool_rtt
    return {
        "pool_wire": c_wire / rent_saved,
        "pool_rtt": c_rtt / rent_saved,
    }


def break_even_pool(host: HostConfig, l_blk, **kw):
    """tau_be for the pool column (seconds)."""
    c = break_even_components_pool(host, l_blk, **kw)
    return c["pool_wire"] + c["pool_rtt"]


def pool_flash_crossover(host: HostConfig, l_blk, tau_be, *,
                         pool_bw: float = 12.5e9,
                         pool_rtt: float = 25e-6,
                         rent_factor: float = 0.5,
                         alpha_net: float = 2.0):
    """Upper edge of the pool band: the reuse interval beyond which a
    flash re-read underprices pooled residency.

    `break_even_pool` is the pool-vs-local-DRAM edge (where the
    discounted rent starts beating full rent). This is the other side
    of the band: pooled bytes still pay `rent_factor` of the DRAM rate
    per byte-second plus `c_pool_io` per access, while a flash-resident
    byte pays only the flash column's IO cost (`tau_be * rent_dram` per
    access, by Eq. 1's own definition). Pool wins iff

        c_pool_io + rent_factor * rent_dram * tau  <  tau_be * rent_dram

    i.e. tau < (tau_be - c_pool_io / rent_dram) / rent_factor. A result
    at or below tau_be means the band is empty — the pool's own access
    cost exceeds a flash IO and no interval prefers it.
    """
    if not 0.0 < rent_factor < 1.0:
        raise ValueError(
            f"rent_factor must be in (0, 1) (got {rent_factor})")
    l_blk = jnp.asarray(l_blk, dtype=jnp.float64)
    rent_dram = l_blk * host.alpha_h_dram / host.c_h_dram_die
    c_pool_io = l_blk * alpha_net / pool_bw + alpha_net * pool_rtt
    return (jnp.asarray(tau_be, jnp.float64)
            - c_pool_io / rent_dram) / rent_factor


def break_even_for_ssd(host: HostConfig, ssd: SsdConfig, l_blk,
                       gamma_rw=9.0, phi_wa=3.0, iops_ssd=None):
    """Break-even using the first-principles device model for the SSD term.

    iops_ssd overrides the peak (e.g. a feasibility-capped usable IOPS from
    constraints.py).
    """
    if iops_ssd is None:
        iops_ssd = iops_ssd_peak(ssd, l_blk, gamma_rw, phi_wa)
    return break_even(host, l_blk, ssd.cost, iops_ssd)


def classical_break_even(l_blk, ssd_cost, iops_ssd, dram_cost_per_byte):
    """Gray's economics-only rule: T = C_ssd_io / C_dram_page.

    With host terms dropped and peak IOPS assumed, Eq. 1 reduces to this.
    dram_cost_per_byte is in the same normalized units as ssd_cost.
    """
    c_ssd_io = jnp.asarray(ssd_cost, jnp.float64) / jnp.asarray(
        iops_ssd, jnp.float64)
    c_dram_page = jnp.asarray(l_blk, jnp.float64) * dram_cost_per_byte
    return c_ssd_io / c_dram_page
