"""Calibrated economic break-even model (paper §III-A, Eq. 1) plus the
classical Gray/Putzolu form it reduces to.

Costs are normalized to the NAND-die cost (Table III). Host DRAM cost and
bandwidth/capacity are per-die figures; the break-even interval only depends
on the per-die ratios, so totals are not needed here (they enter the
feasibility analysis in platform.py instead).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .ssd_model import SsdConfig, iops_ssd_peak


@dataclasses.dataclass(frozen=True)
class HostConfig:
    """Host-side cost/performance parameters (paper Table III row)."""

    name: str
    alpha_h_dram: float       # normalized cost per host-DRAM die
    b_h_dram_die: float       # bandwidth per DRAM die (B/s)
    c_h_dram_die: float       # capacity per DRAM die (bytes)
    alpha_core: float         # normalized cost per core / SM
    iops_core: float          # sustainable IOPS per core / SM


CPU_DDR = HostConfig("CPU+DDR", alpha_h_dram=1.0, b_h_dram_die=3e9,
                     c_h_dram_die=3e9, alpha_core=4.0, iops_core=1e6)
GPU_GDDR = HostConfig("GPU+GDDR", alpha_h_dram=2.0, b_h_dram_die=80e9,
                      c_h_dram_die=2e9, alpha_core=3.0, iops_core=4e6)


def break_even_components(host: HostConfig, l_blk, ssd_cost, iops_ssd):
    """Per-term contributions to the break-even interval, in seconds.

    Returns dict with 'host', 'dram_bw', 'ssd' components; their sum is the
    calibrated break-even interval (Eq. 1).
    """
    l_blk = jnp.asarray(l_blk, dtype=jnp.float64)
    # $ per I/O for each resource
    c_host_io = host.alpha_core / host.iops_core
    c_dram_io = l_blk * host.alpha_h_dram / host.b_h_dram_die
    c_ssd_io = jnp.asarray(ssd_cost, jnp.float64) / jnp.asarray(
        iops_ssd, jnp.float64)
    # DRAM rent rate: $ per second to hold the block resident
    rent_rate = l_blk * host.alpha_h_dram / host.c_h_dram_die
    return {
        "host": c_host_io / rent_rate,
        "dram_bw": c_dram_io / rent_rate,
        "ssd": c_ssd_io / rent_rate,
    }


def break_even(host: HostConfig, l_blk, ssd_cost, iops_ssd):
    """Calibrated break-even interval tau_be (seconds), Eq. 1."""
    c = break_even_components(host, l_blk, ssd_cost, iops_ssd)
    return c["host"] + c["dram_bw"] + c["ssd"]


def break_even_for_ssd(host: HostConfig, ssd: SsdConfig, l_blk,
                       gamma_rw=9.0, phi_wa=3.0, iops_ssd=None):
    """Break-even using the first-principles device model for the SSD term.

    iops_ssd overrides the peak (e.g. a feasibility-capped usable IOPS from
    constraints.py).
    """
    if iops_ssd is None:
        iops_ssd = iops_ssd_peak(ssd, l_blk, gamma_rw, phi_wa)
    return break_even(host, l_blk, ssd.cost, iops_ssd)


def classical_break_even(l_blk, ssd_cost, iops_ssd, dram_cost_per_byte):
    """Gray's economics-only rule: T = C_ssd_io / C_dram_page.

    With host terms dropped and peak IOPS assumed, Eq. 1 reduces to this.
    dram_cost_per_byte is in the same normalized units as ssd_cost.
    """
    c_ssd_io = jnp.asarray(ssd_cost, jnp.float64) / jnp.asarray(
        iops_ssd, jnp.float64)
    c_dram_page = jnp.asarray(l_blk, jnp.float64) * dram_cost_per_byte
    return c_ssd_io / c_dram_page
