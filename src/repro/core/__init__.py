"""repro.core — the paper's contribution: a calibrated, constraint- and
workload-aware reformulation of the five-minute rule (RQ1-RQ3).

Analytics run in float64: enable x64 before any JAX op. Model/runtime code
elsewhere in the package is dtype-explicit (f32/bf16), so this is safe.
"""
import jax

jax.config.update("jax_enable_x64", True)

from . import units  # noqa: E402
from .ssd_model import (  # noqa: E402
    NandConfig, SsdConfig, SLC, PSLC, TLC, NAND_TYPES,
    storage_next_ssd, normal_ssd, iops_ssd_peak, iops_dev_peak,
    rw_fractions, gamma_from_mix, bottleneck,
)
from .economics import (  # noqa: E402
    HostConfig, CPU_DDR, GPU_GDDR, break_even, break_even_components,
    classical_break_even,
)
from .constraints import (  # noqa: E402
    mean_read_latency, tail_read_latency, rho_max_for_targets, usable_iops,
    LatencyTargets,
)
from .workload import (  # noqa: E402
    LogNormalWorkload, EmpiricalWorkload, thresholds, Thresholds,
)
from .platform import (  # noqa: E402
    PlatformConfig, CPU_PLATFORM, GPU_PLATFORM, analyze_platform,
    PlatformReport,
)
from .policy import TieringPolicy, Tier  # noqa: E402

__all__ = [
    "units", "NandConfig", "SsdConfig", "SLC", "PSLC", "TLC", "NAND_TYPES",
    "storage_next_ssd", "normal_ssd", "iops_ssd_peak", "iops_dev_peak",
    "rw_fractions", "gamma_from_mix", "bottleneck",
    "HostConfig", "CPU_DDR", "GPU_GDDR", "break_even",
    "break_even_components", "classical_break_even",
    "mean_read_latency", "tail_read_latency", "rho_max_for_targets",
    "usable_iops", "LatencyTargets",
    "LogNormalWorkload", "EmpiricalWorkload", "thresholds", "Thresholds",
    "PlatformConfig", "CPU_PLATFORM", "GPU_PLATFORM", "analyze_platform",
    "PlatformReport", "TieringPolicy", "Tier",
]
