"""Unit helpers and hardware constants used across the framework.

All cost terms are normalized to the cost of one NAND die (= 1.0), following
the paper's Table III normalization.  All times are seconds, sizes bytes,
rates per-second.
"""
from __future__ import annotations

# ---- sizes ----------------------------------------------------------------
KiB = 1024
MiB = 1024 * KiB
GiB = 1024 * MiB
TiB = 1024 * GiB

KB = 1000
MB = 1000 * KB
GB = 1000 * MB
TB = 1000 * GB

# ---- times ----------------------------------------------------------------
NS = 1e-9
US = 1e-6
MS = 1e-3

# ---- rates ----------------------------------------------------------------
M_IOPS = 1e6
G_IOPS = 1e9

# ---- TPU v5e-class roofline constants (target hardware; CPU is the host of
# record for the dry-run container) ------------------------------------------
TPU_PEAK_FLOPS_BF16 = 197e12   # per chip
TPU_HBM_BW = 819e9             # bytes/s per chip
TPU_ICI_BW = 50e9              # bytes/s per link (per direction)

SECONDS_PER_MINUTE = 60.0


def human_time(seconds: float) -> str:
    """Render a duration compactly (ns/us/ms/s/min)."""
    s = float(seconds)
    if s == float("inf"):
        return "inf"
    if s < 1e-6:
        return f"{s * 1e9:.1f}ns"
    if s < 1e-3:
        return f"{s * 1e6:.1f}us"
    if s < 1.0:
        return f"{s * 1e3:.1f}ms"
    if s < 120.0:
        return f"{s:.2f}s"
    return f"{s / 60.0:.1f}min"


def human_bytes(n: float) -> str:
    n = float(n)
    for unit, width in ((TiB, "TiB"), (GiB, "GiB"), (MiB, "MiB"), (KiB, "KiB")):
        if n >= unit:
            return f"{n / unit:.2f}{width}"
    return f"{n:.0f}B"


def human_rate(iops: float) -> str:
    iops = float(iops)
    if iops >= 1e9:
        return f"{iops / 1e9:.2f}G IOPS"
    if iops >= 1e6:
        return f"{iops / 1e6:.1f}M IOPS"
    if iops >= 1e3:
        return f"{iops / 1e3:.1f}K IOPS"
    return f"{iops:.0f} IOPS"
