"""Beyond-paper extension (paper §VIII "Device and cost modeling" and
"System integration and topology"): a total-cost-of-ownership break-even
that adds OpEx to Gray's CapEx-only rent, and the pairwise multi-tier
analysis the paper sketches for CXL-attached memory.

Units (everything amortized to rates):
  rent_rate [$/s]  = l_blk * (cost_per_byte / amort_s
                              + power_per_byte * $_per_joule)
  io_cost   [$]    = device_cost / (device_IOPS * amort_s)   (CapEx share)
                   + energy_per_io * $_per_joule             (OpEx share)
  tau_be    [s]    = io_cost / rent_rate

With power terms zeroed this reduces exactly to the paper's Eq. 1 SSD
term (the amortization cancels), so the CapEx-only results in
`economics.py` are the special case — validated in tests.

Pairwise ladder: apply the same break-even between each adjacent pair of
an ordered hierarchy (HBM, DRAM, CXL-DRAM, Storage-Next flash); fabric
tiers enter through their effective IOPS = 1/(latency + l/bw). The result
is a reuse-interval ladder generalizing `TieringPolicy` to N tiers.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from .ssd_model import SsdConfig, iops_ssd_peak

KWH_JOULES = 3.6e6
DEFAULT_POWER_COST = 0.10 / KWH_JOULES      # $ per joule ($0.10/kWh)
AMORT_SECONDS = 5 * 365 * 86400             # 5-year depreciation


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One memory/storage tier for the pairwise ladder. Costs are in the
    paper's normalized NAND-die units."""

    name: str
    cost_per_byte: float          # capital, per resident byte
    power_per_byte: float         # W per resident byte (refresh etc.)
    device_cost: float            # capital cost of the serving device
    device_iops: float            # attainable IOPS at l_blk
    energy_per_io: float          # J per access (dynamic)


def tco_break_even(l_blk: float, upper: TierSpec, lower: TierSpec,
                   host_cost_per_io: float = 0.0,
                   power_cost: float = DEFAULT_POWER_COST,
                   amort_s: float = AMORT_SECONDS) -> float:
    """Break-even reuse interval between an adjacent tier pair, with OpEx.

    `host_cost_per_io` carries the paper's host term ($ per IO, already
    amortized the same way) when the lower tier sits behind the I/O stack.
    """
    rent_rate = l_blk * (upper.cost_per_byte / amort_s
                         + upper.power_per_byte * power_cost)
    io_cost = (lower.device_cost / (lower.device_iops * amort_s)
               + host_cost_per_io
               + lower.energy_per_io * power_cost)
    return float(io_cost / rent_rate)


def tier_ladder(l_blk: float, tiers: Sequence[TierSpec],
                host_cost_per_io: float = 0.0,
                power_cost: float = DEFAULT_POWER_COST
                ) -> List[Tuple[str, float]]:
    """[(tier name, max reuse interval to stay in it)] for the hierarchy:
    an object with reuse interval tau lives in the first tier whose
    threshold exceeds tau."""
    out = []
    for hi, lo in zip(tiers[:-1], tiers[1:]):
        host = host_cost_per_io if lo.name.startswith("FLASH") else 0.0
        out.append((hi.name,
                    tco_break_even(l_blk, hi, lo, host,
                                   power_cost=power_cost)))
    out.append((tiers[-1].name, float("inf")))
    return out


def place(tau: float, ladder: List[Tuple[str, float]]) -> str:
    for name, thresh in ladder:
        if tau <= thresh:
            return name
    return ladder[-1][0]


# ---------------------------------------------------------------------------
# Reference 2025 hierarchy (normalized NAND-die units, Table III anchors)
# ---------------------------------------------------------------------------

def reference_tiers(ssd: SsdConfig, l_blk: int = 512,
                    cxl_latency: float = 400e-9,
                    cxl_bw: float = 64e9) -> List[TierSpec]:
    """HBM / DRAM / CXL-DRAM / Storage-Next-flash ladder.

    DRAM die: 1 unit per 3GB, ~1e9 IOPS at 512B (Table III);
    HBM: ~4x DRAM $/byte, higher bandwidth/lower energy per bit moved;
    CXL-DRAM: DRAM silicon + fabric premium, IOPS set by link physics;
    flash: the first-principles device model."""
    ssd_iops = float(iops_ssd_peak(ssd, l_blk, 9.0, 3.0))
    dram_cpb = 1.0 / 3e9
    cxl_iops = 1.0 / (cxl_latency + l_blk / cxl_bw)
    return [
        TierSpec("HBM", cost_per_byte=4 * dram_cpb, power_per_byte=1.2e-10,
                 device_cost=4.0, device_iops=5e9,
                 energy_per_io=l_blk * 3.5e-12),
        TierSpec("DRAM", cost_per_byte=dram_cpb, power_per_byte=1.0e-10,
                 device_cost=1.0, device_iops=1e9,
                 energy_per_io=l_blk * 8e-12),
        TierSpec("CXL-DRAM", cost_per_byte=1.3 * dram_cpb,
                 power_per_byte=1.0e-10, device_cost=1.3,
                 device_iops=cxl_iops, energy_per_io=l_blk * 15e-12),
        TierSpec("FLASH-SN", cost_per_byte=ssd.cost / ssd.total_nand_bytes,
                 power_per_byte=5e-12, device_cost=ssd.cost,
                 device_iops=ssd_iops, energy_per_io=8e-6),
    ]
