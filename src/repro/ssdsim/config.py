"""Simulator configuration — reuses the analytic model's SsdConfig so the
simulator and the closed-form model are parameterized identically (Table I).
"""
from __future__ import annotations

import dataclasses

from ..core.ssd_model import SsdConfig, storage_next_ssd


@dataclasses.dataclass(frozen=True)
class SimConfig:
    ssd: SsdConfig = dataclasses.field(default_factory=storage_next_ssd)
    l_blk: int = 512
    read_frac: float = 0.9          # host read fraction (90:10 -> 0.9)
    phi_wa: float = 3.0             # intra-SSD write amplification
    # --- ECC model (paper §VI) ---
    p_bch: float = 0.0              # per-read BCH decode failure probability
    ldpc_codeword: int = 4096       # outer LDPC spans 8 x 512B sectors
    ldpc_decode_time: float = 3e-6  # iterative decode latency on escalation
    # --- run control ---
    sca_lane: bool = False          # commands on a separate CA lane
    seed: int = 0

    @property
    def blocks_per_page(self) -> int:
        return max(1, self.ssd.nand.page_bytes // self.l_blk)

    @property
    def l_eff(self) -> int:
        """Internal read size (normal SSDs round up to the ECC codeword)."""
        return max(self.l_blk, self.ssd.min_access_bytes)

    @property
    def gamma_rw(self) -> float:
        if self.read_frac >= 1.0:
            return float("inf")
        return self.read_frac / (1.0 - self.read_frac)
