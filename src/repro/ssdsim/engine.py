"""Event-driven SSD simulator (MQSim-Next core, paper §VI).

Channel/die/plane-level discrete-event model with:
  * shared per-channel command+data bus (SCA: short tau_cmd),
  * per-plane sense occupancy (independent multi-plane reads) with cache
    registers (the plane frees at sense end; transfer streams from the
    register, giving explicit transfer/sense overlap),
  * read-prioritized, plane-aware arbitration (ready host transfers first,
    then host read commands to free planes, then GC transfers, then host
    programs, then GC),
  * page-coalesced writes: the controller fills a per-plane buffer of
    blocks_per_page host blocks and commits them with one program,
  * page-granular GC: each host program spawns (phi_wa - 1) internal page
    reads, each followed by an internal program,
  * two-layer ECC: host reads escalate with probability p_bch to a full
    LDPC codeword transfer plus decode latency.

The model is intentionally parameterized identically to the closed-form
model in repro.core.ssd_model so the two can be compared (paper Fig. 7).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from .config import SimConfig


@dataclasses.dataclass
class SimResult:
    iops: float
    makespan: float
    n_ops: int
    n_reads: int
    n_writes: int
    mean_read_latency: float
    p99_read_latency: float
    bus_utilization: float          # mean across channels
    n_bch_escalations: int
    n_gc_reads: int
    n_gc_programs: int

    def __str__(self):
        return (f"SimResult(iops={self.iops/1e6:.2f}M, "
                f"mean_lat={self.mean_read_latency*1e6:.2f}us, "
                f"p99={self.p99_read_latency*1e6:.2f}us, "
                f"bus_util={self.bus_utilization:.2f})")


# event kinds (ordering tie-break by sequence number)
_ARR, _BUSFREE, _SENSE, _GCSENSE, _PROGDONE, _GCPROGDONE = range(6)


class _Channel:
    """Per-channel scheduler state."""

    __slots__ = ("bus_free", "ca_free", "busy_acc", "ready_xfer",
                 "gc_ready_xfer", "plane_free", "read_q", "pending_planes",
                 "wbuf", "full_progs", "gc_reads", "gc_progs", "gc_debt",
                 "rr_plane", "plane_keys")

    def __init__(self, n_dies: int, n_planes: int):
        self.bus_free = 0.0
        self.ca_free = 0.0
        self.busy_acc = 0.0
        self.ready_xfer: deque = deque()       # host reads sensed, await bus
        self.gc_ready_xfer: deque = deque()    # GC page reads sensed
        self.plane_keys: List[Tuple[int, int]] = [
            (d, p) for d in range(n_dies) for p in range(n_planes)]
        self.plane_free: Dict[Tuple[int, int], float] = {
            k: 0.0 for k in self.plane_keys}
        self.read_q: Dict[Tuple[int, int], deque] = {
            k: deque() for k in self.plane_keys}
        self.pending_planes: deque = deque()   # plane keys with queued reads
        self.wbuf: Dict[Tuple[int, int], int] = {
            k: 0 for k in self.plane_keys}
        self.full_progs: deque = deque()       # (plane_key, n_blocks)
        self.gc_reads = 0
        self.gc_progs = 0
        self.gc_debt = 0.0
        self.rr_plane = 0


class Simulator:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        ssd = cfg.ssd
        self.n_ch = ssd.n_ch
        self.n_dies = ssd.n_nand
        self.n_planes = ssd.nand.n_plane
        self.tau_cmd = ssd.tau_cmd
        # With SCA the short command/address bursts can optionally ride a
        # separate lane (sca_lane=True). The paper's analytic model charges
        # tau_CMD on the channel (Eq. IOPS_CH), and its Fig. 7c channel-bw
        # scaling matches that accounting, so the default keeps commands on
        # the shared bus and sca_lane is an explicit what-if knob.
        self.sca = bool(getattr(cfg, "sca_lane", False))
        self.tau_sense = ssd.nand.tau_sense
        self.tau_prog = ssd.nand.tau_prog
        self.b_ch = ssd.b_ch
        self.page = ssd.nand.page_bytes
        self.P = cfg.blocks_per_page
        self.l_eff = cfg.l_eff
        self.rng = np.random.default_rng(cfg.seed)
        self.channels = [_Channel(self.n_dies, self.n_planes)
                         for _ in range(self.n_ch)]
        self.events: list = []
        self._seq = itertools.count()
        # stats
        self.read_lat: List[float] = []
        self.completions = 0
        self.last_completion = 0.0
        self.t_end = 0.0               # end of ALL work incl. GC drain
        self.n_reads = self.n_writes = 0
        self.n_bch = 0
        self.n_gc_reads = self.n_gc_progs = 0
        self._wr_rr = 0  # round-robin pointer for write placement
        self._arrivals_left = 0
        # closed-loop mode: inject a replacement op on each completion
        self._closed_remaining = 0
        self.completion_times: List[float] = []

    # ------------------------------------------------------------------ util
    def _push(self, t: float, kind: int, ch: int, a=0, b=0.0):
        heapq.heappush(self.events, (t, next(self._seq), kind, ch, a, b))

    def _xfer_time(self, nbytes: float) -> float:
        return nbytes / self.b_ch

    # ------------------------------------------------------------- workload
    def load(self, arrival_times: np.ndarray, is_read: np.ndarray):
        """Queue a host op stream. Writes are placed round-robin."""
        assert len(arrival_times) == len(is_read)
        self._arrivals_left = len(arrival_times)
        read_ch = self.rng.integers(0, self.n_ch, size=len(is_read))
        read_die = self.rng.integers(0, self.n_dies, size=len(is_read))
        read_pl = self.rng.integers(0, self.n_planes, size=len(is_read))
        for i, (t, rd) in enumerate(zip(arrival_times, is_read)):
            self._push(float(t), _ARR, int(read_ch[i]), int(rd),
                       float(read_die[i] * self.n_planes + read_pl[i]))

    def load_closed_loop(self, n_ops: int, queue_depth: int = 4096):
        """Closed-system saturation: `queue_depth` ops outstanding; each
        completion injects a fresh op, keeping the read/write mix stationary
        (no phase separation between the read and write/GC streams)."""
        qd = min(queue_depth, n_ops)
        self._closed_remaining = n_ops - qd
        self._arrivals_left = n_ops
        for _ in range(qd):
            self._inject(0.0)

    def _inject(self, t: float):
        rd = int(self.rng.random() < self.cfg.read_frac)
        ch = int(self.rng.integers(0, self.n_ch))
        plane_idx = float(self.rng.integers(0, self.n_dies * self.n_planes))
        self._push(t, _ARR, ch, rd, plane_idx)

    def _maybe_refill(self, t: float, n: int = 1):
        for _ in range(n):
            if self._closed_remaining > 0:
                self._closed_remaining -= 1
                self._inject(t)

    # ------------------------------------------------------------- schedule
    def _schedule(self, ch_id: int, t: float):
        """Advance both channel lanes (read-prioritized).

        With SCA, read commands issue on the CA lane concurrently with data
        transfers; on conventional devices every action serializes on the
        shared bus (ca_free is aliased to bus_free)."""
        ch = self.channels[ch_id]
        self._schedule_ca(ch, ch_id, t)
        self._schedule_data(ch, ch_id, t)

    def _schedule_ca(self, ch: _Channel, ch_id: int, t: float):
        """Command/address issue: host read commands, then GC reads."""
        lane_free = ch.ca_free if self.sca else ch.bus_free
        if lane_free > t + 1e-15:
            return
        start = max(lane_free, t)
        key = self._pick_pending_read_plane(ch, start)
        if key is not None:
            arr_t = ch.read_q[key].popleft()
            if ch.read_q[key]:
                ch.pending_planes.append(key)
            end = start + self.tau_cmd
            self._finish_ca(ch, ch_id, start, end)
            sense_done = end + self.tau_sense
            ch.plane_free[key] = sense_done
            self._push(sense_done, _SENSE, ch_id, 0, arr_t)
            return
        if ch.gc_reads > 0:
            key = self._any_free_plane(ch, start)
            if key is not None:
                ch.gc_reads -= 1
                end = start + self.tau_cmd
                self._finish_ca(ch, ch_id, start, end)
                sense_done = end + self.tau_sense
                ch.plane_free[key] = sense_done
                self._push(sense_done, _GCSENSE, ch_id, 0, 0.0)

    def _schedule_data(self, ch: _Channel, ch_id: int, t: float):
        """Data-bus actions: read transfers first, then programs — unless
        the program backlog exceeds one page per plane, in which case
        writes preempt (bounded write buffer, as in real controllers;
        without this, strict read priority defers writes indefinitely
        under closed-loop saturation and overstates mixed-workload IOPS).
        """
        if ch.bus_free > t + 1e-15:
            return
        start = max(ch.bus_free, t)

        backlog = len(ch.full_progs) + ch.gc_progs
        if backlog > len(ch.plane_keys):
            cmd = 0.0 if self.sca else self.tau_cmd
            prog = self._pick_program(ch, start)
            if prog is not None:
                key, n_blocks = prog
                end = start + cmd + self._xfer_time(self.page)
                self._finish_bus(ch, ch_id, start, end)
                prog_done = end + self.tau_prog
                ch.plane_free[key] = prog_done
                self._push(prog_done, _PROGDONE, ch_id, n_blocks, 0.0)
                return
            if ch.gc_progs > 0:
                key = self._any_free_plane(ch, start)
                if key is not None:
                    ch.gc_progs -= 1
                    end = start + cmd + self._xfer_time(self.page)
                    self._finish_bus(ch, ch_id, start, end)
                    prog_done = end + self.tau_prog
                    ch.plane_free[key] = prog_done
                    self._push(prog_done, _GCPROGDONE, ch_id, 0, 0.0)
                    return

        # 1. host read data transfer (sense already done)
        if ch.ready_xfer:
            arr_t, = (ch.ready_xfer.popleft(),)
            nbytes = self.l_eff
            extra = 0.0
            if self.cfg.p_bch > 0 and self.rng.random() < self.cfg.p_bch:
                nbytes = max(nbytes, self.cfg.ldpc_codeword)
                extra = self.cfg.ldpc_decode_time
                self.n_bch += 1
            end = start + self._xfer_time(nbytes)
            self._finish_bus(ch, ch_id, start, end)
            done = end + extra
            self.read_lat.append(done - arr_t)
            self._complete(done)
            return

        # 2. GC page-read transfer
        if ch.gc_ready_xfer:
            ch.gc_ready_xfer.popleft()
            end = start + self._xfer_time(self.page)
            self._finish_bus(ch, ch_id, start, end)
            ch.gc_progs += 1
            self.n_gc_progs += 1
            return

        # 3. host program for a coalesced page on a free plane
        cmd = 0.0 if self.sca else self.tau_cmd
        prog = self._pick_program(ch, start)
        if prog is not None:
            key, n_blocks = prog
            end = start + cmd + self._xfer_time(self.page)
            self._finish_bus(ch, ch_id, start, end)
            prog_done = end + self.tau_prog
            ch.plane_free[key] = prog_done
            self._push(prog_done, _PROGDONE, ch_id, n_blocks, 0.0)
            return

        # 4. GC program to a free plane
        if ch.gc_progs > 0:
            key = self._any_free_plane(ch, start)
            if key is not None:
                ch.gc_progs -= 1
                end = start + cmd + self._xfer_time(self.page)
                self._finish_bus(ch, ch_id, start, end)
                prog_done = end + self.tau_prog
                ch.plane_free[key] = prog_done
                self._push(prog_done, _GCPROGDONE, ch_id, 0, 0.0)
                return

    def _finish_ca(self, ch: _Channel, ch_id: int, start: float,
                   end: float):
        if self.sca:
            ch.ca_free = end
        else:
            ch.bus_free = end
            ch.busy_acc += end - start
        self.t_end = max(self.t_end, end)
        self._push(end, _BUSFREE, ch_id)

    def _finish_bus(self, ch: _Channel, ch_id: int, start: float, end: float):
        ch.bus_free = end
        ch.busy_acc += end - start
        self.t_end = max(self.t_end, end)
        self._push(end, _BUSFREE, ch_id)

    def _pick_pending_read_plane(self, ch: _Channel, t: float):
        """First queued-read plane that is free; rotates for fairness."""
        for _ in range(len(ch.pending_planes)):
            key = ch.pending_planes.popleft()
            if not ch.read_q[key]:
                continue                      # stale entry, drop
            if ch.plane_free[key] <= t + 1e-15:
                return key
            ch.pending_planes.append(key)
        return None

    def _pick_program(self, ch: _Channel, t: float):
        for _ in range(len(ch.full_progs)):
            key, n = ch.full_progs.popleft()
            if ch.plane_free[key] <= t + 1e-15:
                return key, n
            ch.full_progs.append((key, n))
        return None

    def _any_free_plane(self, ch: _Channel, t: float):
        n = len(ch.plane_keys)
        for i in range(n):
            key = ch.plane_keys[(ch.rr_plane + i) % n]
            if ch.plane_free[key] <= t + 1e-15:
                ch.rr_plane = (ch.rr_plane + i + 1) % n
                return key
        return None

    def _complete(self, t: float):
        self.completions += 1
        self.completion_times.append(t)
        self.last_completion = max(self.last_completion, t)
        self._maybe_refill(t)

    # ----------------------------------------------------------------- run
    def run(self) -> SimResult:
        cfg = self.cfg
        events = self.events
        while events:
            t, _, kind, ch_id, a, b = heapq.heappop(events)
            self.t_end = max(self.t_end, t)
            ch = self.channels[ch_id]
            if kind == _ARR:
                self._arrivals_left -= 1
                if a:  # read
                    self.n_reads += 1
                    key = ch.plane_keys[int(b)]
                    ch.read_q[key].append(t)
                    if len(ch.read_q[key]) == 1:
                        ch.pending_planes.append(key)
                else:   # write: round-robin plane placement, page coalescing
                    self.n_writes += 1
                    wch = self.channels[self._wr_rr % self.n_ch]
                    wch_id = self._wr_rr % self.n_ch
                    self._wr_rr += 1
                    key = wch.plane_keys[
                        (self._wr_rr // self.n_ch) % len(wch.plane_keys)]
                    wch.wbuf[key] += 1
                    if wch.wbuf[key] >= self.P:
                        wch.full_progs.append((key, wch.wbuf[key]))
                        wch.wbuf[key] = 0
                    if wch_id != ch_id:
                        self._schedule(wch_id, t)
                if self._arrivals_left == 0:
                    self._flush_partial_pages()
                self._schedule(ch_id, t)
            elif kind == _BUSFREE:
                self._schedule(ch_id, t)
            elif kind == _SENSE:
                ch.ready_xfer.append(b)      # b = arrival time
                self._schedule(ch_id, t)
            elif kind == _GCSENSE:
                ch.gc_ready_xfer.append(t)
                self._schedule(ch_id, t)
            elif kind == _PROGDONE:
                # a = host blocks committed by this program
                for _ in range(int(a)):
                    self._complete(t)
                # spawn GC debt: (phi_wa - 1) page moves per host page
                ch.gc_debt += (cfg.phi_wa - 1.0) * (int(a) / self.P)
                while ch.gc_debt >= 1.0:
                    ch.gc_debt -= 1.0
                    ch.gc_reads += 1
                    self.n_gc_reads += 1
                self._schedule(ch_id, t)
            elif kind == _GCPROGDONE:
                self._schedule(ch_id, t)

        # Throughput is measured over the steady-state window (10th..90th
        # completion percentile): the saturation preload starts with cold
        # write buffers / no GC backlog and ends with a GC drain tail, and
        # both transients dilute the whole-makespan rate.
        makespan = max(self.t_end, self.last_completion, 1e-12)
        lat = np.asarray(self.read_lat) if self.read_lat else np.zeros(1)
        util = float(np.mean([c.busy_acc for c in self.channels])) / makespan
        n_ops = self.n_reads + self.n_writes
        ct = np.sort(np.asarray(self.completion_times))
        if len(ct) >= 100:
            lo, hi = int(0.1 * len(ct)), int(0.9 * len(ct))
            window = max(ct[hi - 1] - ct[lo], 1e-12)
            steady_iops = (hi - lo) / window
        else:
            steady_iops = self.completions / makespan
        return SimResult(
            iops=steady_iops, makespan=makespan,
            n_ops=n_ops, n_reads=self.n_reads, n_writes=self.n_writes,
            mean_read_latency=float(lat.mean()),
            p99_read_latency=float(np.percentile(lat, 99)),
            bus_utilization=util, n_bch_escalations=self.n_bch,
            n_gc_reads=self.n_gc_reads, n_gc_programs=self.n_gc_progs)

    def _flush_partial_pages(self):
        for ch in self.channels:
            for key, n in ch.wbuf.items():
                if n > 0:
                    ch.full_progs.append((key, n))
                    ch.wbuf[key] = 0


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def simulate(cfg: SimConfig, arrival_times: np.ndarray,
             is_read: np.ndarray) -> SimResult:
    sim = Simulator(cfg)
    sim.load(arrival_times, is_read)
    return sim.run()


def simulate_peak_iops(cfg: SimConfig, n_ops: int = 60_000,
                       queue_depth: int = 4096) -> SimResult:
    """Saturation throughput via a closed system: `queue_depth` ops stay
    outstanding and every completion injects a replacement, keeping the
    read/write mix stationary (an all-at-t=0 preload phase-separates reads
    from writes under the read-prioritized scheduler and misstates the
    mix sensitivity)."""
    sim = Simulator(cfg)
    sim.load_closed_loop(n_ops, queue_depth)
    return sim.run()


def simulate_latency(cfg: SimConfig, rho: float, n_ops: int = 40_000,
                     peak_iops: Optional[float] = None) -> SimResult:
    """Open-loop Poisson arrivals at rho x peak (M/D/1 validation, §IV)."""
    if peak_iops is None:
        peak_iops = simulate_peak_iops(cfg, n_ops=min(n_ops, 40_000)).iops
    rate = rho * peak_iops
    rng = np.random.default_rng(cfg.seed + 2)
    gaps = rng.exponential(1.0 / rate, size=n_ops)
    arrivals = np.cumsum(gaps)
    is_read = rng.random(n_ops) < cfg.read_frac
    return simulate(cfg, arrivals, is_read)
