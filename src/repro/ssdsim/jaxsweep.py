"""Vectorized analytic sweeps (jax.vmap) over the first-principles model —
used by the sensitivity benchmarks to sweep large parameter grids cheaply
and by tests to cross-check the event simulator trends.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.ssd_model import SsdConfig, iops_ssd_peak


def analytic_iops_grid(cfg: SsdConfig, l_blks: Sequence[int],
                       gammas: Sequence[float], phi_wa: float = 3.0):
    """IOPS over the (block size x read:write ratio) grid.

    Returns array of shape (len(l_blks), len(gammas)).
    """
    ls = jnp.asarray(l_blks, jnp.float64)
    gs = jnp.asarray(gammas, jnp.float64)

    def one(l, g):
        return iops_ssd_peak(cfg, l, g, phi_wa)

    return jax.vmap(lambda l: jax.vmap(lambda g: one(l, g))(gs))(ls)


def analytic_channel_bw_sweep(cfg: SsdConfig, l_blk: int,
                              bws: Sequence[float], gamma: float = 9.0,
                              phi_wa: float = 3.0):
    """IOPS as channel bandwidth scales (paper Fig. 7c trend)."""
    out = []
    for bw in bws:
        c = dataclasses.replace(cfg, b_ch=float(bw))
        out.append(float(iops_ssd_peak(c, l_blk, gamma, phi_wa)))
    return jnp.asarray(out)
