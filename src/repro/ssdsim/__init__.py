"""MQSim-Next — a calibrated Storage-Next SSD simulator (paper §VI).

Re-implements the mechanisms the paper adds on top of MQSim:
  * SCA command/address timing on the NAND channel (short tau_cmd),
  * independent multi-plane reads (per-plane sense occupancy),
  * explicit transfer/sense overlap (bus free while arrays sense),
  * read-prioritized, plane-aware channel arbitration,
  * two-layer ECC: per-512B BCH fast path, p_BCH escalation to a full
    4KB LDPC decode (extra transfer + decode latency),
  * page-granular GC traffic at write-amplification Phi_WA (page-level GC
    is slightly cheaper than the analytic model's block-level accounting,
    so simulated IOPS sits a few percent above the model — same relation
    the paper reports in Fig. 7a).

`simulate_peak_iops` saturates the device (closed preload) to measure peak
throughput; `simulate_latency` drives open-loop Poisson arrivals to measure
mean/percentile read latency for the M/D/1 validation.
"""
from .config import SimConfig
from .engine import SimResult, simulate, simulate_peak_iops, simulate_latency
from .jaxsweep import analytic_iops_grid

__all__ = ["SimConfig", "SimResult", "simulate", "simulate_peak_iops",
           "simulate_latency", "analytic_iops_grid"]
