"""Deterministic observability plane on the `VirtualClock`.

Three parts, one holder:

  * `Tracer` (`obs.trace`) — causally-linked spans + policy-decision
    instants, exported as byte-stable Perfetto/Chrome `trace_event`
    JSON and a folded-stack flamegraph of modeled time.
  * `MetricsRegistry` (`obs.metrics`) — array-backed counters / gauges
    / log-bucket histograms with per-host and per-tenant labels, plus
    the fleet-wide `snapshot_stats()/reset_stats()` component registry.
  * `StallLedger` (`obs.ledger`) — every modeled stalled second
    attributed to exactly one Eq. 1 component, with a conservation
    invariant against the scheduler's `per_token_stall`.

`Observability` bundles the three so one object threads through the
stack (`HierarchySpec.observability` -> `Platform.compile` ->
`ShardedTieredStore` -> per-host runtimes -> scheduler). The ledger is
always present (plain float adds — the conservation law holds on every
run); tracing and metrics are opt-in/opt-out knobs.
"""
from __future__ import annotations

from typing import Optional

from .jsonio import bench_json, canon, write_bench_json
from .ledger import COMPONENTS, StallLedger, tenant_of_key
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Tracer

__all__ = [
    "COMPONENTS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Observability", "StallLedger", "Tracer", "bench_json", "canon",
    "tenant_of_key", "write_bench_json",
]


class Observability:
    """tracer (optional) + metrics (optional) + ledger (always)."""

    def __init__(self, trace: bool = False, metrics: bool = True,
                 max_events: int = 200_000):
        self.tracer: Optional[Tracer] = (
            Tracer(max_events=max_events) if trace else None)
        self.metrics: Optional[MetricsRegistry] = (
            MetricsRegistry() if metrics else None)
        self.ledger = StallLedger()
        if self.metrics is not None:
            self.metrics.register("stall_ledger", self.ledger)

    def snapshot_stats(self) -> dict:
        if self.metrics is not None:
            return self.metrics.snapshot()
        return {"components": {
            "stall_ledger": self.ledger.snapshot_stats()}}

    def reset_stats(self) -> None:
        """Fleet-wide reset through the registry — every registered
        component, the metrics arrays, and the ledger in one sweep."""
        if self.metrics is not None:
            self.metrics.reset()      # includes the ledger (registered)
        else:
            self.ledger.reset_stats()
