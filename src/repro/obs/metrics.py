"""MetricsRegistry — array-backed counters/gauges/histograms plus the
fleet-wide snapshot/reset protocol.

Two jobs in one module because they share a failure mode:

  1. **Metrics.** Counters, gauges and log-bucket histograms with
     per-host / per-tenant label tuples. Storage follows the
     `_ArrayGhost` idiom from `autopilot/reuse.py`: the label -> row
     map is a Python dict, the values live in flat numpy arrays that
     grow by doubling, and histograms take *batch* observes (one
     vectorized bucketize + `np.add.at` per step). That is what lets
     the registry stay on during the 1M-key `serving_scale.py` replay
     instead of being a benchmark-off switch.

  2. **Component registration.** Before this module the fleet had four
     divergent ad-hoc stats resets (`TieredStore.reset_stats`,
     `AsyncTierRuntime.reset_stats`, `ShardedTieredStore.reset_stats`,
     `Platform.reset_stats`) and a fleet-wide reset silently skipped
     whichever component forgot to chain. Components now register here
     with a uniform ``snapshot_stats()/reset_stats()`` pair;
     `registry.reset()` walks every registered component, so nothing
     can be skipped, and `registry.snapshot()` is the one place to ask
     "what does the whole stack's bookkeeping say right now".
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

Label = Tuple[str, ...]


def _as_label(label: Union[str, Sequence[str], None]) -> Label:
    if label is None:
        return ()
    if isinstance(label, str):
        return (label,)
    return tuple(str(x) for x in label)


class _Labeled:
    """Shared label -> row machinery (the `_ArrayGhost` idiom: dict for
    hashing, flat arrays for the values)."""

    def __init__(self, name: str, width: int = 1):
        self.name = name
        self._width = width
        cap0 = 8
        self._vals = np.zeros((cap0, width), np.float64)
        self._row: Dict[Label, int] = {}

    def _rowof(self, label: Label) -> int:
        r = self._row.get(label)
        if r is None:
            r = len(self._row)
            if r >= self._vals.shape[0]:
                self._vals = np.concatenate(
                    [self._vals, np.zeros_like(self._vals)])
            self._row[label] = r
        return r

    def labels(self) -> List[Label]:
        return sorted(self._row)

    def reset(self) -> None:
        self._vals[:] = 0.0


class Counter(_Labeled):
    """Monotone per-label accumulator."""

    def inc(self, label=None, v: float = 1.0) -> None:
        # resolve the row BEFORE indexing: _rowof may grow (replace)
        # self._vals, and `self._vals[...] += v` binds the old array
        # before the call
        r = self._rowof(_as_label(label))
        self._vals[r, 0] += v

    def value(self, label=None) -> float:
        r = self._row.get(_as_label(label))
        return 0.0 if r is None else float(self._vals[r, 0])

    def as_dict(self) -> Dict[str, float]:
        return {"/".join(lb) if lb else "": float(self._vals[r, 0])
                for lb, r in sorted(self._row.items())}


class Gauge(Counter):
    """Last-write-wins per-label value."""

    def set(self, label=None, v: float = 0.0) -> None:
        r = self._rowof(_as_label(label))      # may grow self._vals
        self._vals[r, 0] = v

    inc = Counter.inc    # gauges may also accumulate (e.g. occupancy)


class Histogram(_Labeled):
    """Log-bucket histogram: bucket b covers
    [tau0 * 2^b, tau0 * 2^(b+1)), bucket 0 also absorbs everything
    below tau0 (and exact zeros). One row of bucket counts per label;
    `observe_batch` is a single digitize + `np.add.at`."""

    def __init__(self, name: str, n_buckets: int = 32,
                 tau0: float = 1e-6):
        super().__init__(name, width=n_buckets)
        self.n_buckets = int(n_buckets)
        self.tau0 = float(tau0)
        self._count = Counter(name + "_count")
        self._sum = Counter(name + "_sum")

    def _bucketize(self, vals: np.ndarray) -> np.ndarray:
        with np.errstate(divide="ignore"):
            b = np.floor(np.log2(np.maximum(vals, 0.0) / self.tau0))
        return np.clip(np.where(np.isfinite(b), b, 0), 0,
                       self.n_buckets - 1).astype(np.int64)

    def observe(self, v: float, label=None) -> None:
        self.observe_batch(np.asarray([v], np.float64), label)

    def observe_batch(self, vals, label=None) -> None:
        vals = np.asarray(vals, np.float64)
        if vals.size == 0:
            return
        r = self._rowof(_as_label(label))
        np.add.at(self._vals[r], self._bucketize(vals), 1.0)
        self._count.inc(label, float(vals.size))
        self._sum.inc(label, float(vals.sum()))

    def count(self, label=None) -> float:
        return self._count.value(label)

    def sum(self, label=None) -> float:
        return self._sum.value(label)

    def quantile(self, q: float, label=None) -> Optional[float]:
        """Bucket-center quantile (same scheme as the reuse sketch);
        None when the label has no observations."""
        r = self._row.get(_as_label(label))
        if r is None:
            return None
        row = self._vals[r]
        total = float(row.sum())
        if total <= 0.0:
            return None
        cum = np.cumsum(row)
        b = int(np.searchsorted(cum, q * total, side="left"))
        return float(self.tau0 * 2.0 ** (min(b, self.n_buckets - 1)
                                         + 0.5))

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for lb, r in sorted(self._row.items()):
            key = "/".join(lb) if lb else ""
            out[key] = {"count": self._count.value(lb),
                        "sum": self._sum.value(lb),
                        "p50": self.quantile(0.5, lb) or 0.0,
                        "p99": self.quantile(0.99, lb) or 0.0}
        return out

    def reset(self) -> None:
        super().reset()
        self._count.reset()
        self._sum.reset()


class MetricsRegistry:
    """Named metrics + registered stats-bearing components, one
    `snapshot()`/`reset()` for the whole stack."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._components: Dict[str, object] = {}

    # -------------------------------------------------------------- metrics
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, n_buckets: int = 32,
                  tau0: float = 1e-6) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(name, n_buckets, tau0)
        return h

    # ----------------------------------------------------------- components
    def register(self, name: str, component) -> None:
        """Register a stats-bearing component. The component must
        implement the protocol — registering is what guarantees a
        fleet-wide reset cannot silently skip it."""
        for attr in ("snapshot_stats", "reset_stats"):
            if not callable(getattr(component, attr, None)):
                raise TypeError(
                    f"component {name!r} lacks {attr}(); the "
                    f"snapshot/reset protocol requires both "
                    f"snapshot_stats() and reset_stats()")
        self._components[name] = component

    def components(self) -> List[str]:
        return sorted(self._components)

    # --------------------------------------------------------- fleet sweeps
    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "counters": {n: c.as_dict()
                         for n, c in sorted(self._counters.items())},
            "gauges": {n: g.as_dict()
                       for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.as_dict()
                           for n, h in sorted(self._hists.items())},
        }
        out["components"] = {
            n: comp.snapshot_stats()
            for n, comp in sorted(self._components.items())}
        return out

    def reset(self) -> None:
        for c in self._counters.values():
            c.reset()
        for g in self._gauges.values():
            g.reset()
        for h in self._hists.values():
            h.reset()
        for comp in self._components.values():
            comp.reset_stats()
