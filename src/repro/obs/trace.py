"""Deterministic causal tracer with Perfetto/Chrome export.

Records the modeled request lifecycle as `trace_event` JSON that the
Perfetto UI (https://ui.perfetto.dev) opens directly: "X" complete
events for spans whose duration is known at record time (every modeled
transfer knows its `done_t` the moment it is submitted — so spans are
recorded *at submit*, with explicit ts/dur, rather than via begin/end
pairs), "i" instants for policy decisions (gate admit/price-out,
autoscaler add/remove, host failure, deadline misses), and "s"/"f"
flow events stitching a session's admission to the fetches and resume
that served it.

Determinism contract: timestamps come off the `VirtualClock` (modeled
seconds -> microseconds), pids/tids are assigned in first-registration
order from deterministic component labels, flow ids from a monotone
counter keyed by session id, and the export canonicalizes floats the
same way `obs.jsonio` does — so a double run under the same spec JSON
and seed produces a byte-identical trace file, which CI diffs.

The tracer is bounded: past `max_events` new events are dropped (and
counted), never resized — a trace of a 1M-key replay should truncate,
not OOM.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

from .jsonio import canon

_US = 1e6    # modeled seconds -> trace microseconds


class Tracer:
    def __init__(self, max_events: int = 200_000):
        self.max_events = int(max_events)
        self.dropped = 0
        self._events: List[dict] = []
        self._pids: Dict[str, int] = {}
        self._tids: Dict[Tuple[int, str], int] = {}
        self._flow_ids: Dict[object, int] = {}

    # -------------------------------------------------------------- tracks
    def track(self, process: str, thread: str = "main") -> Tuple[int, int]:
        """(pid, tid) for a component track, assigned deterministically
        in first-registration order; emits the Perfetto name metadata
        on first sight so the UI shows labels, not numbers."""
        pid = self._pids.get(process)
        if pid is None:
            pid = self._pids[process] = len(self._pids) + 1
            self._meta(pid, 0, "process_name", {"name": process})
        tid = self._tids.get((pid, thread))
        if tid is None:
            tid = self._tids[(pid, thread)] = (
                len([1 for (p, _) in self._tids if p == pid]) + 1)
            self._meta(pid, tid, "thread_name", {"name": thread})
        return pid, tid

    def _meta(self, pid: int, tid: int, name: str, args: dict) -> None:
        # metadata events bypass the max_events bound (they are O(tracks))
        self._events.append({"ph": "M", "pid": pid, "tid": tid,
                             "name": name, "args": args})

    def _emit(self, ev: dict) -> bool:
        if len(self._events) >= self.max_events:
            self.dropped += 1
            return False
        self._events.append(ev)
        return True

    # -------------------------------------------------------------- events
    def complete(self, track: Tuple[int, int], name: str, ts: float,
                 dur: float, cat: str = "", args: Optional[dict] = None
                 ) -> None:
        """A span with explicit start + duration (modeled seconds)."""
        pid, tid = track
        ev = {"ph": "X", "pid": pid, "tid": tid, "name": name,
              "ts": ts * _US, "dur": max(dur, 0.0) * _US}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, track: Tuple[int, int], name: str, ts: float,
                args: Optional[dict] = None, cat: str = "") -> None:
        pid, tid = track
        ev = {"ph": "i", "pid": pid, "tid": tid, "name": name,
              "ts": ts * _US, "s": "t"}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self._emit(ev)

    # --------------------------------------------------------------- flows
    def flow_id(self, key) -> int:
        """Deterministic flow id for a causal chain (e.g. a session)."""
        fid = self._flow_ids.get(key)
        if fid is None:
            fid = self._flow_ids[key] = len(self._flow_ids) + 1
        return fid

    def _flow(self, ph: str, track: Tuple[int, int], name: str,
              ts: float, key) -> None:
        pid, tid = track
        ev = {"ph": ph, "pid": pid, "tid": tid, "name": name,
              "ts": ts * _US, "id": self.flow_id(key), "cat": "flow"}
        if ph == "f":
            ev["bp"] = "e"
        self._emit(ev)

    def flow_start(self, track, name, ts, key) -> None:
        self._flow("s", track, name, ts, key)

    def flow_step(self, track, name, ts, key) -> None:
        self._flow("t", track, name, ts, key)

    def flow_end(self, track, name, ts, key) -> None:
        self._flow("f", track, name, ts, key)

    # ------------------------------------------------------------- exports
    def to_chrome_json(self) -> str:
        """Byte-stable Chrome `trace_event` JSON (load in Perfetto or
        chrome://tracing). Events stay in record order — stable because
        recording order is itself deterministic."""
        doc = {"traceEvents": canon(self._events),
               "displayTimeUnit": "ms",
               "otherData": {"dropped_events": self.dropped}}
        return json.dumps(doc, sort_keys=True, indent=1)

    def flamegraph(self) -> str:
        """Folded-stacks text of modeled time: one line per
        `process;thread;name` with total microseconds of span time —
        feed to any flamegraph renderer, or read directly as a sorted
        where-did-modeled-time-go table."""
        names_pid = {v: k for k, v in self._pids.items()}
        names_tid = {(p, t): n for (p, n), t in self._tids.items()}
        agg: Dict[str, float] = {}
        for ev in self._events:
            if ev.get("ph") != "X":
                continue
            proc = names_pid.get(ev["pid"], str(ev["pid"]))
            thr = names_tid.get((ev["pid"], ev["tid"]), str(ev["tid"]))
            stack = f"{proc};{thr};{ev['name']}"
            agg[stack] = agg.get(stack, 0.0) + ev["dur"]
        lines = [f"{stack} {int(round(us))}"
                 for stack, us in sorted(agg.items())]
        return "\n".join(lines)

    def __len__(self) -> int:
        return len(self._events)
