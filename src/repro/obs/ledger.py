"""The Eq. 1 stall ledger: every modeled stalled second, attributed.

The paper's five-minute-rule revisit is an *attribution* argument — it
prices the DRAM-vs-flash decision by splitting each second of engine
time into named components (SSD service, queueing, stalled-engine
rent). The simulator models those seconds but until now only summed
them (`TierStats.stall_time`, `kv_stall_time`); a regression shows up
as "stall went up" with no way to say which queue it came from.

`StallLedger` closes that: every stalled second materialized by
`AsyncTierRuntime.wait` lands in exactly one component, and the
scheduler adds idle-slot time under the identical condition it counts
`slot_idle_steps`, so the ledger obeys a conservation law that tests
enforce to 1e-9 relative:

    sum(components) == kv_stall_time + step_time * slot_idle_steps
                    == per_token_stall * tokens

Components (the Eq. 1 decomposition):

  * ``flash_service``    — SSD occupancy + latency on the flash lane
  * ``nic_queue``        — NIC lane service + queueing behind other
                           flows (minus the incast share below)
  * ``incast``           — the extra NIC seconds attributable to
                           fan-in (topology incast factor > 1)
  * ``interference``     — waiting behind, or gated by, rebalance /
                           repair traffic (write-shield readability
                           gates included)
  * ``gate_miss_restore``— flash restore seconds for keys the
                           EconomicGate priced out of DRAM (the cost
                           of an admission decision, not of the media)
  * ``scheduler_idle``   — decode slots empty while work was pending
  * ``pool_rtt``         — far-memory pool lane seconds: the per-host
                           RTT + fabric-bandwidth lane to the shared
                           pool (the price of pooled DRAM's distance)
  * ``gpu_direct_service``— BaM-style GPU-direct flash path: device
                           service through the accelerator submission
                           queue (no host bounce, so none of these
                           seconds ever appear under ``flash_service``)
  * ``other``            — DRAM/HBM residuals and anything a future
                           lane adds before it is classified; keeping
                           a catch-all is what makes conservation
                           *exact* rather than aspirational

Per-tenant sub-ledgers use the same components, keyed by the tenant
tag carried in the KV key (``("kv", "tenant/idx")``); the SLO budget
burn-rate in `ContinuousScheduler.report` divides a tenant's ledger
total by its declared `p99_stall_budget * tokens`.
"""
from __future__ import annotations

from typing import Dict, Optional

COMPONENTS = ("flash_service", "nic_queue", "incast", "interference",
              "gate_miss_restore", "scheduler_idle", "pool_rtt",
              "gpu_direct_service", "other")


class StallLedger:
    """Per-component (and per-tenant) accumulator of modeled stalled
    seconds. Plain float adds — cheap enough to stay on always, which
    is what lets the conservation invariant hold on every run rather
    than only when tracing is enabled."""

    def __init__(self):
        self.totals: Dict[str, float] = {c: 0.0 for c in COMPONENTS}
        self.tenants: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------ recording
    def add(self, component: str, seconds: float,
            tenant: str = "") -> None:
        if seconds == 0.0:
            return
        if component not in self.totals:
            component = "other"
        self.totals[component] += seconds
        if tenant:
            t = self.tenants.get(tenant)
            if t is None:
                t = self.tenants[tenant] = {c: 0.0 for c in COMPONENTS}
            t[component] += seconds

    # ------------------------------------------------------------- reading
    def total(self) -> float:
        return sum(self.totals.values())

    def snapshot(self) -> Dict[str, float]:
        """Copy of the component totals (for delta accounting)."""
        return dict(self.totals)

    def delta_since(self, base: Dict[str, float]) -> Dict[str, float]:
        return {c: self.totals[c] - base.get(c, 0.0) for c in COMPONENTS}

    def tenant_totals(self, tenant: str) -> Dict[str, float]:
        return dict(self.tenants.get(tenant, {c: 0.0 for c in COMPONENTS}))

    def as_dict(self) -> Dict[str, object]:
        d: Dict[str, object] = {c: self.totals[c] for c in COMPONENTS}
        d["total"] = self.total()
        if self.tenants:
            d["tenants"] = {t: dict(v) for t, v in
                            sorted(self.tenants.items())}
        return d

    # ---------------------------------------------- snapshot/reset protocol
    def snapshot_stats(self) -> Dict[str, object]:
        return self.as_dict()

    def reset_stats(self) -> None:
        self.totals = {c: 0.0 for c in COMPONENTS}
        self.tenants = {}


def tenant_of_key(key) -> str:
    """Tenant tag carried by a KV key: ``("kv", "tenant/idx")`` →
    ``"tenant"``; anything else has no tenant attribution."""
    if isinstance(key, tuple) and len(key) == 2 and key[0] == "kv":
        rid = key[1]
        if isinstance(rid, str) and "/" in rid:
            return rid.split("/", 1)[0]
    return ""
