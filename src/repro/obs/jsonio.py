"""Canonical bench-JSON emit.

Every `benchmarks/serving_*.py` is byte-diffed across a double run in
CI; the diff is only meaningful if serialization itself is pinned.
Before this module each bench hand-rolled `json.dumps(report,
sort_keys=True, indent=2)` and hoped no numpy scalar or
platform-dependent float repr leaked in. `bench_json` pins all of it:

  * keys sorted, two-space indent (the existing bench convention),
  * numpy scalars / arrays folded to plain Python before dumping,
  * every float routed through ``float(f"{x:.12g}")`` so the emitted
    digits don't depend on accumulated rounding noise below the 12th
    significant digit (re-running a sum in a different association
    order stays byte-identical),
  * non-finite floats mapped to strings ("inf"/"-inf"/"nan") — the
    JSON spec has no spelling for them and `json.dumps` would emit
    the non-portable `Infinity`.
"""
from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Any, Optional, Union

import numpy as np


def _canon_float(x: float) -> Union[float, str]:
    if math.isnan(x):
        return "nan"
    if math.isinf(x):
        return "inf" if x > 0 else "-inf"
    return float(f"{x:.12g}")


def canon(obj: Any) -> Any:
    """Fold `obj` into canonical plain-Python JSON-ready structure."""
    if isinstance(obj, dict):
        return {str(k): canon(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [canon(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [canon(v) for v in obj.tolist()]
    if isinstance(obj, (bool, np.bool_)):
        return bool(obj)
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        return _canon_float(float(obj))
    return obj


def bench_json(report: Any) -> str:
    """Canonical JSON text for a bench report (no trailing newline)."""
    return json.dumps(canon(report), sort_keys=True, indent=2)


def write_bench_json(report: Any, out: Optional[Union[str, Path]] = None,
                     echo: bool = True) -> str:
    """The shared bench emit path: canonical dump, optional `--out`
    file (text + trailing newline), optional echo to stdout."""
    js = bench_json(report)
    if out is not None:
        Path(out).write_text(js + "\n")
    if echo:
        print(js)
    return js
