"""Platform — the compiled runtime behind a `HierarchySpec`.

`Platform.compile(spec)` assembles in one pass what previously took
five constructor dialects: the injected clock, N per-host `TieredStore`s
(each with its own tier geometry and its own policy — per-host
`EconomicGate`s sharing one fleet-wide `ReuseTracker` under the
economic policy), the sharded fabric with a capacity-weighted
consistent-hash ring, the NIC/topology service models, and an attached
`ProvisionAdvisor`. Economics and topology are inputs; nothing is
plumbing.

The facade hands out uniform capabilities:

    platform = Platform.compile(spec)
    sess = platform.kv_session("user-42", host=1)
    sess.save(blob); h = sess.prefetch(); ...; blob = h.result()
    es = platform.expert_store(n_layers=4, n_experts=8)
    eng = platform.engine(cfg, params, rules, host=0)
    advice = platform.advise()
    platform.autoscale(step)        # closed provisioning loop
    platform.fail_host(2)           # unplanned failure (no drain)
    platform.repair()               # paced re-replication
    platform.advise_availability()  # replication-factor pricing

`autoscale` lets the advisor *drive* `add_host`/`remove_host` (under
the spec's rebalance pacer and autoscale bounds) instead of merely
advising — see `repro.platform.autoscale`.
"""
from __future__ import annotations

from typing import Dict, Optional

from ..autopilot.advisor import ProvisionAdvice, ProvisionAdvisor
from ..autopilot.gate import EconomicGate
from ..autopilot.reuse import ReuseTracker
from ..core.policy import TieringPolicy
from ..obs import Observability
from ..runtime.clock import VirtualClock, WallClock
from ..runtime.fabric import RebalanceStats, ShardedTieredStore
from ..runtime.service import NetQueueModel, SsdQueueModel
from .handles import Handle, KvSession
from .spec import HierarchySpec, PolicyDecl

__all__ = ["Platform", "Handle", "KvSession"]


class Platform:
    """Compiled hierarchy: clock + fabric + policies + advisor, behind
    capability handles. Construct via `Platform.compile(spec)`."""

    def __init__(self, spec: HierarchySpec, clock, fabric, *,
                 tracker: Optional[ReuseTracker] = None,
                 advisor: Optional[ProvisionAdvisor] = None,
                 step_time: float = 0.0,
                 obs: Optional[Observability] = None):
        self.spec = spec
        self.clock = clock
        self.fabric = fabric
        self.tracker = tracker
        self.advisor = advisor
        self.step_time = step_time
        self.obs = obs if obs is not None else Observability()
        self._autoscaler = None
        self._workload = None

    # ------------------------------------------------------ observability
    @property
    def tracer(self):
        """Causal tracer (None unless spec.observability.trace)."""
        return self.obs.tracer

    @property
    def metrics(self):
        """`MetricsRegistry` (None when spec.observability.metrics off)."""
        return self.obs.metrics

    @property
    def ledger(self):
        """The fleet's always-on Eq. 1 stall ledger."""
        return self.obs.ledger

    # ------------------------------------------------------------- compile
    @classmethod
    def compile(cls, spec: HierarchySpec, *, sim_cfg=None) -> "Platform":
        """Validate `spec` and assemble the runtime. `sim_cfg` (a
        `repro.ssdsim.SimConfig`) overrides the flash calibration for
        every host — programmatic only, like a policy factory."""
        spec.validate()
        clock = VirtualClock(spec.t0) if spec.clock == "virtual" \
            else WallClock()

        tracker: Optional[ReuseTracker] = None
        advisor: Optional[ProvisionAdvisor] = None
        hosts = spec.expanded_hosts()
        decl = spec.policy
        if callable(decl) and not isinstance(decl, PolicyDecl):
            factory = decl
        elif decl.kind == "static":
            def factory(_h, _d=decl):
                return TieringPolicy(tau_hot=_d.tau_hot, tau_be=_d.tau_be,
                                     hysteresis=_d.hysteresis,
                                     ema_alpha=_d.ema_alpha)
        else:
            host_cfg, ssd = decl.economics()
            workload = spec.workload
            tenants = workload.tenants if workload is not None else ()
            # one fleet-wide tracker: every host's gate feeds it, the
            # advisor reads the whole workload's reuse histograms
            tracker = ReuseTracker(max_classes=max(8, len(tenants) + 4))
            fetch_seconds = 0.0
            if decl.alpha_stall or any(t.slo.alpha_stall
                                       for t in tenants):
                # price the miss the way the cost model does: the
                # modeled demand-fetch time at depth 1
                fetch_seconds = SsdQueueModel.shared(sim_cfg).service(
                    decl.l_blk, 1).total

            # declared workload -> per-tenant SLO economics: each
            # tenant's alpha_stall folds into its *own* tau_be, its key
            # class is the tenant name, and its declared think gap
            # seeds the tracker prior so the very first offload is
            # priced by the declaration, not the cold default.
            # isolation="shared" is the control arm: one fleet-wide
            # threshold/class, no declared priors
            classify = None
            class_tau_be = None
            priors = dict(spec.class_priors)
            if tenants and workload.isolation == "per-tenant":
                from .workload import tenant_classifier
                classify = tenant_classifier([t.name for t in tenants])
                class_tau_be = {
                    t.name: EconomicGate.breakeven_tau(
                        host_cfg, ssd, decl.l_blk,
                        gamma_rw=decl.gamma_rw, phi_wa=decl.phi_wa,
                        alpha_stall=t.slo.alpha_stall,
                        fetch_seconds=fetch_seconds)
                    for t in tenants}
                st = spec.resolved_step_time()
                if st > 0:
                    for t in tenants:
                        priors.setdefault(t.name,
                                          t.session.gap_steps * st)
            for cls_name, interval in sorted(priors.items()):
                tracker.seed_prior(cls_name, interval)

            # fourth-tier thresholds: the pool band's upper edge (pool
            # column vs a flash re-read) gates fleet-pool admission;
            # hosts that declare a "gpu_flash" tier route gate-cold
            # admissions down the BaM path. An empty band (crossover at
            # or under tau_be) compiles to no pooling — the economics
            # say the pool's own access cost exceeds a flash IO
            tau_pool = None
            if spec.pool is not None:
                from ..core.economics import pool_flash_crossover
                base_tau = EconomicGate.breakeven_tau(
                    host_cfg, ssd, decl.l_blk, gamma_rw=decl.gamma_rw,
                    phi_wa=decl.phi_wa, alpha_stall=decl.alpha_stall,
                    fetch_seconds=fetch_seconds)
                cross = float(pool_flash_crossover(
                    host_cfg, decl.l_blk, base_tau,
                    pool_bw=spec.pool.read_bw, pool_rtt=spec.pool.rtt,
                    rent_factor=spec.pool.rent_factor,
                    alpha_net=spec.pool.alpha_net))
                if cross > base_tau:
                    tau_pool = cross
            gpu_hosts = {i for i, h in enumerate(hosts)
                         if "gpu_flash" in h.tiers}
            template_gpu = "gpu_flash" in \
                spec.hosts[spec.autoscale.template].tiers

            def factory(_h, _d=decl, _t=tracker, _f=fetch_seconds,
                        _host=host_cfg, _ssd=ssd, _c=classify,
                        _taus=class_tau_be, _tp=tau_pool,
                        _g=gpu_hosts, _n=len(hosts), _tg=template_gpu):
                kw = {} if _c is None else {"classify": _c}
                gpu = _h in _g or (_h >= _n and _tg)
                return EconomicGate.from_break_even(
                    _host, _ssd, _d.l_blk, gamma_rw=_d.gamma_rw,
                    phi_wa=_d.phi_wa, alpha_stall=_d.alpha_stall,
                    fetch_seconds=_f, tracker=_t,
                    prior_quantile=_d.prior_quantile,
                    class_tau_be=_taus, tau_pool=_tp,
                    gpu_direct=gpu, **kw)

        topology = spec.topology.compile() if spec.topology is not None \
            else None
        net_model = None
        if spec.net is not None:
            net_model = NetQueueModel(rtt=spec.net.rtt,
                                      bandwidth=spec.net.bandwidth,
                                      sat_depth=spec.net.sat_depth,
                                      topology=topology)
            topology = None         # attached to the model, per fabric rule

        obs_decl = spec.observability
        obs = Observability(trace=obs_decl.trace,
                            metrics=obs_decl.metrics,
                            max_events=obs_decl.max_events)

        pool = None
        if spec.pool is not None:
            from ..runtime.pool import PooledStore
            p = spec.pool
            pool = PooledStore(
                p.capacity_bytes, read_bw=p.read_bw,
                write_bw=p.write_bw, rtt=p.rtt, sat_depth=p.sat_depth,
                rent_factor=p.rent_factor, clock=clock, obs=obs)
        fabric = ShardedTieredStore(
            host_specs=[h.tier_specs() for h in hosts],
            weights=spec.resolved_weights(),
            policy_factory=factory, clock=clock, sim_cfg=sim_cfg,
            net_model=net_model, topology=topology,
            write_shield_depth=spec.write_shield_depth,
            vnodes=spec.vnodes, rebalance_rate=spec.rebalance_rate,
            obs=obs, pool=pool)
        if obs.metrics is not None:
            obs.metrics.register("fabric", fabric)

        if tracker is not None:
            template = spec.hosts[spec.autoscale.template]
            advisor = ProvisionAdvisor(
                host_cfg, ssd, decl.l_blk, gamma_rw=decl.gamma_rw,
                phi_wa=decl.phi_wa,
                dram_bytes_per_host=template.dram_capacity(),
                active_window=spec.autoscale.active_window)

        return cls(spec, clock, fabric, tracker=tracker, advisor=advisor,
                   step_time=spec.resolved_step_time(), obs=obs)

    # -------------------------------------------------------- capabilities
    @property
    def n_hosts(self) -> int:
        return self.fabric.n_hosts

    def policy(self, host: int = 0) -> TieringPolicy:
        return self.fabric.hosts[host].policy

    def kv_session(self, rid: str, *, host: int = 0,
                   replicas: Optional[int] = None) -> KvSession:
        """Session-state capability (save/prefetch/resume one KV blob)."""
        return KvSession(self.fabric, rid, host,
                         replicas=replicas if replicas is not None
                         else self.spec.replicas)

    def expert_store(self, n_layers: int, n_experts: int, *,
                     host: int = 0, replicas: Optional[int] = None,
                     expert_bytes: float = 0.0):
        """MoE expert streaming over the fabric from `host`'s view."""
        from ..tiering.expert_store import ExpertStore
        r = replicas if replicas is not None else self.spec.replicas
        return ExpertStore(
            n_layers, n_experts, policy=self.policy(host),
            store=self.fabric.host_view(host, replicas=r),
            expert_bytes=expert_bytes)

    def checkpoint_steps(self, step_time: Optional[float] = None) -> int:
        """spec.checkpoint_interval (seconds) -> decode steps for this
        platform's step time; 0 when checkpointing is off."""
        iv = self.spec.checkpoint_interval
        if iv is None:
            return 0
        st = self.step_time if step_time is None else step_time
        if st > 0:
            import math
            return max(1, int(math.ceil(iv / st)))
        return max(1, int(round(iv)))

    def engine(self, cfg, params, rules, *, host: int = 0,
               step_time: Optional[float] = None, **kw):
        """Decode engine on `host`'s fabric view, stepping the shared
        clock by the spec's (possibly roofline-measured) step time.
        The view replicates puts to `spec.replicas` holders — a paused
        or checkpointed session's KV blob survives `fail_host` — and
        `spec.checkpoint_interval` arms the engine's periodic session
        checkpointing."""
        from ..serving.engine import DecodeEngine
        st = self.step_time if step_time is None else step_time
        kw.setdefault("checkpoint_interval", self.checkpoint_steps(st))
        return DecodeEngine(
            cfg, params, rules, policy=self.policy(host),
            store=self.fabric.host_view(host,
                                        replicas=self.spec.replicas),
            step_time=st, **kw)

    def scheduler(self, cfg, params, rules, *, host: int = 0,
                  pause_idle_steps: Optional[int] = None,
                  prefetch_lead=None, **kw):
        """Continuous-batching scheduler over a fresh engine on `host`
        (`repro.serving.ContinuousScheduler`): per-step admission,
        pause-on-idle through the tiered store, prefetch-led resume.
        Knobs default to the spec's `scheduler` declaration; engine
        kwargs (`max_slots`, `max_len`, ...) pass through."""
        from ..serving.scheduler import ContinuousScheduler
        eng = self.engine(cfg, params, rules, host=host, **kw)
        decl = self.spec.scheduler
        budgets = {}
        if self.spec.workload is not None:
            budgets = {t.name: t.slo.p99_stall_budget
                       for t in self.spec.workload.tenants
                       if t.slo.p99_stall_budget is not None}
        return ContinuousScheduler(
            eng,
            pause_idle_steps=decl.pause_idle_steps
            if pause_idle_steps is None else pause_idle_steps,
            prefetch_lead=decl.prefetch_lead
            if prefetch_lead is None else prefetch_lead,
            stall_budgets=budgets)

    # ------------------------------------------------------------ workload
    def workload(self):
        """Compiled rendering of `spec.workload`
        (`repro.platform.workload.CompiledWorkload`): tenant-tagged
        jobs, access traces, per-tenant thresholds. Cached — every
        call sees the same deterministic draw."""
        if self.spec.workload is None:
            raise ValueError(
                "spec declares no workload: set HierarchySpec.workload "
                "(a WorkloadDecl with at least one tenant) to compile "
                "scenario jobs/traces from the spec")
        if self._workload is None:
            from .workload import compile_workload
            self._workload = compile_workload(self.spec.workload)
        return self._workload

    def jobs(self, *, vocab: int = 64):
        """Declared-scenario `SessionJob` list for `self.scheduler(...)`
        — tenant-tagged, deterministic in (spec JSON, workload seed)."""
        return self.workload().jobs(vocab=vocab)

    # ---------------------------------------------------------- provision
    def advise(self, horizon: Optional[float] = None) -> ProvisionAdvice:
        """Live provisioning guidance from the fleet's measured state."""
        if self.advisor is None or self.tracker is None:
            raise ValueError(
                "platform has no advisor: provisioning guidance needs "
                "the economic policy (PolicyDecl(kind='economic')); "
                "static/factory policies track no reuse telemetry")
        return self.advisor.advise(self.tracker, fabric=self.fabric,
                                   horizon=horizon)

    def add_host(self) -> RebalanceStats:
        """Join a template host (spec.autoscale.template) and rebalance
        under the spec's pacer."""
        spec = self.spec
        template = spec.hosts[spec.autoscale.template]
        weights = spec.resolved_weights()
        first = sum(h.count for h in
                    spec.hosts[:spec.autoscale.template])
        return self.fabric.add_host(specs=template.tier_specs(),
                                    weight=weights[first])

    def fail_host(self, host: int):
        """Unplanned failure: drop `host` with no drain (see
        `ShardedTieredStore.fail_host`). Returns the `FailureReport`."""
        return self.fabric.fail_host(host)

    def repair(self, batch_keys: int = 64):
        """Re-replicate everything under-replicated or misplaced after a
        failure, paced by the spec's `rebalance_rate`. Returns
        `RepairStats` (its `duration` is the recovery time)."""
        from ..runtime.repair import RepairLoop
        return RepairLoop(self.fabric, batch_keys=batch_keys).run()

    def advise_availability(self, mttf: Optional[float] = None, **kw):
        """Replication-factor recommendation priced from live fleet
        state; `mttf` defaults to the spec's declared value."""
        if self.advisor is None:
            raise ValueError(
                "platform has no advisor: availability pricing needs "
                "the economic policy (PolicyDecl(kind='economic'))")
        mttf = self.spec.mttf if mttf is None else mttf
        if mttf is None:
            raise ValueError("no MTTF declared: set spec.mttf or pass "
                             "mttf= explicitly")
        return self.advisor.advise_availability(fabric=self.fabric,
                                                mttf=mttf, **kw)

    def advise_tiers(self, *, access_rate: float,
                     resident_bytes: Optional[float] = None, **kw):
        """Four-arm hierarchy-shape recommendation (3-tier baseline vs
        +pool vs +gpu_flash vs both) priced from the fleet's tracked
        reuse distribution. Pool parameters default to the spec's
        `PoolDecl` when one is declared; `resident_bytes` defaults to a
        live census across hosts and pool."""
        if self.advisor is None or self.tracker is None:
            raise ValueError(
                "platform has no advisor: tier-shape pricing needs "
                "the economic policy (PolicyDecl(kind='economic'))")
        p = self.spec.pool
        if p is not None:
            kw.setdefault("pool_bw", p.read_bw)
            kw.setdefault("pool_rtt", p.rtt)
            kw.setdefault("rent_factor", p.rent_factor)
            kw.setdefault("alpha_net", p.alpha_net)
        if resident_bytes is None:
            seen: Dict[object, int] = {}
            for s in self.fabric.hosts.values():
                for key in s.keys():
                    seen.setdefault(key, s.nbytes_of(key))
            if self.fabric.pool is not None:
                for key in self.fabric.pool.keys():
                    seen.setdefault(key,
                                    self.fabric.pool.nbytes_of(key))
            resident_bytes = float(sum(seen.values()))
        return self.advisor.advise_tiers(
            self.tracker, access_rate=access_rate,
            resident_bytes=resident_bytes, **kw)

    def autoscale(self, step: Optional[int] = None):
        """One closed-loop provisioning step: the advisor's host-count
        recommendation drives `add_host`/`remove_host` under the spec's
        bounds, cooldown and rebalance pacer. Returns the
        `AutoscaleDecision` (action taken, advice, rebalance stats)."""
        if self._autoscaler is None:
            from .autoscale import Autoscaler
            self._autoscaler = Autoscaler(self)
        return self._autoscaler.step(step)

    # ------------------------------------------------------------- control
    def drain(self) -> float:
        return self.fabric.drain()

    def reset_stats(self):
        """One reset for the whole platform, routed through the
        metrics registry's snapshot/reset protocol: registered
        components (fabric counters + per-host/NIC queue stats, the
        stall ledger) and every counter/gauge/histogram reset together.
        Falls back to direct resets when metrics are declared off."""
        if self.obs.metrics is not None:
            self.obs.metrics.reset()
        else:
            self.fabric.reset_stats()
            self.obs.ledger.reset_stats()

    def snapshot_stats(self) -> Dict[str, object]:
        """Uniform stats snapshot (metrics + registered components)."""
        return self.obs.snapshot_stats()

    def summary(self) -> Dict[str, float]:
        return self.fabric.summary()

    def report(self) -> str:
        return self.fabric.report()
