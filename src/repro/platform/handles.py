"""Uniform capability handles for the compiled platform.

The runtime grew three async call styles — `TieredStore.get_async` ->
`PendingFetch.wait()`, `AsyncTierRuntime.submit` -> `Transfer`, and the
engines' `prefetch_*`/`resume` pairs. The facade collapses them into
one future idiom:

    h = session.fetch()          # issue, never blocks
    ... overlap compute ...
    blob = h.result()            # block only on the unfinished remainder

`Handle.done()` answers "would result() stall right now"; `result()` is
idempotent (the value is cached after the first wait). Writes return an
already-done Handle — placement is structural-now, the bytes stream
behind compute, exactly the store's non-blocking write contract.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.policy import Tier


class Handle:
    """One async future over any runtime pending object (`PendingFetch`,
    the fabric's `RemoteFetch`, or nothing for an already-done write)."""

    __slots__ = ("_pending", "_value", "_resolved")

    def __init__(self, pending=None, value=None):
        self._pending = pending
        self._value = value
        self._resolved = pending is None

    def done(self) -> bool:
        """True iff `result()` would return without stalling."""
        if self._resolved:
            return True
        return bool(self._pending.done())

    def result(self):
        """Block on the unfinished remainder (stall lands in the owning
        store's stats) and return the value; idempotent."""
        if not self._resolved:
            self._value = self._pending.wait()
            self._resolved = True
        return self._value


class KvSession:
    """One session's KV state as a capability: save/fetch/prefetch the
    blob through the fabric from a bound host's vantage point, with the
    uniform `Handle` idiom and p99 prefetch-lead sizing. Obtained from
    `Platform.kv_session(rid, host=...)`."""

    def __init__(self, fabric, rid: str, host: int, replicas: int = 1):
        self.fabric = fabric
        self.rid = rid
        self.host = host
        self.replicas = replicas
        self._pending: Optional[Handle] = None

    @property
    def key(self):
        return ("kv", self.rid)

    def save(self, blob, tier: Tier = Tier.DRAM) -> Handle:
        """Place the session's KV (policy may re-tier the ask); the
        write streams behind compute, so the handle is already done."""
        self._pending = None          # a new blob supersedes any prefetch
        self.fabric.put(self.key, np.asarray(blob), tier=tier,
                        from_host=self.host, replicas=self.replicas)
        return Handle()

    def fetch(self) -> Handle:
        """Issue a fresh async restore from this session's host."""
        return Handle(self.fabric.get_async(self.key,
                                            from_host=self.host))

    def prefetch(self) -> Handle:
        """Idempotent async restore: repeated calls share one in-flight
        fetch until its `result()` is consumed."""
        if self._pending is None or self._pending._resolved:
            self._pending = self.fetch()
        return self._pending

    def resume(self) -> np.ndarray:
        """The prefetch's value, blocking only on the remainder."""
        return self.prefetch().result()

    # ------------------------------------------------------------ queries
    def tier(self) -> Optional[Tier]:
        return self.fabric.tier_of(self.key)

    def preferred_host(self) -> int:
        """Least-loaded holder of the KV replica (locality routing)."""
        return self.fabric.preferred_host(self.key, default=self.host)

    def route(self) -> "KvSession":
        """Rebind to the preferred host, turning a remote restore into a
        local read; returns self for chaining."""
        self.host = self.preferred_host()
        return self

    def lead_steps(self, step_time: float) -> int:
        """p99-sized prefetch lead in decode steps from this vantage."""
        return self.fabric.prefetch_lead_steps(self.key, step_time,
                                               from_host=self.host)
