"""WorkloadDecl compiler: declared scenarios -> jobs, traces, thresholds.

The benches used to hand-code session/turn shapes in four places
(`autopilot/traces.py`, `serving/scheduler.py::jobs_from_trace`,
`serving/scale.py`, `benchmarks/*`). `compile_workload` replaces those
with one generator over a declared `WorkloadDecl`: every tenant's
arrival process, session shape and SLO compile into

  * `jobs()`     — tenant-tagged multi-turn `SessionJob` lists for the
                   `ContinuousScheduler` (session ids `"{tenant}/NNN"`,
                   so the gate's classifier recovers the tenant),
  * `trace()`    — an `autopilot.traces.Trace` whose keys are
                   `(tenant, id)` tuples for the economics benches,
  * `id_steps()` — dense per-step int-id arrays for the vectorized
                   control-plane replay (`serving.scale`),
  * `tenant_taus()` / `declared_priors()` — per-tenant `tau_be` (each
                   tenant's `alpha_stall` folded in via the same Eq. 1
                   correction `EconomicGate.from_break_even` applies)
                   and declared reuse priors for the `ReuseTracker`.

Everything is drawn from per-tenant rngs seeded by
`(decl.seed, crc32(tenant.name), stream)`, so each product is a pure
function of the spec JSON — byte-identical across
compile -> to_json -> from_json -> compile, which CI asserts.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional

import numpy as np

from ..autopilot.traces import Trace
from .spec import TenantDecl, WorkloadDecl

__all__ = ["CompiledWorkload", "compile_workload", "tenant_classifier"]


def _rng(decl: WorkloadDecl, tenant: str, stream: int):
    """Per-(tenant, stream) rng: streams keep jobs/trace/prompt draws
    independent so rendering one product never perturbs another."""
    return np.random.default_rng(
        [decl.seed, zlib.crc32(tenant.encode()), stream])


def tenant_classifier(names):
    """Key -> class fn that recovers the tenant from both key shapes the
    compiler emits: scheduler KV keys `("kv", "{tenant}/NNN")` and trace
    keys `(tenant, id)`. Anything else falls back to the runtime's
    default conventions."""
    from ..autopilot.gate import default_classify
    known = frozenset(names)

    def classify(key) -> str:
        if isinstance(key, tuple) and len(key) == 2:
            head = key[0]
            if head in known:
                return head
            if head == "kv" and isinstance(key[1], str):
                tenant = key[1].split("/", 1)[0]
                if tenant in known:
                    return tenant
        return default_classify(key)

    return classify


class CompiledWorkload:
    """Deterministic rendering of one `WorkloadDecl`. Schedules are
    drawn once at construction; `jobs`/`trace`/`id_steps` are pure
    views over them."""

    def __init__(self, decl: WorkloadDecl):
        decl.validate()
        self.decl = decl
        self.horizon = decl.horizon_steps
        # per tenant: turn schedule (due/new int arrays, [n_sessions x
        # n_turns]), background object ids per step, extra per-turn keys
        self._due: Dict[str, np.ndarray] = {}
        self._new: Dict[str, np.ndarray] = {}
        self._background: Dict[str, List[np.ndarray]] = {}
        self._bg_space: Dict[str, int] = {}
        self._extras: Dict[str, List[np.ndarray]] = {}
        self._extra_space: Dict[str, int] = {}
        for t in decl.tenants:
            due, new = self._schedule(t)
            self._due[t.name], self._new[t.name] = due, new
            bg, bg_space = self._background_stream(t)
            self._background[t.name] = bg
            self._bg_space[t.name] = bg_space
            ex, ex_space = self._extra_stream(t, due)
            self._extras[t.name] = ex
            self._extra_space[t.name] = ex_space

    # ----------------------------------------------------------- drawing
    def _schedule(self, t: TenantDecl):
        """Turn schedule for one tenant: first turns arrive by the
        declared intensity; later turns chain at the declared
        (jittered) think gap after the previous turn's decode."""
        n, turns = t.n_sessions, t.session.n_turns
        rng = _rng(self.decl, t.name, 0)
        mass = t.arrival.intensity(self.horizon)
        cdf = np.cumsum(mass) / mass.sum()
        first = np.searchsorted(cdf, rng.random(n)).astype(np.int64)
        s = t.session
        lo = max(1, s.tokens_per_turn // 2)
        hi = 2 * s.tokens_per_turn
        new = rng.integers(lo, hi, size=(n, turns)).astype(np.int64)
        jitter = 1.0 + s.gap_jitter * (2.0 * rng.random((n, turns)) - 1.0)
        gaps = np.maximum(1, np.rint(s.gap_steps * jitter)).astype(np.int64)
        due = np.empty((n, turns), np.int64)
        if n:
            due[:, 0] = first
            for k in range(1, turns):
                # strictly ordered, leaving decode room for the previous
                # turn — the same invariant jobs_from_trace kept
                due[:, k] = due[:, k - 1] + new[:, k - 1] + gaps[:, k]
        return due, new

    def _background_stream(self, t: TenantDecl):
        """Side-object ids per step: `background_per_step` scaled by the
        arrival intensity, zipf over a pool (or fresh one-touch ids when
        the pool is 0 — the scan shape)."""
        arr = t.arrival
        if arr.background_per_step == 0:
            return [], 0
        rng = _rng(self.decl, t.name, 1)
        mass = arr.intensity(self.horizon)
        counts = np.rint(arr.background_per_step * mass).astype(np.int64)
        total = int(counts.sum())
        if arr.background_pool > 0:
            pool = arr.background_pool
            u = rng.random(total)
            flat = np.minimum((pool * np.power(u, arr.background_zipf))
                              .astype(np.int64), pool - 1)
            space = pool
        else:
            flat = np.arange(total, dtype=np.int64)   # fresh, never reused
            space = total
        bounds = np.concatenate([[0], np.cumsum(counts)])
        steps = [flat[bounds[i]:bounds[i + 1]]
                 for i in range(self.horizon)]
        return steps, space

    def _extra_stream(self, t: TenantDecl, due: np.ndarray):
        """Per-turn side reads (RAG corpus / scan keys): rendered at the
        turn's due step in the access trace."""
        s = t.session
        if s.extra_keys_per_turn == 0 or due.size == 0:
            return [], 0
        rng = _rng(self.decl, t.name, 2)
        turn_steps = due.ravel()
        live = turn_steps < self.horizon
        total = int(live.sum()) * s.extra_keys_per_turn
        if s.extra_key_pool > 0:
            pool = s.extra_key_pool
            u = rng.random(total)
            flat = np.minimum((pool * np.power(u, s.extra_zipf))
                              .astype(np.int64), pool - 1)
            space = pool
        else:
            flat = np.arange(total, dtype=np.int64)
            space = total
        steps: List[np.ndarray] = [np.empty(0, np.int64)
                                   for _ in range(self.horizon)]
        order = np.argsort(turn_steps[live], kind="stable")
        grouped = flat.reshape(-1, s.extra_keys_per_turn)[order]
        srt = turn_steps[live][order]
        bounds = np.searchsorted(srt, np.arange(self.horizon + 1))
        for i in range(self.horizon):
            if bounds[i + 1] > bounds[i]:
                steps[i] = grouped[bounds[i]:bounds[i + 1]].ravel()
        return steps, space

    # ------------------------------------------------------------- views
    def jobs(self, *, vocab: int = 64):
        """Tenant-tagged `SessionJob` list in declared tenant order.
        Session ids are `"{tenant}/{i:03d}"`, so the tenant classifier
        (and per-tenant gate thresholds) see the offloaded KV keys."""
        from ..serving.scheduler import SessionJob, Turn
        jobs = []
        for t in self.decl.tenants:
            due, new = self._due[t.name], self._new[t.name]
            prng = _rng(self.decl, t.name, 3)
            prompts = prng.integers(
                1, vocab, size=(t.n_sessions, t.session.prompt_len)
            ).astype(np.int32)
            dl = t.slo.deadline_steps
            for i in range(t.n_sessions):
                turns = [Turn(due_step=int(due[i, k]),
                              max_new=int(new[i, k]),
                              deadline_steps=dl)
                         for k in range(due.shape[1])]
                jobs.append(SessionJob(sid=f"{t.name}/{i:03d}",
                                       prompt=prompts[i], turns=turns,
                                       tenant=t.name))
        return jobs

    def trace(self, *, step_time: float = 0.25,
              name: str = "workload") -> Trace:
        """Access trace for the autopilot benches. Keys are
        `(tenant, id)` tuples with disjoint per-tenant id spaces:
        sessions `[0, n)`, background objects and per-turn extras
        offset after them — `default_classify` (key[0]) recovers the
        tenant class."""
        steps: List[List[tuple]] = [[] for _ in range(self.horizon)]
        for t in self.decl.tenants:
            due = self._due[t.name]
            flat = due.ravel()
            sids = np.repeat(np.arange(t.n_sessions), due.shape[1])
            live = flat < self.horizon
            order = np.argsort(flat[live], kind="stable")
            srt, ssids = flat[live][order], sids[live][order]
            bounds = np.searchsorted(srt, np.arange(self.horizon + 1))
            bg_off = t.n_sessions
            ex_off = bg_off + self._bg_space[t.name]
            bg, ex = self._background[t.name], self._extras[t.name]
            for i in range(self.horizon):
                step = steps[i]
                step.extend((t.name, int(s))
                            for s in ssids[bounds[i]:bounds[i + 1]])
                if ex:
                    step.extend((t.name, int(ex_off + k))
                                for k in ex[i])
                if bg:
                    step.extend((t.name, int(bg_off + k))
                                for k in bg[i])
        return Trace(name=name, step_time=step_time, steps=steps)

    def id_steps(self):
        """Dense-int rendering for the vectorized control-plane replay:
        `(steps, n_session_ids, n_ids)`. Session ids occupy `[0,
        n_session_ids)` in declared tenant order (so `ids <
        n_session_ids` means "session KV key"), object ids follow."""
        sess_off: Dict[str, int] = {}
        off = 0
        for t in self.decl.tenants:
            sess_off[t.name] = off
            off += t.n_sessions
        n_session_ids = off
        obj_off: Dict[str, int] = {}
        for t in self.decl.tenants:
            obj_off[t.name] = off
            off += self._bg_space[t.name] + self._extra_space[t.name]
        n_ids = off

        steps: List[np.ndarray] = []
        per_tenant_sess: Dict[str, List[np.ndarray]] = {}
        for t in self.decl.tenants:
            due = self._due[t.name]
            flat = due.ravel()
            sids = np.repeat(np.arange(t.n_sessions, dtype=np.int64),
                             due.shape[1])
            live = flat < self.horizon
            order = np.argsort(flat[live], kind="stable")
            srt, ssids = flat[live][order], sids[live][order]
            bounds = np.searchsorted(srt, np.arange(self.horizon + 1))
            per_tenant_sess[t.name] = [
                sess_off[t.name] + ssids[bounds[i]:bounds[i + 1]]
                for i in range(self.horizon)]
        for i in range(self.horizon):
            parts = []
            for t in self.decl.tenants:
                parts.append(per_tenant_sess[t.name][i])
                ex = self._extras[t.name]
                if ex and ex[i].size:
                    parts.append(obj_off[t.name] + ex[i])
                bg = self._background[t.name]
                if bg and bg[i].size:
                    parts.append(obj_off[t.name]
                                 + self._extra_space[t.name] + bg[i])
            steps.append(np.concatenate(parts) if parts
                         else np.empty(0, np.int64))
        return steps, n_session_ids, n_ids

    # -------------------------------------------------------- economics
    def tenant_taus(self, host, ssd, l_blk: float, *,
                    gamma_rw: float = 9.0, phi_wa: float = 3.0,
                    iops_ssd: Optional[float] = None,
                    fetch_seconds: float = 0.0) -> Dict[str, float]:
        """Per-tenant break-even thresholds: each tenant's declared
        `alpha_stall` folded into its own tau_be — a premium tenant's
        stall rents DRAM harder than a batch tenant's."""
        from ..autopilot.gate import EconomicGate
        return {t.name: EconomicGate.breakeven_tau(
            host, ssd, l_blk, gamma_rw=gamma_rw, phi_wa=phi_wa,
            iops_ssd=iops_ssd, alpha_stall=t.slo.alpha_stall,
            fetch_seconds=fetch_seconds)
            for t in self.decl.tenants}

    def declared_priors(self, step_time: float) -> Dict[str, float]:
        """Tenant -> declared reuse interval (seconds): the think gap is
        how long an offloaded KV blob waits before its resume touches
        it. Seeded into the `ReuseTracker` so a tenant's first offload
        is priced by its declaration, not the cold default."""
        if step_time <= 0:
            return {}
        return {t.name: t.session.gap_steps * step_time
                for t in self.decl.tenants}

    def slos(self) -> Dict[str, object]:
        return {t.name: t.slo for t in self.decl.tenants}

    def tenant_names(self) -> List[str]:
        return [t.name for t in self.decl.tenants]


def compile_workload(decl: WorkloadDecl) -> CompiledWorkload:
    """Validate + render a `WorkloadDecl`. Pure in (decl JSON, seed)."""
    return CompiledWorkload(decl)
