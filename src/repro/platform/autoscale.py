"""Closed provisioning loop: the advisor drives the fleet.

PR 4's `ProvisionAdvisor` answered the paper's §V questions from live
telemetry but only *advised*. `Autoscaler` closes the loop:
`Platform.autoscale(step)` compares the advisor's measured-hot-set host
recommendation against the current fleet and calls the elastic fabric's
`add_host`/`remove_host` under the spec's bounds (`AutoscaleDecl`:
min/max hosts, cooldown) and rebalance pacer (`rebalance_rate` token
bucket) — the diurnal fleet grows a host for the peak and hands it back
off-peak, paying only the measured rebalance tax.

`run_autoscale_bench` prices the loop on a scenario trace: modeled
$/token (DRAM rent on *provisioned* capacity — provisioning is the
knob — plus flash IO, host CPU and stalled-engine time) for the
autoscaled fleet vs a static fleet provisioned for the peak. The
acceptance bound (asserted in tests, reported by
`benchmarks/serving_autopilot.py --autoscale`): the loop ends within
one host of the advisor's final recommendation at equal-or-lower
$/token than the static fleet.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..autopilot.bench import PAGE_BYTES, pricing_rates
from ..autopilot.traces import generate
from ..core.policy import Tier
from .spec import AutoscaleDecl, HierarchySpec, HostDecl, PolicyDecl, \
    TierDecl


@dataclasses.dataclass
class AutoscaleDecision:
    """One closed-loop step: what the advisor saw, what the loop did."""
    step: int
    action: str                 # "add" | "remove" | "hold"
    n_hosts: int                # fleet size after the action
    recommended: int            # advisor's clamped host count
    reason: str
    rebalance: Optional[Dict[str, float]] = None

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}


class Autoscaler:
    """Advisor-driven elastic control for a compiled `Platform`."""

    def __init__(self, platform):
        self.platform = platform
        self.decl: AutoscaleDecl = platform.spec.autoscale
        self.decisions: List[AutoscaleDecision] = []
        self._last_change: Optional[int] = None
        self._auto_step = 0

    def step(self, step: Optional[int] = None) -> AutoscaleDecision:
        """Consult the advisor once and act at most once.

        Decisions are denominated in DRAM *bytes*, not host counts: the
        advisor's `recommended_hosts` assumes template-sized hosts, so
        on a heterogeneous fleet matching the count could strand the
        hot set below its capacity target. The loop instead grows while
        the fleet's DRAM capacity is short of the advisor's provision
        target, and retires the newest host only when the survivors
        still cover it."""
        if step is None:
            step = self._auto_step
        self._auto_step = step + 1
        fabric = self.platform.fabric
        advice = self.platform.advise()
        rec = int(np.clip(advice.recommended_hosts, self.decl.min_hosts,
                          self.decl.max_hosts))
        target = advice.recommended_dram_bytes
        cur = fabric.n_hosts

        def dram_cap(h):
            return fabric.hosts[h].specs[Tier.DRAM].capacity_bytes

        cap = sum(dram_cap(h) for h in fabric.host_ids)
        victim = max(fabric.host_ids)           # the newest host
        if (self._last_change is not None
                and step - self._last_change < self.decl.cooldown_steps):
            d = AutoscaleDecision(step, "hold", cur, rec,
                                  f"cooldown ({step - self._last_change}"
                                  f"/{self.decl.cooldown_steps} steps "
                                  f"since last change)")
        elif cap < target and cur < self.decl.max_hosts:
            rb = self.platform.add_host()
            self._last_change = step
            d = AutoscaleDecision(step, "add", fabric.n_hosts, rec,
                                  f"hot-set target {target/2**20:.1f}MiB "
                                  f"exceeds fleet DRAM "
                                  f"{cap/2**20:.1f}MiB",
                                  rebalance=rb.as_dict())
        elif advice.bandwidth_limited and cur < self.decl.max_hosts:
            # capacity covers the hot set but the binding constraint is
            # a bandwidth threshold (T_B: DRAM wire, T_S: SSD lanes) —
            # more bytes on the same hosts won't help; more hosts
            # (spindles + DRAM channels) spread the demand
            rb = self.platform.add_host()
            self._last_change = step
            d = AutoscaleDecision(step, "add", fabric.n_hosts, rec,
                                  f"{advice.limit}-limited "
                                  f"(T_B={advice.t_b:.3g}s "
                                  f"T_S={advice.t_s:.3g}s): adding a "
                                  f"host to spread bandwidth demand",
                                  rebalance=rb.as_dict())
        elif (cur > self.decl.min_hosts
                and cap - dram_cap(victim) >= target
                and not advice.bandwidth_limited):
            rb = fabric.remove_host(victim)
            self._last_change = step
            d = AutoscaleDecision(step, "remove", fabric.n_hosts, rec,
                                  f"hot-set target {target/2**20:.1f}MiB "
                                  f"fits without host {victim}; "
                                  f"retiring it",
                                  rebalance=rb.as_dict())
        else:
            d = AutoscaleDecision(step, "hold", cur, rec,
                                  "fleet capacity matches the target"
                                  if not advice.bandwidth_limited else
                                  f"{advice.limit}-limited but at "
                                  f"max_hosts={self.decl.max_hosts}; "
                                  f"holding")
        self.decisions.append(d)
        return d


# ---------------------------------------------------------------------------
# The autoscale benchmark (diurnal trace, closed loop vs static fleet)
# ---------------------------------------------------------------------------

def default_autoscale_spec(l_blk: int = 128 << 10, *,
                           alpha_stall: float = 4.0,
                           dram_blocks_per_host: int = 20,
                           max_hosts: int = 4,
                           active_window: float = 4.0,
                           cooldown_steps: int = 20,
                           rebalance_rate: Optional[float] = 2e9
                           ) -> HierarchySpec:
    """A one-host seed fleet sized so one trace pool's hot set fits a
    single host and the diurnal overlap needs two — the shape the
    closed-loop acceptance criterion exercises."""
    host = HostDecl(tiers={
        "hbm": TierDecl(2 * l_blk, 819e9, 1e-7),
        "dram": TierDecl(dram_blocks_per_host * l_blk, 45e9, 5e-7),
        "flash": TierDecl(1 << 34, 7e9, 2e-5),
    })
    return HierarchySpec(
        hosts=(host,),
        policy=PolicyDecl.economic(l_blk=l_blk, alpha_stall=alpha_stall),
        rebalance_rate=rebalance_rate,
        autoscale=AutoscaleDecl(min_hosts=1, max_hosts=max_hosts,
                                cooldown_steps=cooldown_steps,
                                active_window=active_window))


def _run_arm(spec: HierarchySpec, trace, *, l_blk: int, step_time: float,
             tokens_per_step: int, alpha_accel: float, every: int,
             autoscale: bool, sim_cfg=None) -> Dict[str, object]:
    from .compiler import Platform
    platform = Platform.compile(spec, sim_cfg=sim_cfg)
    fabric, clock = platform.fabric, platform.clock
    host_cfg, ssd = spec.policy.economics()
    blob = np.zeros(max(l_blk // 4, 1), np.float32)

    total_stall = 0.0
    first_touches = 0
    provisioned_byte_seconds = 0.0
    host_seconds = 0.0
    peak_hosts = fabric.n_hosts
    last_t = clock.now()
    for t, step in enumerate(trace.steps):
        for key in step:
            h = fabric.owner(key)
            if fabric.tier_of(key) is None:
                # the ask is DRAM; the per-host gate re-tiers it by the
                # tracked reuse estimate vs break-even
                fabric.put(key, blob, tier=Tier.DRAM, from_host=h)
                first_touches += 1
            else:
                t0 = clock.now()
                fabric.get(key, from_host=h)
                total_stall += clock.now() - t0
        clock.advance(step_time)
        now = clock.now()
        dt = now - last_t
        for store in fabric.hosts.values():
            provisioned_byte_seconds += \
                store.specs[Tier.DRAM].capacity_bytes * dt
        host_seconds += fabric.n_hosts * dt
        last_t = now
        if autoscale and (t + 1) % every == 0:
            platform.autoscale(t)
            peak_hosts = max(peak_hosts, fabric.n_hosts)
    horizon = clock.now()
    platform.drain()

    # -------------------------------------------------------- cost model
    # the same normalized rates as the admission benchmark
    # (autopilot.bench.pricing_rates), with rent charged on
    # *provisioned* capacity — provisioning is this loop's knob
    rates = pricing_rates(host_cfg, ssd)
    flash_pages = 0
    dram_bytes_moved = 0
    total_ios = 0
    for store in fabric._all_stores():
        q = store.runtime.qstats
        flash_pages += -(-q[Tier.FLASH].bytes_moved // PAGE_BYTES)
        dram_bytes_moved += (q[Tier.DRAM].bytes_moved
                             + q[Tier.HBM].bytes_moved)
        total_ios += sum(s.submitted for s in q.values())
    tokens = trace.n_steps * tokens_per_step
    cost = {
        "dram_rent": provisioned_byte_seconds * rates["rent_rate"],
        "dram_wire": dram_bytes_moved * rates["dram_wire_rate"],
        "flash_io": flash_pages * rates["page_io_cost"],
        "host_cpu": total_ios * rates["host_io_cost"],
        "stall": total_stall * alpha_accel,
    }
    total_cost = float(sum(cost.values()))

    advice = platform.advise(horizon=horizon)
    out: Dict[str, object] = {
        "autoscale": bool(autoscale),
        "hosts_start": float(spec.n_hosts),
        "hosts_final": float(fabric.n_hosts),
        "hosts_peak": float(peak_hosts),
        "host_seconds": float(host_seconds),
        "horizon": float(horizon),
        "tokens": float(tokens),
        "first_touches": float(first_touches),
        "total_stall": float(total_stall),
        "per_token_stall": float(total_stall / max(tokens, 1)),
        "cost_total": total_cost,
        "cost_per_token": float(total_cost / max(tokens, 1)),
        "recommended_final": float(advice.recommended_hosts),
        "rebalances": [rb.as_dict() for rb in fabric.rebalances],
    }
    out.update({f"cost_{k}": float(v) for k, v in cost.items()})
    if autoscale and platform._autoscaler is not None:
        out["decisions"] = [d.as_dict()
                            for d in platform._autoscaler.decisions
                            if d.action != "hold"]
    return out


def run_autoscale_bench(spec: Optional[HierarchySpec] = None, *,
                        scenario: str = "diurnal",
                        n_steps: int = 240,
                        step_time: float = 0.25,
                        l_blk: int = 128 << 10,
                        tokens_per_step: int = 16,
                        alpha_accel: float = 4.0,
                        every: int = 10,
                        static_hosts: Optional[int] = None,
                        seed: int = 0,
                        sim_cfg=None) -> Dict[str, object]:
    """Closed loop vs static fleet on one scenario trace.

    The autoscaled arm starts from `spec` (default: the one-host
    `default_autoscale_spec`) and lets `Platform.autoscale` act every
    `every` steps. The static arm runs the identical trace on a fixed
    fleet of `static_hosts` (default: the peak size the loop reached —
    the fleet a peak-provisioner would run all day). Deterministic:
    both arms share the seeded trace and the virtual clock."""
    spec = spec if spec is not None else default_autoscale_spec(
        l_blk, alpha_stall=alpha_accel)
    trace = generate(scenario, n_steps=n_steps, step_time=step_time,
                     seed=seed)
    kw = dict(l_blk=l_blk, step_time=step_time,
              tokens_per_step=tokens_per_step, alpha_accel=alpha_accel,
              every=every, sim_cfg=sim_cfg)
    auto = _run_arm(spec, trace, autoscale=True, **kw)
    n_static = static_hosts if static_hosts is not None \
        else int(auto["hosts_peak"])
    template = spec.hosts[spec.autoscale.template]
    static_spec = dataclasses.replace(
        spec, hosts=(dataclasses.replace(template, count=n_static),))
    static = _run_arm(static_spec, trace, autoscale=False, **kw)
    return {
        "scenario": scenario,
        "params": {"n_steps": n_steps, "step_time": step_time,
                   "l_blk": l_blk, "alpha_accel": alpha_accel,
                   "every": every, "seed": seed,
                   "static_hosts": n_static},
        "autoscaled": auto,
        "static": static,
        "cost_ratio_vs_static": float(
            auto["cost_per_token"]
            / max(static["cost_per_token"], 1e-30)),
        "autoscale_wins": bool(
            auto["cost_per_token"] <= static["cost_per_token"] + 1e-12),
        "final_within_one_of_advice": bool(
            abs(auto["hosts_final"] - auto["recommended_final"]) <= 1),
    }
