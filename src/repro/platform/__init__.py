"""Declarative platform API: `HierarchySpec` -> compiled `Platform`.

One validated spec (per-host tier geometry, fabric topology, policy,
workload priors, clock source) compiles into the whole runtime — clock,
per-host stores with per-host `EconomicGate`s, the capacity-weighted
sharded fabric, and an attached `ProvisionAdvisor` whose recommendation
`Platform.autoscale` turns into `add_host`/`remove_host` actions (the
closed provisioning loop). Specs round-trip through JSON so benchmarks
and CI pin byte-identical scenarios.

    from repro.platform import HierarchySpec, HostDecl, PolicyDecl, Platform
    spec = HierarchySpec(hosts=(HostDecl(count=4),),
                         policy=PolicyDecl.economic(l_blk=128 << 10))
    platform = Platform.compile(spec)
"""
from .autoscale import (AutoscaleDecision, Autoscaler,  # noqa: F401
                        default_autoscale_spec, run_autoscale_bench)
from .compiler import Platform  # noqa: F401
from .failover import default_failover_spec, run_failover_bench  # noqa: F401
from .handles import Handle, KvSession  # noqa: F401
from .roofline_hook import measured_step_time  # noqa: F401
from .spec import (ArrivalDecl, AutoscaleDecl,  # noqa: F401
                   HierarchySpec, HostDecl, NetDecl, ObservabilityDecl,
                   PolicyDecl, PoolDecl, SchedulerDecl, SessionShapeDecl,
                   SloDecl, TenantDecl, TierDecl, TopologyDecl,
                   WorkloadDecl, gpu_flash_tier)
from .workload import (CompiledWorkload, compile_workload,  # noqa: F401
                       tenant_classifier)

__all__ = [
    "ArrivalDecl", "AutoscaleDecision", "AutoscaleDecl", "Autoscaler",
    "CompiledWorkload", "Handle", "HierarchySpec", "HostDecl",
    "KvSession", "NetDecl", "ObservabilityDecl", "Platform",
    "PolicyDecl", "PoolDecl", "SchedulerDecl",
    "SessionShapeDecl", "SloDecl", "TenantDecl", "TierDecl",
    "TopologyDecl", "WorkloadDecl",
    "compile_workload", "default_autoscale_spec", "gpu_flash_tier",
    "default_failover_spec", "measured_step_time",
    "run_autoscale_bench", "run_failover_bench", "tenant_classifier",
]
