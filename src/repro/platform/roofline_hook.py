"""Roofline hook: measured decode step times for the serving runtime.

`DecodeEngine.step_time` models the decode compute that overlaps KV
transfers. On a container it is a declared constant; on real hardware
the dry-run/roofline grid (`benchmarks/roofline_report.py`, results in
`results/dryrun/*__single.json`) already measures the per-step decode
wall-time bound per architecture. `HierarchySpec.step_time="measured"`
closes that loop: the compiled platform pulls `step_time_bound` from
the decode-shape roofline record and falls back to the spec's modeled
constant when no results exist (the wall-clock edge of the
clock-injection contract — nothing below the runtime reads hardware
time directly).
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Optional

ENV_RESULTS = "REPRO_ROOFLINE_RESULTS"

# src/repro/platform/roofline_hook.py -> repo root is parents[3]
_DEFAULT_RESULTS = (pathlib.Path(__file__).resolve().parents[3]
                    / "results" / "dryrun")


def _results_dir(results_dir: Optional[str]) -> pathlib.Path:
    if results_dir is not None:
        return pathlib.Path(results_dir)
    env = os.environ.get(ENV_RESULTS)
    if env:
        return pathlib.Path(env)
    return _DEFAULT_RESULTS


def _step_time_of(path: pathlib.Path) -> Optional[float]:
    try:
        d = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None
    r = d.get("roofline")
    if not isinstance(r, dict):
        return None
    t = r.get("step_time_bound")
    if isinstance(t, (int, float)) and t > 0:
        return float(t)
    return None


def measured_step_time(arch: Optional[str] = None,
                       shape: str = "decode_32k",
                       results_dir: Optional[str] = None
                       ) -> Optional[float]:
    """Measured per-step decode wall time (seconds) from the roofline
    grid, or None when no usable record exists.

    `arch=None` scans every architecture's decode record and takes the
    slowest bound (the conservative fleet-wide overlap budget — a lead
    sized for the slowest step never under-covers a faster one).
    Deterministic: records are read in sorted filename order."""
    root = _results_dir(results_dir)
    if not root.is_dir():
        return None
    pattern = f"{arch}__{shape}__single.json" if arch is not None \
        else f"*__{shape}__single.json"
    times = [t for p in sorted(root.glob(pattern))
             if (t := _step_time_of(p)) is not None]
    if not times:
        return None
    return max(times)
