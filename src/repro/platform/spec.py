"""HierarchySpec — the declarative front door to the tiering runtime.

The paper's thesis is that tiering should fall out of a *declared*
cost/feasibility model, not hand-wired mechanism. After the runtime grew
a fabric, an autopilot and an advisor, standing up a full system still
meant threading a `VirtualClock` through five constructor dialects
(`TieredStore`, `ShardedTieredStore`, `DecodeEngine`, `ExpertStore`,
`EconomicGate`). This module replaces that with one validated spec in
the spec-then-compile style of disaggregated buffer managers:

    spec = HierarchySpec(
        hosts=[HostDecl(dram_gib=256), HostDecl(dram_gib=128, count=3)],
        policy=PolicyDecl.economic(l_blk=128 << 10),
        topology=TopologyDecl(hosts_per_rack=2),
        step_time="measured",            # roofline hook, modeled fallback
    )
    platform = Platform.compile(spec)    # repro.platform.compiler

Everything in a spec is data: `to_json()`/`from_json()` round-trip
byte-exactly, so benchmarks and CI pin scenario specs instead of
constructor call sites. The one escape hatch — `policy` may be a
callable `host_id -> TieringPolicy` factory — is rejected by
`to_json()` with an actionable error, because a factory is code, not a
declaration.

Heterogeneous hosts: each `HostDecl` may carry its own tier geometry
(capacity/bandwidth skew) and the compiled fabric places ring weight
proportional to DRAM capacity (`weighting="capacity"`, the default) so
a host with 2x the DRAM owns ~2x the keys. `weighting="uniform"`
keeps the unweighted ring (the pre-heterogeneity behavior, useful as a
control arm); explicit `weights=[...]` overrides both.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Tuple, Union

from ..core.economics import CPU_DDR, GPU_GDDR, HostConfig
from ..core.policy import Tier, TieringPolicy
from ..core.ssd_model import NAND_TYPES, SsdConfig, storage_next_ssd
from ..runtime.tiers import TierSpec

SPEC_VERSION = 1

_TIER_NAMES = {"hbm": Tier.HBM, "dram": Tier.DRAM, "flash": Tier.FLASH,
               "gpu_flash": Tier.GPU_FLASH}
_HOST_PROFILES: Dict[str, HostConfig] = {"cpu": CPU_DDR, "gpu": GPU_GDDR}

# the TieredStore defaults (v5e-host-like HBM/DRAM + Storage-Next SSD);
# a HostDecl that omits a tier inherits the matching row. "gpu_flash"
# is intentionally absent: the BaM tier exists only when declared (its
# default geometry below mirrors the flash row — same media, different
# access path), so 3-tier hosts compile bit-identically
_DEFAULT_TIERS: Dict[str, Tuple[float, float, float]] = {
    "hbm": (16e9, 819e9, 1e-7),
    "dram": (128e9, 45e9, 5e-7),
    "flash": (4e12, 7e9, 2e-5),
}
_GPU_FLASH_DEFAULT: Tuple[float, float, float] = (4e12, 7e9, 2e-5)


def _err(path: str, msg: str) -> ValueError:
    return ValueError(f"HierarchySpec.{path}: {msg}")


@dataclasses.dataclass(frozen=True)
class TierDecl:
    """One tier's geometry on one host. `write_bw` declares an
    asymmetric write path; None inherits `read_bw` (and is omitted from
    the JSON form, so pre-existing specs stay byte-identical)."""
    capacity_bytes: float
    read_bw: float
    read_latency: float
    write_bw: Optional[float] = None

    def validate(self, path: str):
        if not self.capacity_bytes > 0:
            raise _err(path, f"capacity_bytes must be > 0 (got "
                             f"{self.capacity_bytes!r}); a zero-capacity "
                             f"tier can never hold an object")
        if not self.read_bw > 0:
            raise _err(path, f"read_bw must be > 0 B/s (got "
                             f"{self.read_bw!r})")
        if self.read_latency < 0:
            raise _err(path, f"read_latency must be >= 0 s (got "
                             f"{self.read_latency!r})")
        if self.write_bw is not None and not self.write_bw > 0:
            raise _err(path, f"write_bw must be > 0 B/s when given "
                             f"(got {self.write_bw!r}); omit it to "
                             f"inherit read_bw")


def gpu_flash_tier(**kw) -> TierDecl:
    """A BaM-style GPU-direct flash tier at the default flash geometry
    (same media as the host flash row, different access path); override
    any field via keywords."""
    cap, bw, lat = _GPU_FLASH_DEFAULT
    return TierDecl(**{**dict(capacity_bytes=cap, read_bw=bw,
                              read_latency=lat), **kw})


@dataclasses.dataclass(frozen=True)
class HostDecl:
    """One host class: its tier geometry, ring weight and multiplicity.

    `tiers` maps "hbm"/"dram"/"flash" to a `TierDecl`; omitted tiers
    inherit the runtime defaults. `count` expands the declaration into
    that many identical hosts. `weight` overrides the capacity-derived
    ring weight for these hosts."""
    tiers: Dict[str, TierDecl] = dataclasses.field(default_factory=dict)
    weight: Optional[float] = None
    count: int = 1

    def validate(self, path: str):
        if self.count < 1:
            raise _err(path, f"count must be >= 1 (got {self.count})")
        if self.weight is not None and not self.weight > 0:
            raise _err(path, f"weight must be > 0 (got {self.weight!r})")
        for name, tier in self.tiers.items():
            if name not in _TIER_NAMES:
                raise _err(f"{path}.tiers", f"unknown tier {name!r}; one "
                           f"of {sorted(_TIER_NAMES)}")
            tier.validate(f"{path}.tiers[{name!r}]")

    def dram_capacity(self) -> float:
        decl = self.tiers.get("dram")
        return decl.capacity_bytes if decl is not None \
            else _DEFAULT_TIERS["dram"][0]

    def tier_specs(self) -> Optional[Dict[Tier, TierSpec]]:
        """Compiled per-host TierSpec dict; None when fully default.
        The three base tiers always compile (omitted ones inherit the
        defaults); "gpu_flash" compiles only when declared — a store
        never grows the BaM lane implicitly."""
        if not self.tiers:
            return None
        out: Dict[Tier, TierSpec] = {}
        for name, (cap, bw, lat) in _DEFAULT_TIERS.items():
            decl = self.tiers.get(name)
            wbw = None
            if decl is not None:
                cap, bw, lat, wbw = (decl.capacity_bytes, decl.read_bw,
                                     decl.read_latency, decl.write_bw)
            out[_TIER_NAMES[name]] = TierSpec(cap, bw, lat, write_bw=wbw)
        decl = self.tiers.get("gpu_flash")
        if decl is not None:
            out[Tier.GPU_FLASH] = TierSpec(
                decl.capacity_bytes, decl.read_bw, decl.read_latency,
                write_bw=decl.write_bw)
        return out


@dataclasses.dataclass(frozen=True)
class PolicyDecl:
    """Declarative placement policy.

    kind="static": a plain `TieringPolicy` with pinned thresholds on
    every host. kind="economic": a per-host `EconomicGate` priced from
    the calibrated break-even economics (`host_profile` x `nand` x
    `l_blk`), all gates sharing one fleet-wide `ReuseTracker` so the
    advisor sees the whole workload."""
    kind: str = "economic"
    # static thresholds
    tau_hot: Optional[float] = None
    tau_be: Optional[float] = None
    ema_alpha: float = 0.2
    hysteresis: float = 0.25
    # economic calibration
    host_profile: str = "gpu"
    nand: str = "slc"
    l_blk: int = 128 << 10
    alpha_stall: float = 0.0
    gamma_rw: float = 9.0
    phi_wa: float = 3.0
    prior_quantile: float = 0.5

    KINDS = ("economic", "static")

    def validate(self, path: str = "policy"):
        if self.kind not in self.KINDS:
            raise _err(path, f"unknown policy kind {self.kind!r}; one of "
                             f"{self.KINDS} (or pass a callable "
                             f"host_id -> TieringPolicy factory)")
        if self.kind == "static":
            if self.tau_hot is None or self.tau_be is None:
                raise _err(path, "static policy needs explicit tau_hot "
                                 "and tau_be thresholds")
            if self.tau_hot > self.tau_be:
                raise _err(path, f"tau_hot={self.tau_hot} must be <= "
                                 f"tau_be={self.tau_be}")
        else:
            if self.host_profile not in _HOST_PROFILES:
                raise _err(path, f"unknown host_profile "
                           f"{self.host_profile!r}; one of "
                           f"{sorted(_HOST_PROFILES)}")
            if self.nand not in NAND_TYPES:
                raise _err(path, f"unknown nand {self.nand!r}; one of "
                           f"{sorted(NAND_TYPES)}")
            if self.l_blk < 1:
                raise _err(path, f"l_blk must be >= 1 byte "
                                 f"(got {self.l_blk})")

    # ------------------------------------------------------- constructors
    @classmethod
    def static(cls, tau_hot: float, tau_be: float, **kw) -> "PolicyDecl":
        return cls(kind="static", tau_hot=tau_hot, tau_be=tau_be, **kw)

    @classmethod
    def pinned_flash(cls) -> "PolicyDecl":
        """Everything stays on flash — the restore-path benchmark policy."""
        return cls.static(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)

    @classmethod
    def pinned_dram(cls) -> "PolicyDecl":
        """Everything wants DRAM; only capacity pressure demotes."""
        return cls.static(tau_hot=1e-12, tau_be=1e12)

    @classmethod
    def economic(cls, **kw) -> "PolicyDecl":
        return cls(kind="economic", **kw)

    # ----------------------------------------------------------- compile
    def economics(self) -> Tuple[HostConfig, SsdConfig]:
        return (_HOST_PROFILES[self.host_profile],
                storage_next_ssd(NAND_TYPES[self.nand]))


@dataclasses.dataclass(frozen=True)
class PoolDecl:
    """The fleet-shared disaggregated far-memory pool
    (`runtime.pool.PooledStore`): one DRAM-class slab every host
    reaches over a per-host RTT lane, rented at `rent_factor` of the
    local DRAM rate (statistical multiplexing of uncorrelated per-host
    peaks pays the discount). The compiler wires the pool into the
    fabric (gate-admitted between local-DRAM miss and remote-flash
    fetch) and prices its Eq. 1 column from these numbers."""
    capacity_bytes: float
    read_bw: float = 40e9
    write_bw: Optional[float] = None
    rtt: float = 2e-6
    sat_depth: int = 4
    rent_factor: float = 0.5
    alpha_net: float = 2.0

    def validate(self, path: str = "pool"):
        if not self.capacity_bytes > 0:
            raise _err(path, f"capacity_bytes must be > 0 (got "
                             f"{self.capacity_bytes!r})")
        if not self.read_bw > 0:
            raise _err(path, f"read_bw must be > 0 B/s (got "
                             f"{self.read_bw!r})")
        if self.write_bw is not None and not self.write_bw > 0:
            raise _err(path, f"write_bw must be > 0 B/s when given "
                             f"(got {self.write_bw!r}); omit it to "
                             f"inherit read_bw")
        if self.rtt < 0:
            raise _err(path, f"rtt must be >= 0 s (got {self.rtt!r})")
        if self.sat_depth < 1:
            raise _err(path, f"sat_depth must be >= 1 (got "
                             f"{self.sat_depth})")
        if not 0.0 < self.rent_factor < 1.0:
            raise _err(path, f"rent_factor must be in (0, 1) (got "
                             f"{self.rent_factor!r}): 0 rents the pool "
                             f"for free, 1 at the full local-DRAM rate "
                             f"— neither is a pool")
        if self.alpha_net <= 0:
            raise _err(path, f"alpha_net must be positive (got "
                             f"{self.alpha_net!r})")


@dataclasses.dataclass(frozen=True)
class TopologyDecl:
    """Rack/spine descriptor, compiled to `runtime.service.FabricTopology`."""
    hosts_per_rack: int = 4
    rack_rtt: float = 15e-6
    spine_rtt: float = 40e-6
    rack_bandwidth: float = 12.5e9
    spine_bandwidth: float = 6.25e9
    incast_degree: int = 2

    def validate(self, path: str = "topology"):
        try:
            self.compile()
        except ValueError as e:
            raise _err(path, str(e)) from e

    def compile(self):
        from ..runtime.service import FabricTopology
        return FabricTopology(**dataclasses.asdict(self))


@dataclasses.dataclass(frozen=True)
class NetDecl:
    """Uniform NIC-link parameters (`runtime.service.NetQueueModel`)."""
    rtt: float = 25e-6
    bandwidth: float = 12.5e9
    sat_depth: int = 4

    def validate(self, path: str = "net"):
        if self.rtt < 0 or self.bandwidth <= 0 or self.sat_depth < 1:
            raise _err(path, f"invalid NIC parameters "
                             f"{dataclasses.asdict(self)}")


@dataclasses.dataclass(frozen=True)
class AutoscaleDecl:
    """Closed provisioning loop: bounds and pacing for
    `Platform.autoscale` (advisor-driven `add_host`/`remove_host`)."""
    min_hosts: int = 1
    max_hosts: int = 8
    cooldown_steps: int = 8
    template: int = 0           # index into spec.hosts for new hosts
    active_window: Optional[float] = None   # advisor hot-set staleness (s)

    def validate(self, path: str = "autoscale"):
        if self.min_hosts < 1:
            raise _err(path, f"min_hosts must be >= 1 (got "
                             f"{self.min_hosts})")
        if self.max_hosts < self.min_hosts:
            raise _err(path, f"max_hosts={self.max_hosts} < "
                             f"min_hosts={self.min_hosts}")
        if self.cooldown_steps < 0:
            raise _err(path, "cooldown_steps must be >= 0")
        if self.active_window is not None and self.active_window <= 0:
            raise _err(path, "active_window must be positive seconds")


@dataclasses.dataclass(frozen=True)
class SchedulerDecl:
    """Continuous-batching scheduler knobs
    (`repro.serving.ContinuousScheduler`, built via
    `Platform.scheduler`).

    `pause_idle_steps`: inter-turn gaps of at most this many decode
    steps keep a session *parked* in its slot (resident, not decoding);
    longer gaps offload the KV through the tiered store — the paper's
    break-even decision point. 0 always offloads.
    `prefetch_lead`: "p99" sizes each paused session's restore prefetch
    from the serving tier's calibrated tail latency; an integer is a
    fixed lead in decode steps; 0 disables prefetch."""
    pause_idle_steps: int = 0
    prefetch_lead: Union[int, str] = "p99"

    def validate(self, path: str = "scheduler"):
        if self.pause_idle_steps < 0:
            raise _err(path, f"pause_idle_steps must be >= 0 (got "
                             f"{self.pause_idle_steps})")
        if isinstance(self.prefetch_lead, str):
            if self.prefetch_lead != "p99":
                raise _err(path, f"prefetch_lead must be 'p99' or a "
                           f"step count (got {self.prefetch_lead!r})")
        elif self.prefetch_lead < 0:
            raise _err(path, f"prefetch_lead must be >= 0 steps (got "
                             f"{self.prefetch_lead})")


@dataclasses.dataclass(frozen=True)
class ObservabilityDecl:
    """Observability plane knobs (`repro.obs.Observability`, attached
    by `Platform.compile`).

    `metrics` keeps the array-backed `MetricsRegistry` on (counters,
    gauges, log-bucket histograms; cheap enough for the 1M-key replay).
    `trace` turns on the causal `Tracer` — Perfetto/Chrome trace_event
    export of the full request lifecycle on the modeled clock — capped
    at `max_events` non-metadata events. The Eq. 1 stall ledger is
    *not* declared here: it is always on."""
    trace: bool = False
    metrics: bool = True
    max_events: int = 200_000

    def validate(self, path: str = "observability"):
        if self.max_events < 1:
            raise _err(path, f"max_events must be >= 1 (got "
                             f"{self.max_events})")


@dataclasses.dataclass(frozen=True)
class ArrivalDecl:
    """When a tenant's sessions (and background objects) show up.

    `kind` shapes a per-step arrival intensity over the workload
    horizon: "stationary" is flat, "scan_flood" is a low baseline with
    periodic full-rate bursts (`period`/`burst_len`), "diurnal" is a
    raised-cosine day curve (`period` = one day in steps), and
    "flash_crowd" is a low baseline with one spike of `burst_len` steps
    centered on `peak_step` (default mid-horizon). Session start steps
    are drawn from the normalized intensity; `background_per_step`
    objects per step (scaled by the same intensity) model side traffic —
    drawn zipf-`background_zipf` from a `background_pool` keyspace, or
    fresh one-touch keys when the pool is 0 (the scan shape)."""
    kind: str = "stationary"
    period: int = 48
    burst_len: int = 8
    peak_step: Optional[int] = None
    baseline: float = 0.1
    background_per_step: int = 0
    background_pool: int = 0
    background_zipf: float = 3.0

    KINDS = ("stationary", "scan_flood", "diurnal", "flash_crowd")

    def validate(self, path: str):
        if self.kind not in self.KINDS:
            raise _err(path, f"unknown arrival kind {self.kind!r}; one "
                             f"of {self.KINDS}")
        if self.period < 1 or self.burst_len < 1:
            raise _err(path, f"period/burst_len must be >= 1 step (got "
                             f"{self.period}/{self.burst_len})")
        if self.peak_step is not None and self.peak_step < 0:
            raise _err(path, "peak_step must be >= 0")
        if not 0.0 <= self.baseline <= 1.0:
            raise _err(path, f"baseline must be in [0, 1] (got "
                             f"{self.baseline!r})")
        if self.background_per_step < 0 or self.background_pool < 0:
            raise _err(path, "background_per_step/background_pool must "
                             "be >= 0")
        if self.background_zipf <= 0:
            raise _err(path, "background_zipf must be positive")

    def intensity(self, n_steps: int) -> "np.ndarray":
        """Per-step arrival mass over `n_steps`, values in (0, 1]."""
        import numpy as np
        t = np.arange(n_steps)
        if self.kind == "stationary":
            return np.ones(n_steps)
        if self.kind == "scan_flood":
            mass = np.full(n_steps, self.baseline)
            mass[(t % self.period) < self.burst_len] = 1.0
            return mass
        if self.kind == "diurnal":
            day = 0.5 - 0.5 * np.cos(2 * np.pi * t / max(self.period, 1))
            return self.baseline + (1.0 - self.baseline) * day
        # flash_crowd
        peak = self.peak_step if self.peak_step is not None \
            else n_steps // 2
        mass = np.full(n_steps, self.baseline)
        half = self.burst_len // 2
        mass[max(0, peak - half):peak + self.burst_len - half] = 1.0
        return mass


@dataclasses.dataclass(frozen=True)
class SessionShapeDecl:
    """One session class: turn count, token/prompt shape and think-time.

    `gap_steps` is the declared mean inter-turn think gap in decode
    steps (jittered by `gap_jitter`); it doubles as the tenant's
    declared reuse interval, which `Platform.compile` seeds into the
    `ReuseTracker` prior so a tenant's very first KV offload is priced
    from its declaration instead of the cold default.
    `extra_keys_per_turn` models per-turn side reads (RAG corpus
    lookups when `extra_key_pool` > 0, fresh scan keys when 0)."""
    n_turns: int = 3
    tokens_per_turn: int = 6
    prompt_len: int = 5
    gap_steps: int = 4
    gap_jitter: float = 0.5
    extra_keys_per_turn: int = 0
    extra_key_pool: int = 0
    extra_zipf: float = 1.5

    def validate(self, path: str):
        if self.n_turns < 1:
            raise _err(path, f"n_turns must be >= 1 (got {self.n_turns})")
        if self.tokens_per_turn < 1:
            raise _err(path, f"tokens_per_turn must be >= 1 (got "
                             f"{self.tokens_per_turn})")
        if self.prompt_len < 1:
            raise _err(path, f"prompt_len must be >= 1 (got "
                             f"{self.prompt_len})")
        if self.gap_steps < 1:
            raise _err(path, f"gap_steps must be >= 1 (got "
                             f"{self.gap_steps})")
        if not 0.0 <= self.gap_jitter < 1.0:
            raise _err(path, f"gap_jitter must be in [0, 1) (got "
                             f"{self.gap_jitter!r})")
        if self.extra_keys_per_turn < 0 or self.extra_key_pool < 0:
            raise _err(path, "extra_keys_per_turn/extra_key_pool must "
                             "be >= 0")
        if self.extra_zipf <= 0:
            raise _err(path, "extra_zipf must be positive")

    # ------------------------------------------------- session-class presets
    @classmethod
    def chat(cls, **kw) -> "SessionShapeDecl":
        """Interactive multi-turn chat: short gaps, modest tokens."""
        return cls(**{**dict(n_turns=3, tokens_per_turn=6, prompt_len=5,
                             gap_steps=3), **kw})

    @classmethod
    def rag(cls, **kw) -> "SessionShapeDecl":
        """Retrieval-augmented: long prompts + per-turn corpus reads."""
        return cls(**{**dict(n_turns=2, tokens_per_turn=8, prompt_len=12,
                             gap_steps=5, extra_keys_per_turn=4,
                             extra_key_pool=256), **kw})

    @classmethod
    def moe_heavy(cls, **kw) -> "SessionShapeDecl":
        """Expert-heavy decode: long generations, sparse turns."""
        return cls(**{**dict(n_turns=2, tokens_per_turn=16, prompt_len=6,
                             gap_steps=8), **kw})

    @classmethod
    def scan(cls, **kw) -> "SessionShapeDecl":
        """Scan adversary: short decodes, long think gaps, a stream of
        fresh one-touch side keys."""
        return cls(**{**dict(n_turns=2, tokens_per_turn=2, prompt_len=3,
                             gap_steps=24, gap_jitter=0.25,
                             extra_keys_per_turn=8, extra_key_pool=0),
                      **kw})


@dataclasses.dataclass(frozen=True)
class SloDecl:
    """Per-tenant service objective, priced into the gate.

    `alpha_stall` is the paper's stalled-engine rent multiplier: it is
    folded into this tenant's *own* `tau_be` via
    `EconomicGate.from_break_even`, so a premium tenant's stall rents
    DRAM harder than a batch tenant's. `deadline_steps` bounds turn
    admission lateness; `p99_stall_budget` (seconds of stall per
    generated token, p99 across the tenant's sessions) is the isolation
    assertion's budget — None declares no budget."""
    deadline_steps: int = 8
    p99_stall_budget: Optional[float] = None
    alpha_stall: float = 0.0

    def validate(self, path: str):
        if self.deadline_steps < 0:
            raise _err(path, f"deadline_steps must be >= 0 (got "
                             f"{self.deadline_steps})")
        if self.p99_stall_budget is not None \
                and self.p99_stall_budget <= 0:
            raise _err(path, "p99_stall_budget must be positive seconds "
                             "per token (omit it to declare no budget)")
        if self.alpha_stall < 0:
            raise _err(path, f"alpha_stall must be >= 0 (got "
                             f"{self.alpha_stall!r})")


@dataclasses.dataclass(frozen=True)
class TenantDecl:
    """One tenant: a named session population with an arrival process
    and an SLO. The tenant name becomes the reuse-tracking class for
    its KV keys (session ids are `"{name}/NNN"`), so priors, quantiles
    and gate thresholds are all per-tenant."""
    name: str
    n_sessions: int = 4
    session: SessionShapeDecl = SessionShapeDecl()
    arrival: ArrivalDecl = ArrivalDecl()
    slo: SloDecl = SloDecl()

    def validate(self, path: str):
        if not self.name or "/" in self.name:
            raise _err(path, f"tenant name must be a non-empty string "
                             f"without '/' (got {self.name!r}); '/' "
                             f"separates the tenant from the session id")
        if self.n_sessions < 0:
            raise _err(path, f"n_sessions must be >= 0 (got "
                             f"{self.n_sessions})")
        self.session.validate(f"{path}.session")
        self.arrival.validate(f"{path}.arrival")
        self.slo.validate(f"{path}.slo")


@dataclasses.dataclass(frozen=True)
class WorkloadDecl:
    """A declared multi-tenant scenario: who arrives when, with what
    session shape, under which SLO. Compiled by
    `repro.platform.workload.compile_workload` into deterministic
    `SessionJob` lists for the continuous scheduler, access traces for
    the autopilot benches, and per-tenant `EconomicGate` thresholds —
    one JSON artifact end-to-end.

    `isolation="per-tenant"` gives every tenant its own tau_be (its
    `alpha_stall` folded in) and seeds its declared reuse prior;
    `"shared"` compiles the pack against one fleet-wide threshold and
    class (the pre-WorkloadDecl behavior — the control arm the
    isolation benchmark compares against)."""
    tenants: Tuple[TenantDecl, ...] = ()
    horizon_steps: int = 96
    seed: int = 0
    isolation: str = "per-tenant"

    ISOLATION = ("per-tenant", "shared")

    def __post_init__(self):
        if isinstance(self.tenants, list):
            object.__setattr__(self, "tenants", tuple(self.tenants))

    def validate(self, path: str = "workload"):
        if not self.tenants:
            raise _err(f"{path}.tenants", "need at least one tenant")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise _err(f"{path}.tenants", f"tenant names must be unique "
                       f"(got {names})")
        for i, t in enumerate(self.tenants):
            if not isinstance(t, TenantDecl):
                raise _err(f"{path}.tenants[{i}]", f"expected TenantDecl,"
                           f" got {type(t).__name__}")
            t.validate(f"{path}.tenants[{i}]")
        if self.horizon_steps < 1:
            raise _err(f"{path}.horizon_steps", f"must be >= 1 (got "
                       f"{self.horizon_steps})")
        if self.isolation not in self.ISOLATION:
            raise _err(f"{path}.isolation", f"unknown mode "
                       f"{self.isolation!r}; one of {self.ISOLATION}")

    @staticmethod
    def from_dict(d: Dict) -> "WorkloadDecl":
        """Reconstruct from a JSON-decoded dict (nested decls included)."""
        tenants = tuple(
            TenantDecl(name=t["name"],
                       n_sessions=t.get("n_sessions", 4),
                       session=SessionShapeDecl(**t.get("session", {})),
                       arrival=ArrivalDecl(**t.get("arrival", {})),
                       slo=SloDecl(**t.get("slo", {})))
            for t in d.get("tenants", []))
        return WorkloadDecl(
            tenants=tenants,
            horizon_steps=d.get("horizon_steps", 96),
            seed=d.get("seed", 0),
            isolation=d.get("isolation", "per-tenant"))


PolicyLike = Union[PolicyDecl, Callable[[int], TieringPolicy]]


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """The whole platform, declared: hosts (possibly heterogeneous),
    fabric topology, policy, workload priors and clock source. Compile
    with `repro.platform.Platform.compile`."""
    hosts: Tuple[HostDecl, ...] = (HostDecl(),)
    policy: PolicyLike = PolicyDecl()
    weighting: str = "capacity"             # capacity | uniform
    weights: Optional[Tuple[float, ...]] = None
    topology: Optional[TopologyDecl] = None
    net: Optional[NetDecl] = None
    pool: Optional[PoolDecl] = None
    clock: str = "virtual"                  # virtual | wall
    t0: float = 0.0
    step_time: Union[float, str] = 0.0      # seconds | "measured"
    step_time_fallback: float = 2e-3
    roofline_arch: Optional[str] = None
    roofline_shape: str = "decode_32k"
    roofline_results: Optional[str] = None  # results dir override
    class_priors: Dict[str, float] = dataclasses.field(
        default_factory=dict)               # class -> assumed interval (s)
    replicas: int = 1
    vnodes: int = 64
    write_shield_depth: Optional[int] = None
    rebalance_rate: Optional[float] = None
    mttf: Optional[float] = None            # seconds/host (availability)
    checkpoint_interval: Optional[float] = None     # seconds between
    #                                 engine session checkpoints (None=off)
    autoscale: AutoscaleDecl = AutoscaleDecl()
    scheduler: SchedulerDecl = SchedulerDecl()
    observability: ObservabilityDecl = ObservabilityDecl()
    workload: Optional[WorkloadDecl] = None

    def __post_init__(self):
        # normalize list inputs (JSON round-trip hands us lists)
        if isinstance(self.hosts, list):
            object.__setattr__(self, "hosts", tuple(self.hosts))
        if isinstance(self.weights, list):
            object.__setattr__(self, "weights", tuple(self.weights))

    # ----------------------------------------------------------- validate
    def validate(self) -> "HierarchySpec":
        if not self.hosts:
            raise _err("hosts", "need at least one host declaration")
        for i, h in enumerate(self.hosts):
            if not isinstance(h, HostDecl):
                raise _err(f"hosts[{i}]", f"expected HostDecl, got "
                                          f"{type(h).__name__}")
            h.validate(f"hosts[{i}]")
        if callable(self.policy):
            pass                        # programmatic factory, trusted
        elif isinstance(self.policy, PolicyDecl):
            self.policy.validate()
        else:
            raise _err("policy", f"expected PolicyDecl or a callable "
                       f"host_id -> TieringPolicy factory, got "
                       f"{type(self.policy).__name__}")
        if self.weighting not in ("capacity", "uniform"):
            raise _err("weighting", f"unknown weighting "
                       f"{self.weighting!r}; one of ('capacity', "
                       f"'uniform')")
        if self.weights is not None:
            if len(self.weights) != self.n_hosts:
                raise _err("weights", f"{len(self.weights)} ring weights "
                           f"for {self.n_hosts} hosts; lengths must "
                           f"match")
            if any(not w > 0 for w in self.weights):
                raise _err("weights", "ring weights must be positive")
        if self.topology is not None:
            self.topology.validate()
        if self.net is not None:
            self.net.validate()
        if self.pool is not None:
            if not isinstance(self.pool, PoolDecl):
                raise _err("pool", f"expected PoolDecl, got "
                                   f"{type(self.pool).__name__}")
            self.pool.validate()
        if self.clock not in ("virtual", "wall"):
            raise _err("clock", f"unknown clock source {self.clock!r}; "
                       f"one of ('virtual', 'wall')")
        if isinstance(self.step_time, str):
            if self.step_time != "measured":
                raise _err("step_time", f"expected seconds or "
                           f"'measured', got {self.step_time!r}")
        elif self.step_time < 0:
            raise _err("step_time", "step_time must be >= 0 seconds")
        if self.step_time_fallback < 0:
            raise _err("step_time_fallback", "must be >= 0 seconds")
        for cls, iv in self.class_priors.items():
            if not iv > 0:
                raise _err(f"class_priors[{cls!r}]",
                           f"prior interval must be positive seconds "
                           f"(got {iv!r})")
        if self.replicas < 1:
            raise _err("replicas", f"must be >= 1 (got {self.replicas})")
        if self.vnodes < 1:
            raise _err("vnodes", f"must be >= 1 (got {self.vnodes})")
        if self.write_shield_depth is not None \
                and self.write_shield_depth < 1:
            raise _err("write_shield_depth", "must be >= 1 (a zero "
                       "threshold would shield forever)")
        if self.rebalance_rate is not None and self.rebalance_rate <= 0:
            raise _err("rebalance_rate", "must be positive bytes/s")
        if self.mttf is not None and self.mttf <= 0:
            raise _err("mttf", "must be positive seconds per host")
        if self.checkpoint_interval is not None \
                and self.checkpoint_interval <= 0:
            raise _err("checkpoint_interval", "must be positive seconds "
                       "(omit it to disable checkpointing)")
        self.autoscale.validate()
        self.scheduler.validate()
        self.observability.validate()
        if self.workload is not None:
            if not isinstance(self.workload, WorkloadDecl):
                raise _err("workload", f"expected WorkloadDecl, got "
                                       f"{type(self.workload).__name__}")
            self.workload.validate()
        if not 0 <= self.autoscale.template < len(self.hosts):
            raise _err("autoscale.template", f"host index "
                       f"{self.autoscale.template} out of range for "
                       f"{len(self.hosts)} host declaration(s)")
        return self

    # ------------------------------------------------------------ derived
    @property
    def n_hosts(self) -> int:
        return sum(h.count for h in self.hosts)

    def expanded_hosts(self) -> List[HostDecl]:
        """One entry per physical host (counts unrolled)."""
        out: List[HostDecl] = []
        for h in self.hosts:
            out.extend([h] * h.count)
        return out

    def resolved_weights(self) -> List[float]:
        """Ring weight per physical host: explicit `weights` list, else
        per-host `weight` overrides on top of the weighting mode
        (capacity: DRAM capacity normalized so the smallest host is 1.0
        — homogeneous fleets reproduce the unweighted ring exactly;
        uniform: all 1.0)."""
        hosts = self.expanded_hosts()
        if self.weights is not None:
            return [float(w) for w in self.weights]
        if self.weighting == "uniform":
            base = [1.0] * len(hosts)
        else:
            caps = [h.dram_capacity() for h in hosts]
            lo = min(caps)
            base = [c / lo for c in caps]
        return [h.weight if h.weight is not None else w
                for h, w in zip(hosts, base)]

    def resolved_step_time(self) -> float:
        """Seconds of modeled decode compute per step; `"measured"`
        resolves through the roofline hook (falling back to
        `step_time_fallback` off-hardware)."""
        if self.step_time == "measured":
            from .roofline_hook import measured_step_time
            t = measured_step_time(arch=self.roofline_arch,
                                   shape=self.roofline_shape,
                                   results_dir=self.roofline_results)
            return float(t) if t is not None else self.step_time_fallback
        return float(self.step_time)

    # --------------------------------------------------------------- json
    def to_json(self, indent: Optional[int] = None) -> str:
        """Serialize; byte-stable (sorted keys) so CI can pin specs.
        Raises for a callable policy — a factory is code, not data."""
        if callable(self.policy) and not isinstance(self.policy,
                                                    PolicyDecl):
            raise ValueError(
                "HierarchySpec.policy is a callable factory and cannot "
                "be serialized; declare it as a PolicyDecl (kind="
                "'economic' or 'static') to make the spec round-trip")
        d = dataclasses.asdict(self)
        d["version"] = SPEC_VERSION
        # inherit-markers are omitted, not serialized as null, so specs
        # written before the field existed stay byte-identical
        if d.get("pool") is None:
            d.pop("pool", None)
        for h in d.get("hosts", []):
            for t in h.get("tiers", {}).values():
                if t.get("write_bw") is None:
                    t.pop("write_bw", None)
        return json.dumps(d, sort_keys=True, indent=indent)

    @classmethod
    def from_json(cls, blob: str) -> "HierarchySpec":
        """Parse + validate; `from_json(to_json(spec)) == spec`."""
        try:
            d = json.loads(blob)
        except json.JSONDecodeError as e:
            raise ValueError(f"HierarchySpec JSON is not valid JSON: "
                             f"{e}") from e
        if not isinstance(d, dict):
            raise ValueError("HierarchySpec JSON must be an object")
        version = d.pop("version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise ValueError(f"HierarchySpec version {version} not "
                             f"supported (this build reads "
                             f"{SPEC_VERSION})")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise ValueError(f"HierarchySpec JSON has unknown fields "
                             f"{unknown}; known fields are "
                             f"{sorted(known)}")
        hosts = tuple(
            HostDecl(tiers={name: TierDecl(**t)
                            for name, t in h.get("tiers", {}).items()},
                     weight=h.get("weight"), count=h.get("count", 1))
            for h in d.pop("hosts", [{}]))
        policy = d.pop("policy", None)
        policy = PolicyDecl(**policy) if policy is not None \
            else PolicyDecl()
        topology = d.pop("topology", None)
        topology = TopologyDecl(**topology) if topology is not None \
            else None
        net = d.pop("net", None)
        net = NetDecl(**net) if net is not None else None
        pool = d.pop("pool", None)
        pool = PoolDecl(**pool) if pool is not None else None
        autoscale = d.pop("autoscale", None)
        autoscale = AutoscaleDecl(**autoscale) if autoscale is not None \
            else AutoscaleDecl()
        scheduler = d.pop("scheduler", None)
        scheduler = SchedulerDecl(**scheduler) if scheduler is not None \
            else SchedulerDecl()
        observability = d.pop("observability", None)
        observability = ObservabilityDecl(**observability) \
            if observability is not None else ObservabilityDecl()
        workload = d.pop("workload", None)
        workload = WorkloadDecl.from_dict(workload) \
            if workload is not None else None
        weights = d.pop("weights", None)
        spec = cls(hosts=hosts, policy=policy, topology=topology,
                   net=net, pool=pool, autoscale=autoscale,
                   scheduler=scheduler, observability=observability,
                   workload=workload,
                   weights=tuple(weights) if weights is not None
                   else None, **d)
        return spec.validate()
