"""Kill-a-host-at-diurnal-peak: the availability benchmark.

The paper's economics price DRAM rent against flash IO; this bench
prices *availability*. Three arms replay the same seeded diurnal trace
on the same three-host fleet, differing only in replication factor
r in {1, 2, 3}. At the diurnal peak the busiest host dies unplanned
(`fabric.fail_host` — no drain), the repair loop re-replicates what
survived under the rebalance pacer, and the replay continues through
recovery:

  * a committed key with a surviving replica degrades to a remote read
    (the stall is measured on the shared virtual clock);
  * a committed key whose only copy died is *lost* — its next touch
    pays a modeled recompute stall and re-puts it;
  * in-flight decode sessions checkpoint their KV blob every
    `checkpoint_every` steps (the `DecodeEngine.checkpoint_interval`
    behavior, replayed here at trace scale). A session homed on the
    victim resumes from its last checkpoint on a surviving holder —
    paying the restore fetch plus regeneration of the tokens since the
    checkpoint — or, with no surviving blob, restarts from scratch.

Costs use the same normalized rates as every other cost-reporting
bench (`autopilot.bench.pricing_rates`): DRAM rent on provisioned
capacity, wire bytes, flash pages, host CPU, and stalled-engine time at
`alpha_accel`. The acceptance criterion (asserted in tests, reported by
`benchmarks/serving_autopilot.py --failover`): with r >= 2 zero
committed keys are lost and every session resumes, and the advisor's
recommended replication factor (`advise_availability` under the bench's
MTTF) beats both r=1 and r=3 on measured $/token.

Deterministic by construction: seeded trace, one `VirtualClock` per
arm, deterministic victim selection (max resident bytes, ties to the
smallest id) — the emitted JSON is byte-identical across runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..autopilot.bench import PAGE_BYTES, pricing_rates
from ..autopilot.traces import generate
from ..core.policy import Tier
from ..runtime.repair import RepairLoop
from .spec import HierarchySpec, HostDecl, PolicyDecl, TierDecl


def default_failover_spec(l_blk: int = 128 << 10, *,
                          n_hosts: int = 4,
                          alpha_stall: float = 4.0,
                          dram_blocks_per_host: int = 20,
                          rebalance_rate: Optional[float] = 2e9,
                          replicas: int = 1) -> HierarchySpec:
    """A homogeneous fleet sized like the autoscale bench's hosts; the
    bench swaps `replicas` per arm."""
    host = HostDecl(count=n_hosts, tiers={
        "hbm": TierDecl(2 * l_blk, 819e9, 1e-7),
        "dram": TierDecl(dram_blocks_per_host * l_blk, 45e9, 5e-7),
        "flash": TierDecl(1 << 34, 7e9, 2e-5),
    })
    return HierarchySpec(
        hosts=(host,),
        policy=PolicyDecl.economic(l_blk=l_blk, alpha_stall=alpha_stall),
        rebalance_rate=rebalance_rate,
        replicas=replicas)


def _busiest_host(fabric) -> int:
    """Deterministic victim: most resident bytes, ties to smallest id."""
    loads = {h: sum(s.used_bytes(t) for t in Tier)
             for h, s in sorted(fabric.hosts.items())}
    return max(sorted(loads), key=lambda h: loads[h])


def _run_failover_arm(spec: HierarchySpec, trace, *, replicas: int,
                      l_blk: int, step_time: float,
                      tokens_per_step: int, alpha_accel: float,
                      kill_step: int, n_sessions: int,
                      checkpoint_every: int,
                      lost_recompute_seconds: float,
                      sim_cfg=None) -> Dict[str, object]:
    from .compiler import Platform
    spec = dataclasses.replace(spec, replicas=replicas)
    platform = Platform.compile(spec, sim_cfg=sim_cfg)
    fabric, clock = platform.fabric, platform.clock
    host_cfg, ssd = spec.policy.economics()
    blob = np.zeros(max(l_blk // 4, 1), np.float32)

    # in-flight decode sessions, replayed at trace scale: one KV blob
    # each, re-put (checkpointed) every `checkpoint_every` steps from
    # its home host — the DecodeEngine.checkpoint_interval behavior
    sessions = [("sess", i) for i in range(n_sessions)]
    sess_home = {s: fabric.owner(s) for s in sessions}
    sess_ckpt_step = {s: 0 for s in sessions}
    for s in sessions:
        fabric.put(s, blob, tier=Tier.DRAM, from_host=sess_home[s],
                   replicas=replicas)

    total_stall = 0.0
    first_touches = 0
    put_bytes = float(n_sessions * blob.nbytes)
    provisioned_byte_seconds = 0.0
    committed: set = set(sessions)
    lost_key_stalls = 0
    report = None
    repair = None
    recovery_seconds = 0.0
    committed_lost = 0
    sessions_lost = 0
    sessions_resumed = 0
    last_t = clock.now()

    for t, step in enumerate(trace.steps):
        if t == kill_step:
            victim = _busiest_host(fabric)
            report = fabric.fail_host(victim)
            committed_lost = sum(1 for k in report.lost_keys
                                 if k in committed)
            repair = RepairLoop(fabric).run()
            recovery_seconds = max(0.0, repair.t_done - report.t_fail)
            # failover: sessions homed on the victim resume from their
            # last checkpoint on a surviving holder, or restart
            for s in sessions:
                if sess_home[s] != victim:
                    continue
                new_home = fabric.preferred_host(s)
                if new_home is not None:
                    t0 = clock.now()
                    fabric.get(s, from_host=new_home)
                    # restore fetch + regenerate tokens lost since the
                    # last checkpoint (greedy decode is deterministic)
                    total_stall += (clock.now() - t0
                                    + (t - sess_ckpt_step[s]) * step_time)
                    sessions_resumed += 1
                else:
                    # torn session: no surviving blob, full restart
                    total_stall += t * step_time
                    sessions_lost += 1
                    fabric.put(s, blob, tier=Tier.DRAM,
                               from_host=fabric.owner(s),
                               replicas=replicas)
                    put_bytes += blob.nbytes
                sess_home[s] = fabric.owner(s)
                sess_ckpt_step[s] = t
        for key in step:
            h = fabric.owner(key)
            if fabric.tier_of(key) is None:
                if key in committed:
                    # committed key lost to the failure: its next touch
                    # pays the modeled recompute before the re-put
                    lost_key_stalls += 1
                    total_stall += lost_recompute_seconds
                fabric.put(key, blob, tier=Tier.DRAM, from_host=h,
                           replicas=replicas)
                first_touches += 1
                put_bytes += blob.nbytes
                committed.add(key)
            else:
                t0 = clock.now()
                fabric.get(key, from_host=h)
                total_stall += clock.now() - t0
        if checkpoint_every and (t + 1) % checkpoint_every == 0:
            for s in sessions:
                fabric.put(s, blob, tier=Tier.DRAM,
                           from_host=sess_home[s], replicas=replicas)
                put_bytes += blob.nbytes
                sess_ckpt_step[s] = t + 1
        clock.advance(step_time)
        now = clock.now()
        dt = now - last_t
        for store in fabric.hosts.values():
            provisioned_byte_seconds += \
                store.specs[Tier.DRAM].capacity_bytes * dt
        last_t = now
    horizon = clock.now()
    platform.drain()

    # ------------------------------------------------------- cost model
    rates = pricing_rates(host_cfg, ssd)
    flash_pages = 0
    dram_bytes_moved = 0
    total_ios = 0
    for store in fabric._all_stores():
        q = store.runtime.qstats
        flash_pages += -(-q[Tier.FLASH].bytes_moved // PAGE_BYTES)
        dram_bytes_moved += (q[Tier.DRAM].bytes_moved
                             + q[Tier.HBM].bytes_moved)
        total_ios += sum(s.submitted for s in q.values())
    tokens = trace.n_steps * tokens_per_step
    cost = {
        "dram_rent": provisioned_byte_seconds * rates["rent_rate"],
        "dram_wire": dram_bytes_moved * rates["dram_wire_rate"],
        "flash_io": flash_pages * rates["page_io_cost"],
        "host_cpu": total_ios * rates["host_io_cost"],
        "stall": total_stall * alpha_accel,
    }
    total_cost = float(sum(cost.values()))

    out: Dict[str, object] = {
        "replicas": float(replicas),
        "horizon": float(horizon),
        "tokens": float(tokens),
        "first_touches": float(first_touches),
        "put_bytes": float(put_bytes),
        "total_stall": float(total_stall),
        "per_token_stall": float(total_stall / max(tokens, 1)),
        "cost_total": total_cost,
        "cost_per_token": float(total_cost / max(tokens, 1)),
        "recovery_seconds": float(recovery_seconds),
        "committed_keys_lost": float(committed_lost),
        "lost_key_stalls": float(lost_key_stalls),
        "sessions": float(n_sessions),
        "sessions_resumed": float(sessions_resumed),
        "sessions_lost": float(sessions_lost),
        "remote_puts": float(fabric.remote_puts),
    }
    out.update({f"cost_{k}": float(v) for k, v in cost.items()})
    if report is not None:
        out["failure"] = report.as_dict()
    if repair is not None:
        out["repair"] = repair.as_dict()
    return out


def run_failover_bench(spec: Optional[HierarchySpec] = None, *,
                       scenario: str = "diurnal",
                       n_steps: int = 240,
                       step_time: float = 0.25,
                       l_blk: int = 128 << 10,
                       tokens_per_step: int = 16,
                       alpha_accel: float = 4.0,
                       kill_at_frac: float = 0.5,
                       n_sessions: int = 12,
                       checkpoint_every: int = 8,
                       lost_recompute_seconds: float = 1.0,
                       mttf: Optional[float] = None,
                       seed: int = 0,
                       sim_cfg=None) -> Dict[str, object]:
    """Replication arms r in {1, 2, 3} through the same kill-at-peak
    scenario, plus the advisor's recommendation under the bench's MTTF.

    `mttf` defaults to `n_hosts * horizon` — exactly one expected host
    failure over the replayed window, so the single measured kill is a
    faithful draw from the modeled failure process."""
    spec = spec if spec is not None else default_failover_spec(
        l_blk, alpha_stall=alpha_accel)
    trace = generate(scenario, n_steps=n_steps, step_time=step_time,
                     seed=seed)
    kill_step = max(1, min(n_steps - 2, int(n_steps * kill_at_frac)))
    kw = dict(l_blk=l_blk, step_time=step_time,
              tokens_per_step=tokens_per_step, alpha_accel=alpha_accel,
              kill_step=kill_step, n_sessions=n_sessions,
              checkpoint_every=checkpoint_every,
              lost_recompute_seconds=lost_recompute_seconds,
              sim_cfg=sim_cfg)
    arms = {r: _run_failover_arm(spec, trace, replicas=r, **kw)
            for r in (1, 2, 3)}

    horizon = float(arms[1]["horizon"])
    mttf_eff = float(mttf) if mttf is not None \
        else spec.n_hosts * horizon
    # price availability from the surviving fleet's live state; the
    # put stream feeds the write-cost term
    from .compiler import Platform
    probe = Platform.compile(spec, sim_cfg=sim_cfg)
    advisor = probe.advisor
    put_rate = float(arms[1]["put_bytes"]) / max(horizon, 1e-9)
    # unique committed payload: one blob per distinct key + session
    # (put_bytes also counts checkpoint re-puts, so it is the write
    # stream, not the census)
    resident = (float(arms[1]["first_touches"]) + n_sessions) \
        * float(max(l_blk // 4, 1) * 4)
    advice = advisor.advise_availability(
        resident_bytes=resident, n_hosts=spec.n_hosts,
        dram_fraction=0.35, mttf=mttf_eff,
        alpha_stall=alpha_accel,
        recompute_seconds=lost_recompute_seconds,
        put_bytes_per_second=put_rate)
    rec = advice.recommended_replicas

    cpt = {r: float(arms[r]["cost_per_token"]) for r in arms}
    return {
        "scenario": scenario,
        "params": {"n_steps": n_steps, "step_time": step_time,
                   "l_blk": l_blk, "alpha_accel": alpha_accel,
                   "kill_step": kill_step, "n_sessions": n_sessions,
                   "checkpoint_every": checkpoint_every,
                   "lost_recompute_seconds": lost_recompute_seconds,
                   "mttf": mttf_eff, "seed": seed},
        "arms": {str(r): arms[r] for r in sorted(arms)},
        "advice": advice.as_dict(),
        "recommended_replicas": float(rec),
        "recommended_wins": bool(
            cpt[rec] <= min(cpt[r] for r in arms if r != rec) + 1e-12),
        "zero_committed_loss_replicated": bool(
            all(arms[r]["committed_keys_lost"] == 0
                for r in (2, 3))),
        "all_sessions_resume_replicated": bool(
            all(arms[r]["sessions_lost"] == 0 for r in (2, 3))),
    }
