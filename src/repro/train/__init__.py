from . import step  # noqa
