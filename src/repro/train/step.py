"""Training step: loss -> grad -> AdamW update, jit-able under any mesh.

`TrainState` is a plain pytree {params, opt}; shardings for every leaf come
from the logical-axis rules, so the same step lowers on 1 device (smoke
tests), 256 (single pod) and 512 (multi-pod).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import model as model_lib
from ..models.config import ModelConfig
from ..optim import adamw
from ..parallel.sharding import Rules


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "nothing"      # "nothing"|"dots"|"dots_no_batch"
    z_loss: float = 1e-4
    microbatch: int = 0                # >0: grad-accumulate in chunks


_POLICIES = {
    "nothing": None,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def init_state(key, cfg: ModelConfig, tcfg: TrainConfig):
    params, logical = model_lib.init_params(key, cfg)
    opt = adamw.init_state(params, tcfg.optimizer)
    return {"params": params, "opt": opt}, logical


def state_logical(logical):
    """Logical tree for the full TrainState (opt moments mirror params)."""
    opt = {"step": (), "mu": logical, "nu": logical}
    return {"params": logical, "opt": opt}


def loss_fn(params, cfg: ModelConfig, rules: Rules, batch,
            tcfg: TrainConfig, cost_exact: bool = False,
            unroll: bool = False):
    return model_lib.loss_and_aux(
        params, cfg, rules, batch, compute_dtype=tcfg.compute_dtype,
        remat=tcfg.remat, remat_policy=_POLICIES[tcfg.remat_policy],
        z_loss=tcfg.z_loss, cost_exact=cost_exact, unroll=unroll)


def train_step(state, batch, *, cfg: ModelConfig, rules: Rules,
               tcfg: TrainConfig, cost_exact: bool = False,
               unroll: bool = False):
    """Returns (new_state, metrics)."""
    if tcfg.microbatch and tcfg.microbatch < batch["tokens"].shape[0]:
        return _train_step_accum(state, batch, cfg=cfg, rules=rules,
                                 tcfg=tcfg, cost_exact=cost_exact,
                                 unroll=unroll)
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(state["params"], cfg, rules, batch, tcfg,
                               cost_exact, unroll)
    new_params, new_opt, om = adamw.apply_updates(
        state["params"], grads, state["opt"], tcfg.optimizer)
    metrics = dict(metrics, loss=loss, **om)
    return {"params": new_params, "opt": new_opt}, metrics


def _train_step_accum(state, batch, *, cfg, rules, tcfg, cost_exact=False,
                      unroll=False):
    """Gradient accumulation over microbatches (keeps peak activation
    memory at microbatch scale; the optimizer update happens once)."""
    B = batch["tokens"].shape[0]
    mb = tcfg.microbatch
    n = B // mb
    assert B % mb == 0, (B, mb)

    def reshape(x):
        return x.reshape((n, mb) + x.shape[1:])

    mbatches = jax.tree.map(reshape, batch)

    def body(carry, mbatch):
        gsum, lsum = carry
        (loss, metrics), g = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], cfg, rules, mbatch,
                                   tcfg, cost_exact, unroll)
        gsum = jax.tree.map(jnp.add, gsum, g)
        return (gsum, lsum + loss), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                         state["params"])
    (gsum, lsum), ms = jax.lax.scan(body, (zeros, jnp.zeros(())), mbatches)
    grads = jax.tree.map(lambda g: g / n, gsum)
    new_params, new_opt, om = adamw.apply_updates(
        state["params"], grads, state["opt"], tcfg.optimizer)
    metrics = {k: v[-1] for k, v in ms.items()}
    metrics = dict(metrics, loss=lsum / n, **om)
    return {"params": new_params, "opt": new_opt}, metrics


def make_jit_train_step(cfg: ModelConfig, rules: Rules, tcfg: TrainConfig,
                        state_shardings=None, batch_sharding=None,
                        donate: bool = True):
    fn = functools.partial(train_step, cfg=cfg, rules=rules, tcfg=tcfg)
    kw = {}
    if state_shardings is not None:
        kw["in_shardings"] = (state_shardings, batch_sharding)
        kw["out_shardings"] = (state_shardings, None)
    return jax.jit(fn, donate_argnums=(0,) if donate else (), **kw)
