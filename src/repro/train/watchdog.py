"""Training fault tolerance: NaN/stall watchdog, straggler detection, and
auto-rollback bookkeeping.

On a real multi-pod deployment the same hooks run per-host and feed the
coordinator; here they guard the training driver:

  * NaN/inf loss -> raise RollbackSignal (driver restores last checkpoint
    and, after repeated failures, reduces LR),
  * step-time EMA straggler detection: a step slower than
    `straggler_factor` x EMA flags a straggler event (on hardware: report
    the slow host for eviction / re-mesh),
  * stall detection: loss EMA not improving for `stall_patience` steps.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional


class RollbackSignal(Exception):
    def __init__(self, reason: str, step: int):
        super().__init__(f"rollback at step {step}: {reason}")
        self.reason = reason
        self.step = step


@dataclasses.dataclass
class WatchdogConfig:
    straggler_factor: float = 3.0
    step_ema_alpha: float = 0.2
    loss_ema_alpha: float = 0.05
    stall_patience: int = 200
    max_loss_spike: float = 4.0       # x loss EMA triggers rollback


class Watchdog:
    def __init__(self, cfg: WatchdogConfig = WatchdogConfig()):
        self.cfg = cfg
        self.step_ema: Optional[float] = None
        self.loss_ema: Optional[float] = None
        self.best_loss = math.inf
        self.since_best = 0
        self.straggler_events: List[dict] = []
        self.rollbacks: List[dict] = []
        self._t_last: Optional[float] = None

    def begin_step(self):
        self._t_last = time.monotonic()

    def end_step(self, step: int, loss: float) -> dict:
        """Returns event dict; raises RollbackSignal on fatal anomalies."""
        dt = time.monotonic() - self._t_last if self._t_last else 0.0
        events = {}
        # straggler detection
        if self.step_ema is not None and dt > self.cfg.straggler_factor \
                * self.step_ema:
            ev = {"step": step, "step_time": dt, "ema": self.step_ema}
            self.straggler_events.append(ev)
            events["straggler"] = ev
        a = self.cfg.step_ema_alpha
        self.step_ema = dt if self.step_ema is None else \
            (1 - a) * self.step_ema + a * dt

        # NaN / divergence
        if not math.isfinite(loss):
            self.rollbacks.append({"step": step, "reason": "nan"})
            raise RollbackSignal("non-finite loss", step)
        if self.loss_ema is not None and \
                loss > self.cfg.max_loss_spike * max(self.loss_ema, 1e-9):
            self.rollbacks.append({"step": step, "reason": "spike"})
            raise RollbackSignal(
                f"loss spike {loss:.3f} vs ema {self.loss_ema:.3f}", step)
        b = self.cfg.loss_ema_alpha
        self.loss_ema = loss if self.loss_ema is None else \
            (1 - b) * self.loss_ema + b * loss

        # stall
        if loss < self.best_loss - 1e-6:
            self.best_loss = loss
            self.since_best = 0
        else:
            self.since_best += 1
        if self.since_best >= self.cfg.stall_patience:
            events["stall"] = {"step": step, "since_best": self.since_best}
            self.since_best = 0
        return events
