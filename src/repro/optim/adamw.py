"""AdamW with decoupled weight decay, global-norm clipping, cosine/linear
schedules, and optional int8 error-feedback gradient compression for the
data-parallel all-reduce.

Pure-pytree implementation (no optax dependency): state is {step, mu, nu}
(+ {err} when compression is on), sharded like the parameters — combined
with FSDP parameter sharding this gives ZeRO-style optimizer-state
partitioning for free under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    schedule: str = "cosine"           # "cosine" | "linear" | "constant"
    # int8 error-feedback DP gradient compression (0 = off)
    compress_bits: int = 0


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) \
            * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1.0 - cfg.min_lr_frac) * frac
    else:
        decay = 1.0
    return cfg.peak_lr * warm * decay


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }
    if cfg.compress_bits:
        state["err"] = jax.tree.map(zeros, params)
    return state


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def compress_int8(g, err):
    """Error-feedback int8 quantization of a gradient leaf.

    Returns (decompressed gradient, new error). Under data-parallel
    all-reduce the int8 representation cuts DP gradient wire bytes 4x vs
    f32 (2x vs bf16); the residual is fed back next step so the update is
    unbiased in the long run (EF-SGD).
    """
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm else 1.0

    new_err = state.get("err")
    if cfg.compress_bits:
        pairs = jax.tree.map(compress_int8, grads, state["err"])
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        u = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step, "mu": new_mu, "nu": new_nu}
    if cfg.compress_bits:
        new_state["err"] = new_err
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
