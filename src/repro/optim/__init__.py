from . import adamw  # noqa
