"""Tier-aware, fault-tolerant checkpoint manager.

Design (deployment-grade semantics, single-node I/O here):
  * atomic commits: write to `step_XXXX.tmp/`, fsync, manifest with
    per-leaf SHA-256 checksums, then a single atomic rename — a crash
    mid-save can never corrupt the restore set,
  * elastic restore: leaves are saved as full logical arrays with their
    pytree paths; restore re-shards onto *any* mesh via device_put with
    the target shardings (save on mesh A, restore on mesh B),
  * tiering: the paper's break-even policy decides which checkpoints stay
    on the fast tier — the newest k (reuse interval ~ restart time) in
    `dram/`, older ones demoted to `flash/` (cheap capacity, the paper's
    "active flash tier" for archival state); demotion is a rename, and
    restore transparently searches both tiers,
  * keep-policy GC with never-delete-last semantics.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

# exotic dtype -> (real dtype, same-width storage dtype) for npy round-trips
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    root: str
    keep: int = 3                 # total checkpoints retained
    fast_tier_keep: int = 1       # newest k stay on the fast tier
    verify_on_restore: bool = True


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        out[key] = leaf
    return out, treedef


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.root = pathlib.Path(cfg.root)
        (self.root / "dram").mkdir(parents=True, exist_ok=True)
        (self.root / "flash").mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Blocking save with atomic commit. Returns the final path."""
        leaves, _ = _flatten(tree)
        tmp = self.root / "dram" / f"step_{step:08d}.tmp"
        final = self.root / "dram" / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "created": time.time(),
                    "extra": extra or {}, "leaves": {}}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            fname = hashlib.md5(key.encode()).hexdigest()[:16] + ".npy"
            true_dtype = str(arr.dtype)
            if true_dtype in _EXOTIC:        # bf16 etc: store as raw bits
                np.save(tmp / fname, arr.view(_EXOTIC[true_dtype][1]))
            else:
                np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": true_dtype, "sha256": _sha256(arr),
            }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)            # atomic commit
        self._gc()
        return final

    # ------------------------------------------------------------------ load
    def _all_checkpoints(self) -> List[pathlib.Path]:
        out = []
        for tier in ("dram", "flash"):
            out += [p for p in (self.root / tier).glob("step_*")
                    if p.is_dir() and not p.name.endswith(".tmp")
                    and (p / "manifest.json").exists()]
        return sorted(out, key=lambda p: int(p.name.split("_")[1]))

    def latest_step(self) -> Optional[int]:
        cps = self._all_checkpoints()
        return int(cps[-1].name.split("_")[1]) if cps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of `template`. With `shardings`
        (a matching pytree of NamedShardings) arrays are placed directly
        onto the target mesh — this is the elastic re-mesh path."""
        cps = self._all_checkpoints()
        if not cps:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        if step is None:
            path = cps[-1]
        else:
            match = [p for p in cps if int(p.name.split("_")[1]) == step]
            if not match:
                raise FileNotFoundError(f"step {step} not found")
            path = match[0]
        manifest = json.loads((path / "manifest.json").read_text())

        leaves, treedef = _flatten(template)
        shard_leaves = _flatten(shardings)[0] if shardings is not None \
            else {}
        restored = {}
        for key, leaf in leaves.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"leaf {key!r} missing from checkpoint")
            arr = np.load(path / meta["file"])
            if meta["dtype"] in _EXOTIC:
                arr = arr.view(_EXOTIC[meta["dtype"]][0])
            if self.cfg.verify_on_restore:
                if _sha256(arr) != meta["sha256"]:
                    raise IOError(f"checksum mismatch for {key!r} "
                                  f"in {path.name} (corrupt checkpoint)")
            sh = shard_leaves.get(key)
            restored[key] = jax.device_put(arr, sh) if sh is not None \
                else jax.numpy.asarray(arr)
        ordered = [restored[k] for k in leaves.keys()]
        return jax.tree_util.tree_unflatten(treedef, ordered), \
            manifest["extra"]

    # ------------------------------------------------------------ tiering/gc
    def _gc(self):
        cps = self._all_checkpoints()
        # demote beyond fast_tier_keep
        dram = [p for p in cps if p.parent.name == "dram"]
        for p in dram[:-self.cfg.fast_tier_keep or None]:
            dst = self.root / "flash" / p.name
            if not dst.exists():
                os.replace(p, dst)
        # delete beyond keep (oldest first, never the newest)
        cps = self._all_checkpoints()
        while len(cps) > max(self.cfg.keep, 1):
            shutil.rmtree(cps[0])
            cps = self._all_checkpoints()

    def tier_of(self, step: int) -> Optional[str]:
        for p in self._all_checkpoints():
            if int(p.name.split("_")[1]) == step:
                return p.parent.name
        return None
