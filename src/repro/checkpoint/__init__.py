from .manager import CheckpointConfig, CheckpointManager  # noqa
