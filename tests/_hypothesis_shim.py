"""Minimal deterministic stand-in for `hypothesis` used when the real
package is absent (this container pins its env; see requirements-dev.txt
for the real dependency).

Implements exactly the subset this suite uses — `given`, `settings`, and
the `floats` / `integers` / `sampled_from` / `lists` / `tuples`
strategies — by drawing `max_examples` samples from a fixed-seed PRNG and
running the test once per sample. Property coverage is preserved (the
tests still execute on many generated inputs); what is lost versus real
hypothesis is shrinking and the example database, which is acceptable for
a CI fallback. `tests/conftest.py` installs this into `sys.modules` only
when `import hypothesis` fails.
"""
from __future__ import annotations

import functools
import inspect
import random

_SEED = 0xA11CE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 - mimics the `hypothesis.strategies` module
    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        lo, hi = int(min_value), int(max_value)
        return _Strategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def sampled_from(seq):
        pool = list(seq)
        return _Strategy(lambda rng: rng.choice(pool))

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        max_size = min_size + 8 if max_size is None else max_size
        return _Strategy(lambda rng: [
            elements.example(rng)
            for _ in range(rng.randint(min_size, max_size))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def settings(max_examples=100, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        sig = inspect.signature(fn)
        # positional strategies bind to the rightmost parameters (as in
        # real hypothesis); keyword strategies bind by name
        nonself = [p for p in sig.parameters if p != "self"]
        pos_names = nonself[len(nonself) - len(arg_strats):] \
            if arg_strats else []
        strats = dict(zip(pos_names, arg_strats), **kw_strats)

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_shim_max_examples", None) \
                or getattr(fn, "_shim_max_examples", None) or 20
            rng = random.Random(_SEED ^ len(fn.__name__)
                                ^ sum(map(ord, fn.__name__)))
            for _ in range(n):
                ex = {k: s.example(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **ex)

        # pytest inspects the signature for fixture injection: hide the
        # drawn parameters, keep `self` and any genuine fixtures
        visible = [p for name, p in sig.parameters.items()
                   if name not in strats]
        runner.__signature__ = sig.replace(parameters=visible)
        return runner
    return deco


def assume(condition) -> bool:
    """Best-effort: real hypothesis aborts the example; the shim cannot
    unwind mid-test, so violations just pass the example through."""
    return bool(condition)


st = strategies
