"""Serving-engine tests: continuous batching across slots at different
positions, pause/resume KV round-trip through the tiered store, and
generation equivalence with a reference loop."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import TieringPolicy
from repro.models import model as M
from repro.parallel.sharding import single_device_rules
from repro.serving.engine import DecodeEngine, Request


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, rules, params


def _reference_generate(cfg, rules, params, prompt, n_new):
    """Single-sequence greedy loop via prefill + decode."""
    import jax.numpy as jnp
    cache = M.init_cache(cfg, 1, 64, dtype=jnp.float32)
    cache, logits = M.prefill(params, cfg, rules,
                              {"tokens": jnp.asarray(prompt[None])},
                              cache, compute_dtype=jnp.float32)
    out = [int(np.argmax(np.asarray(logits[0])))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        cache, logits = M.decode_step(
            params, cfg, rules, jnp.asarray([[out[-1]]]), cache,
            jnp.asarray(pos, jnp.int32), compute_dtype=jnp.float32)
        out.append(int(np.argmax(np.asarray(logits[0]))))
        pos += 1
    return out


def test_engine_matches_reference(setup):
    cfg, rules, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, 6).astype(np.int32)
               for _ in range(3)]
    ref = [_reference_generate(cfg, rules, params, p, 6) for p in prompts]

    eng = DecodeEngine(cfg, params, rules, max_slots=3, max_len=64)
    reqs = [Request(rid=f"r{i}", prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    done = eng.run(reqs)
    assert len(done) == 3
    for r, expect in zip(reqs, ref):
        assert r.generated == expect, (r.rid, r.generated, expect)


def test_engine_staggered_admission(setup):
    """Requests admitted at different times share decode steps."""
    cfg, rules, params = setup
    rng = np.random.default_rng(1)
    eng = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64)
    reqs = [Request(rid=f"r{i}",
                    prompt=rng.integers(1, cfg.vocab, 4 + i).astype(
                        np.int32), max_new=5) for i in range(4)]
    done = eng.run(reqs)           # 4 requests through 2 slots
    assert len(done) == 4
    assert all(len(r.generated) == 5 for r in reqs)


def test_engine_pause_lands_kv_per_economic_gate(setup):
    """DecodeEngine + EconomicGate end-to-end: a paused session's KV
    block is admitted to DRAM or flash by the gate's tracked reuse
    estimate, not by the requested tier."""
    from repro.autopilot import EconomicGate
    from repro.core.policy import Tier

    cfg, rules, params = setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab, 5).astype(np.int32)
    gate = EconomicGate(tau_hot=1e-6, tau_be=2.0)
    eng = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                       policy=gate, step_time=1e-2)
    req = Request(rid="s", prompt=prompt, max_new=30)
    eng.admit(req)
    for _ in range(2):
        eng.step()
    # first pause: nothing known about ("kv", "s") -> cold default
    assert eng.pause("s") == Tier.FLASH
    assert gate.gate_stats.cold_defaults >= 1
    # resume + pause again quickly: ghost-measured reuse under tau_be
    eng.resume("s")
    for _ in range(2):
        eng.step()
    assert eng.pause("s") == Tier.DRAM
    eng.resume("s")
    while not req.done:
        eng.step()


def test_engine_pause_resume_roundtrip(setup):
    cfg, rules, params = setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab, 6).astype(np.int32)
    ref = _reference_generate(cfg, rules, params, prompt, 8)

    eng = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                       policy=TieringPolicy(tau_hot=1e-9, tau_be=1e9))
    req = Request(rid="s", prompt=prompt, max_new=8)
    eng.admit(req)
    for _ in range(3):
        eng.step()
    eng.pause("s")
    # another request cycles through the freed slot
    other = Request(rid="o", prompt=prompt[:4], max_new=3)
    eng.admit(other)
    while not other.done:
        eng.step()
    eng.resume("s")
    while not req.done:
        eng.step()
    assert req.generated == ref, (req.generated, ref)
