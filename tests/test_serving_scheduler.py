"""Continuous-batching scheduler tests + the serving bug-sweep
regressions:

  * `resume` with no free slot must fail *without* destroying the
    paused session (the old code popped `_paused`/`_pending` first),
  * `pause`/`checkpoint_session` on an unknown or already-paused rid
    raise KeyError with the session state, not a bare StopIteration,
  * `Request` equality is identity (eq=False) — the generated
    dataclass __eq__ died on the ndarray prompt,
  * `run()` tracks completion by rid set (the O(n^2) identity scan),
  * park/unpark keeps tokens byte-identical (parked-slot KV garbage is
    overwritten by the first real decode),
  * the continuous scheduler emits byte-identical tokens to the
    lock-step gang reference (greedy decode: scheduling must never
    change tokens), with a hypothesis property test over random job
    interleavings — admissions, pauses, parks, prefetches, resumes and
    an unplanned `fail_host` under replicas=2 — plus flat splice-jit
    retrace counters across per-step admissions.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.policy import TieringPolicy
from repro.models import model as M
from repro.parallel.sharding import single_device_rules
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import ShardedTieredStore
from repro.runtime.tiers import TieredStore
from repro.serving.engine import (DecodeEngine, Request,
                                  splice_trace_counts)
from repro.serving.scheduler import (ContinuousScheduler, SessionJob,
                                     Turn, compare_scheduling,
                                     jobs_from_trace, run_lockstep)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, rules, params


def _pinned_flash():
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


def _engine(cfg, params, rules, *, max_slots=2, store=None,
            step_time=2e-3):
    return DecodeEngine(cfg, params, rules, max_slots=max_slots,
                        max_len=64, policy=_pinned_flash(), store=store,
                        step_time=step_time)


def _reference_generate(cfg, rules, params, prompt, n_new):
    import jax.numpy as jnp
    cache = M.init_cache(cfg, 1, 64, dtype=jnp.float32)
    cache, logits = M.prefill(params, cfg, rules,
                              {"tokens": jnp.asarray(prompt[None])},
                              cache, compute_dtype=jnp.float32)
    out = [int(np.argmax(np.asarray(logits[0])))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        cache, logits = M.decode_step(
            params, cfg, rules, jnp.asarray([[out[-1]]]), cache,
            jnp.asarray(pos, jnp.int32), compute_dtype=jnp.float32)
        out.append(int(np.argmax(np.asarray(logits[0]))))
        pos += 1
    return out


# ------------------------------------------------------------ bug sweep
def test_resume_with_no_free_slot_preserves_session(setup):
    """Regression: the failed resume used to pop the session state (and
    its prefetch) before discovering the grid was full, destroying the
    session. Now the slot is secured first."""
    cfg, rules, params = setup
    rng = np.random.default_rng(10)
    prompt = rng.integers(1, cfg.vocab, 5).astype(np.int32)
    ref = _reference_generate(cfg, rules, params, prompt, 8)

    eng = _engine(cfg, params, rules, max_slots=1)
    req_a = Request(rid="a", prompt=prompt, max_new=8)
    eng.admit(req_a)
    for _ in range(3):
        eng.step()
    eng.pause("a")
    req_b = Request(rid="b", prompt=prompt[:4], max_new=4)
    eng.admit(req_b)                       # the only slot is taken
    eng.prefetch("a")
    with pytest.raises(RuntimeError, match="no free slots"):
        eng.resume("a")
    # the session survived the failure intact: metadata and the issued
    # prefetch are still there, and the resume works once a slot frees
    assert "a" in eng._paused
    assert "a" in eng._pending
    while not req_b.done:
        eng.step()
    eng.resume("a")
    while not req_a.done:
        eng.step()
    assert req_a.generated == ref


def test_pause_and_checkpoint_unknown_rid_raise_keyerror(setup):
    cfg, rules, params = setup
    eng = _engine(cfg, params, rules)
    with pytest.raises(KeyError, match="not live"):
        eng.pause("ghost")
    with pytest.raises(KeyError, match="not live"):
        eng.checkpoint_session("ghost")

    rng = np.random.default_rng(11)
    req = Request(rid="s",
                  prompt=rng.integers(1, cfg.vocab, 5).astype(np.int32),
                  max_new=6)
    eng.admit(req)
    eng.step()
    eng.pause("s")
    # a paused session is not pausable/checkpointable again — and the
    # error says *why*, instead of a bare StopIteration out of next()
    with pytest.raises(KeyError, match="paused"):
        eng.pause("s")
    with pytest.raises(KeyError, match="paused"):
        eng.checkpoint_session("s")


def test_request_equality_is_identity():
    p = np.arange(5, dtype=np.int32)
    a = Request(rid="r", prompt=p)
    b = Request(rid="r", prompt=p.copy())
    # the generated dataclass __eq__ raised "truth value of an array is
    # ambiguous" here; eq=False makes equality (and hashing) identity
    assert a == a and a != b
    assert len({a, b}) == 2


def test_run_tracks_completion_by_rid(setup):
    cfg, rules, params = setup
    rng = np.random.default_rng(12)
    eng = _engine(cfg, params, rules, max_slots=2)
    reqs = [Request(rid=f"r{i}",
                    prompt=rng.integers(1, cfg.vocab, 4).astype(np.int32),
                    max_new=3 + i % 3) for i in range(5)]
    done = eng.run(reqs)
    assert len(done) == 5                  # each request exactly once
    assert {r.rid for r in done} == {r.rid for r in reqs}
    assert all(r.done for r in reqs)


def test_park_unpark_token_equivalence(setup):
    """A parked slot rides through decode steps masked out; its tokens
    must be unaffected by the garbage KV written at its pending
    position."""
    cfg, rules, params = setup
    rng = np.random.default_rng(13)
    prompt = rng.integers(1, cfg.vocab, 5).astype(np.int32)
    ref = _reference_generate(cfg, rules, params, prompt, 8)

    eng = _engine(cfg, params, rules, max_slots=2)
    req_a = Request(rid="a", prompt=prompt, max_new=8)
    req_b = Request(rid="b", prompt=prompt[:3], max_new=10)
    eng.admit(req_a)
    eng.admit(req_b)
    for _ in range(3):
        eng.step()
    eng.park("a")
    for _ in range(4):                     # b decodes alone; a idles
        eng.step()
    assert len(req_a.generated) == 4       # prefill token + 3 steps
    eng.unpark("a")
    while not (req_a.done and req_b.done):
        eng.step()
    assert req_a.generated == ref


# ----------------------------------------------------------- scheduler
def test_continuous_matches_lockstep_on_trace_jobs(setup):
    cfg, rules, params = setup
    cell = compare_scheduling(
        lambda: _engine(cfg, params, rules, max_slots=3),
        lambda: jobs_from_trace("zipf", n_jobs=5, n_turns=2,
                                tokens_per_turn=4, vocab=cfg.vocab,
                                horizon=48, seed=0),
        pause_idle_steps=4)
    assert cell["tokens_identical"], cell["token_mismatches"]
    assert cell["continuous"]["tokens"] == cell["lockstep"]["tokens"]
    assert cell["continuous_wins"], (cell["throughput_ratio"],
                                     cell["stall_ratio"])


def test_scheduler_parks_short_gaps_and_preempts_for_admissions(setup):
    cfg, rules, params = setup
    rng = np.random.default_rng(14)
    eng = _engine(cfg, params, rules, max_slots=1)
    sched = ContinuousScheduler(eng, pause_idle_steps=8,
                                prefetch_lead=0)
    mk = lambda n: rng.integers(1, cfg.vocab, n).astype(np.int32)
    # x's inter-turn gap is short -> parks; y then needs the only slot
    # while x is parked -> preemption offloads x through the store
    x = SessionJob(sid="x", prompt=mk(5),
                   turns=[Turn(due_step=0, max_new=3),
                          Turn(due_step=9, max_new=3)])
    y = SessionJob(sid="y", prompt=mk(4),
                   turns=[Turn(due_step=4, max_new=3)])
    rep = sched.run([x, y], max_ticks=200)
    assert x.state == "done" and y.state == "done"
    assert rep["parks"] >= 1
    assert rep["preempt_pauses"] >= 1
    assert rep["resumes"] >= 1
    assert len(x.request.generated) == 6
    assert len(y.request.generated) == 3


def test_platform_scheduler_uses_spec_knobs(setup):
    from repro.platform import (HierarchySpec, Platform, PolicyDecl,
                                SchedulerDecl)
    cfg, rules, params = setup
    spec = HierarchySpec(policy=PolicyDecl.pinned_flash(),
                         step_time=2e-3,
                         scheduler=SchedulerDecl(pause_idle_steps=3,
                                                 prefetch_lead=2))
    plat = Platform.compile(spec)
    sched = plat.scheduler(cfg, params, rules, max_slots=2, max_len=64)
    assert isinstance(sched, ContinuousScheduler)
    assert sched.pause_idle_steps == 3
    assert sched.prefetch_lead == 2
    assert sched.engine.step_time == 2e-3
    # per-call override beats the declaration
    sched2 = plat.scheduler(cfg, params, rules, pause_idle_steps=0,
                            prefetch_lead="p99", max_slots=2,
                            max_len=64)
    assert sched2.pause_idle_steps == 0
    assert sched2.prefetch_lead == "p99"


def test_scheduler_decl_validation():
    from repro.platform import HierarchySpec, SchedulerDecl
    spec = HierarchySpec(scheduler=SchedulerDecl(pause_idle_steps=4,
                                                 prefetch_lead=2))
    assert HierarchySpec.from_json(spec.to_json()) == spec
    with pytest.raises(ValueError, match="pause_idle_steps"):
        HierarchySpec(
            scheduler=SchedulerDecl(pause_idle_steps=-1)).validate()
    with pytest.raises(ValueError, match="prefetch_lead"):
        HierarchySpec(
            scheduler=SchedulerDecl(prefetch_lead="p50")).validate()


# ----------------------------------------------------- property testing
@pytest.fixture(scope="module")
def prop_engines(setup):
    """Two engines (continuous arm, lock-step arm) reused across
    property examples — per-engine jit is the expensive part; state is
    reset per example."""
    cfg, rules, params = setup
    mk = lambda: DecodeEngine(cfg, params, rules, max_slots=3,
                              max_len=64, step_time=2e-3)
    return mk(), mk()


def _reset(eng, store):
    eng.cache = M.init_cache(eng.cfg, eng.max_slots, eng.max_len,
                             dtype=eng.dtype)
    eng.lengths[:] = 0
    eng.live[:] = False
    eng.active[:] = False
    eng.last_token[:] = 0
    eng.slot_req.clear()
    eng._paused.clear()
    eng._pending.clear()
    eng._checkpoints.clear()
    eng.kv_stall_time = 0.0
    eng.steps = 0
    eng.store = store
    eng.clock = store.clock


def _draw_jobs(rng, vocab):
    """Job specs as plain data, materialized twice (one list per arm)."""
    specs = []
    for i in range(int(rng.integers(2, 5))):
        prompt = rng.integers(1, vocab, 5).astype(np.int32)
        turns, prev = [], int(rng.integers(0, 6)) - 1
        for _ in range(int(rng.integers(1, 4))):
            new = int(rng.integers(2, 7))
            due = prev + new + int(rng.integers(1, 7))
            turns.append((due, new, int(rng.integers(0, 5))))
            prev = due
        specs.append((f"s{i}", prompt, turns))
    def make():
        return [SessionJob(sid=s, prompt=p.copy(),
                           turns=[Turn(due_step=d, max_new=n,
                                       deadline_steps=dl)
                                  for d, n, dl in t])
                for s, p, t in specs]
    return make


_SPLICE_WARM = []


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=1_000_000))
def test_scheduler_interleaving_property(prop_engines, seed):
    """Random multi-turn job sets under random scheduler knobs, with an
    unplanned mid-run host failure (replicas=2) on the continuous arm:
    tokens must be byte-identical to the lock-step reference per
    session, every job must complete, and the splice-jit programs must
    not retrace across the run's per-step admissions/resumes."""
    cont_eng, lock_eng = prop_engines
    rng = np.random.default_rng(seed)
    make_jobs = _draw_jobs(rng, cont_eng.cfg.vocab)
    pause_idle = int(rng.integers(0, 7))
    lead = ["p99", 0, 2][int(rng.integers(0, 3))]
    do_fail = bool(rng.integers(0, 2))
    fail_tick = int(rng.integers(2, 16))

    before = splice_trace_counts()

    fabric = ShardedTieredStore(2, clock=VirtualClock())
    _reset(cont_eng, fabric.host_view(0, replicas=2))
    sched = ContinuousScheduler(cont_eng, pause_idle_steps=pause_idle,
                                prefetch_lead=lead)
    cont_jobs = make_jobs()
    sched.submit_all(cont_jobs)
    failed = False
    while sched.pending_work() and sched.metrics["ticks"] < 600:
        if do_fail and not failed and sched.metrics["ticks"] == fail_tick:
            fabric.fail_host(1)      # replicas=2: every KV blob survives
            failed = True
        sched.tick()
    assert not sched.pending_work()

    _reset(lock_eng, TieredStore(_pinned_flash(), clock=VirtualClock()))
    lock_jobs = make_jobs()
    run_lockstep(lock_eng, lock_jobs, max_ticks=600)

    lock_by_sid = {j.sid: list(j.request.generated) for j in lock_jobs}
    for j in cont_jobs:
        assert j.state == "done"
        assert list(j.request.generated) == lock_by_sid[j.sid], j.sid
        assert len(j.request.generated) == j.total()

    after = splice_trace_counts()
    if _SPLICE_WARM:
        # past the first example both splice programs are compiled for
        # this cache geometry: per-step admission must never retrace
        assert after == before, (before, after)
    _SPLICE_WARM.append(1)


def test_unpark_is_counted_and_deadline_checked(setup):
    """Regression: the unpark fast path (arrival pops a *parked* job)
    bypassed `_admit`, so parked turns were invisible to admission
    accounting — no counter, no deadline check, no per-tenant bump.
    A parked turn popped late is an admission like any other."""
    cfg, rules, params = setup
    rng = np.random.default_rng(21)
    mk = lambda n: rng.integers(1, cfg.vocab, n).astype(np.int32)

    # on-time unpark: counted (fleet + tenant), no miss
    eng = _engine(cfg, params, rules, max_slots=2)
    sched = ContinuousScheduler(eng, pause_idle_steps=8,
                                prefetch_lead=0)
    x = SessionJob(sid="x", prompt=mk(5), tenant="t",
                   turns=[Turn(due_step=0, max_new=3),
                          Turn(due_step=9, max_new=3,
                               deadline_steps=4)])
    rep = sched.run([x], max_ticks=200)
    assert x.state == "done"
    assert rep["parks"] >= 1            # the gap did park, not pause
    assert rep["unparks"] == rep["parks"]
    assert rep["deadline_misses"] == 0
    assert rep["tenants"]["t"]["unparks"] == rep["unparks"]

    # late unpark: a parked turn popped past its deadline is a miss
    eng2 = _engine(cfg, params, rules, max_slots=2)
    sched2 = ContinuousScheduler(eng2, pause_idle_steps=8,
                                 prefetch_lead=0)
    y = SessionJob(sid="y", prompt=mk(5), tenant="t",
                   turns=[Turn(due_step=0, max_new=3),
                          Turn(due_step=9, max_new=3,
                               deadline_steps=4)])
    sched2.submit(y)
    for _ in range(50):
        sched2.tick()
        if y.state == "parked":
            break
    assert y.state == "parked"
    sched2.now = y.deadline() + 5       # white-box: stall the clock past
    sched2.tick()                       # the deadline, then let it pop
    assert y.state == "running"
    assert sched2.metrics["unparks"] == 1
    assert sched2.metrics["deadline_misses"] == 1
    assert sched2.tenant_metrics["t"]["deadline_misses"] == 1
    assert y.admitted_step == y.deadline() + 5
