"""`ContinuousScheduler.tenant_report` edge cases.

The per-tenant SLO cells feed benchmark JSON that CI byte-diffs and
budget-burn arithmetic that divides by token counts — so the report
must stay well-formed (uniform keys, finite numbers) for tenants that
never admitted a session, tenants that only ever take the park/unpark
path (no restores, no stall), and it must be derived purely from
scheduler-owned state: resetting the store's stats must not change it.
"""
import numpy as np
import pytest

from repro.core.policy import TieringPolicy
from repro.runtime.clock import VirtualClock
from repro.runtime.tiers import TieredStore
from repro.serving.scheduler import ContinuousScheduler, SessionJob, Turn

CELL_KEYS = {"sessions", "tokens", "stall", "per_token_stall",
             "p99_per_token_stall", "admissions", "resumes", "unparks",
             "parks", "pauses", "deadline_misses", "ledger_stall"}


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.parallel.sharding import single_device_rules
    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, rules, params


def _scheduler(setup, *, max_slots=2, pause_idle_steps=0):
    from repro.serving.engine import DecodeEngine
    cfg, rules, params = setup
    clock = VirtualClock()
    store = TieredStore(
        TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0),
        clock=clock)
    eng = DecodeEngine(cfg, params, rules, max_slots=max_slots,
                       max_len=64, store=store, clock=clock,
                       step_time=0.25)
    return ContinuousScheduler(eng, pause_idle_steps=pause_idle_steps,
                               prefetch_lead=0)


def _job(sid, turns, tenant):
    return SessionJob(sid=sid, prompt=np.arange(1, 5, dtype=np.int32),
                      turns=turns, tenant=tenant)


def test_zero_admitted_tenant_reports_uniform_zero_cell(setup):
    """A tenant whose sessions never became due inside the tick budget
    must still get a complete, all-zero cell — not a KeyError in the
    budget-burn arithmetic or a cell missing its event counters."""
    sched = _scheduler(setup)
    jobs = [
        _job("fast/000", [Turn(0, 3)], "fast"),
        # due far beyond the tick budget: never admitted
        _job("late/000", [Turn(10_000, 3)], "late"),
        _job("late/001", [Turn(10_000, 3)], "late"),
    ]
    sched.submit_all(jobs)
    while sched.metrics["ticks"] < 12:
        sched.tick()
    report = sched.report()
    cell = report["tenants"]["late"]
    assert set(cell) == CELL_KEYS
    assert cell["sessions"] == 2
    assert cell["tokens"] == 0 and cell["stall"] == 0.0
    assert cell["per_token_stall"] == 0.0
    assert cell["p99_per_token_stall"] == 0.0
    assert cell["admissions"] == 0 and cell["resumes"] == 0
    # the admitted tenant's cell has the same key set
    assert set(report["tenants"]["fast"]) == CELL_KEYS
    assert report["tenants"]["fast"]["admissions"] == 1


def test_unpark_only_tenant_has_no_restore_stall(setup):
    """Short inter-turn gaps under a generous `pause_idle_steps` take
    the park/unpark path: KV stays resident, so the tenant's stall and
    resume counters are exactly zero while unparks are counted (and
    held to the same deadline check)."""
    sched = _scheduler(setup, pause_idle_steps=8)
    jobs = [_job("parky/000", [Turn(0, 3), Turn(8, 3, 4)], "parky")]
    report = sched.run(jobs)
    cell = report["tenants"]["parky"]
    assert set(cell) == CELL_KEYS
    assert cell["parks"] >= 1 and cell["unparks"] >= 1
    assert cell["pauses"] == 0 and cell["resumes"] == 0
    assert cell["stall"] == 0.0 and cell["p99_per_token_stall"] == 0.0
    assert cell["tokens"] == 6
    # park/unpark never touches the store: no per-tenant ledger slice
    assert "parky" not in sched.ledger.tenants


def test_tenant_report_stable_across_store_reset_stats(setup):
    """The report is scheduler-owned bookkeeping: zeroing the store's
    tier/lane stats (the benchmark warm-up idiom) must not perturb it."""
    sched = _scheduler(setup)
    jobs = [_job("a/000", [Turn(0, 3), Turn(6, 3)], "a"),
            _job("b/000", [Turn(1, 3)], "b")]
    sched.run(jobs)
    before = sched.report()
    sched.engine.store.reset_stats()
    after = sched.report()
    assert after["tenants"] == before["tenants"]
    assert after["stall_ledger"] == before["stall_ledger"]


def test_budget_burn_emitted_only_for_budgeted_tenants(setup):
    """`stall_budgets` opts a tenant into burn-rate accounting; cells
    of unbudgeted tenants must not grow a key."""
    from repro.serving.engine import DecodeEngine
    cfg, rules, params = setup
    clock = VirtualClock()
    store = TieredStore(
        TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0),
        clock=clock)
    eng = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                       store=store, clock=clock, step_time=0.25)
    sched = ContinuousScheduler(eng, prefetch_lead=0,
                                stall_budgets={"prem": 1e-6})
    jobs = [_job("prem/000", [Turn(0, 3), Turn(8, 3)], "prem"),
            _job("bulk/000", [Turn(0, 3), Turn(8, 3)], "bulk")]
    report = sched.run(jobs)
    prem = report["tenants"]["prem"]
    assert "budget_burn" in prem and np.isfinite(prem["budget_burn"])
    # ledger_stall is the tenant's Eq. 1 slice (restore seconds only —
    # slot-idle rent is fleet-level by design)
    assert prem["ledger_stall"] == pytest.approx(
        sum(sched._tenant_ledger("prem").values()))
    assert "budget_burn" not in report["tenants"]["bulk"]
    assert "ledger_stall" in report["tenants"]["bulk"]