"""Sharded multi-host tiering fabric tests: consistent-hash routing
stability, remote fetch = NIC + remote-flash service composition on the
shared virtual clock, write-shielding admission control, replicated
expert sharding, cross-host DecodeEngine pause/resume, and the fleet
benchmark's >=5x async-prefetch stall win with byte-stable output."""
import json

import numpy as np
import pytest

from repro.core.policy import Tier, TieringPolicy
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import (NIC, HostView, RemoteFetch,
                                  ShardedTieredStore)
from repro.runtime.service import NetQueueModel
from repro.runtime.tiers import TierSpec, TieredStore
from repro.serving.bench import compare_fleet, multi_host_session_bench
from repro.tiering.expert_store import ExpertStore


def _pinned(_h=0):
    # thresholds pinned so objects stay where the test puts them
    return TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)


def _fabric(n_hosts, **kw):
    return ShardedTieredStore(n_hosts, policy_factory=_pinned,
                              clock=VirtualClock(), **kw)


# ---------------------------------------------------------------------------
# shard routing
# ---------------------------------------------------------------------------

def test_shard_routing_deterministic_and_balanced():
    keys = [("kv", f"s{i}") for i in range(1000)]
    a, b = _fabric(4), _fabric(4)
    owners = [a.owner(k) for k in keys]
    assert owners == [b.owner(k) for k in keys]   # instance-independent
    counts = np.bincount(owners, minlength=4)
    assert counts.min() > 0                        # every host owns keys
    assert counts.max() < 2.5 * counts.min()       # vnodes keep it even


def test_shard_routing_stable_under_host_growth():
    """Consistent hashing: adding a host remaps only ~1/(N+1) of keys."""
    keys = [("kv", f"s{i}") for i in range(1000)]
    f4, f5 = _fabric(4), _fabric(5)
    moved = sum(f4.owner(k) != f5.owner(k) for k in keys)
    assert 0 < moved < 0.35 * len(keys)           # expected ~0.2
    # surviving assignments are untouched, and every key is owned
    assert all(0 <= f5.owner(k) < 5 for k in keys)


def test_ring_hosts_distinct_and_start_at_owner():
    fab = _fabric(4)
    order = fab.ring_hosts(("kv", "x"))
    assert sorted(order) == [0, 1, 2, 3]
    assert order[0] == fab.owner(("kv", "x"))


# ---------------------------------------------------------------------------
# remote fetch composition
# ---------------------------------------------------------------------------

def _loaded_fabric(n_hosts=2, kv_bytes=1 << 20):
    fab = _fabric(n_hosts)
    key = ("kv", "s0")
    fab.put(key, np.zeros(kv_bytes, np.uint8), tier=Tier.FLASH,
            from_host=fab.owner(key))
    fab.drain()
    return fab, key


def test_remote_fetch_composes_network_and_remote_flash():
    fab, key = _loaded_fabric()
    owner, other = fab.owner(key), 1 - fab.owner(key)
    clock = fab.clock
    rf = fab.get_async(key, from_host=other)
    assert isinstance(rf, RemoteFetch)
    # the NIC transfer is gated on the remote flash read's completion
    assert rf.nic_tr.start_t >= rf.pf.transfer.done_t - 1e-12
    assert rf.nic_tr.done_t > rf.pf.transfer.done_t
    t0 = clock.now()
    rf.wait()
    assert clock.now() == pytest.approx(rf.nic_tr.done_t)
    # composition: the synchronous remote stall covers flash + network
    assert clock.now() - t0 == pytest.approx(rf.nic_tr.done_t - t0)
    assert fab.nic[owner].qstats[NIC].submitted == 1
    assert fab.nic[owner].qstats[NIC].bytes_moved == 1 << 20
    assert fab.remote_fetches == 1 and fab.local_fetches == 0


def test_remote_fetch_slower_than_local_fetch():
    fab, key = _loaded_fabric()
    clock = fab.clock
    t0 = clock.now()
    fab.get(key, from_host=fab.owner(key))
    t_local = clock.now() - t0
    fab.drain()
    t0 = clock.now()
    fab.get(key, from_host=1 - fab.owner(key))
    t_remote = clock.now() - t0
    assert t_remote > t_local > 0
    assert fab.local_fetches == 1 and fab.remote_fetches == 1


def test_remote_prefetch_streams_behind_decode():
    fab, key = _loaded_fabric()
    clock = fab.clock
    rf = fab.get_async(key, from_host=1 - fab.owner(key))
    fab.hosts[0].runtime.advance(0.05)     # modeled decode on the clock
    t0 = clock.now()
    rf.wait()
    assert clock.now() == t0               # fully overlapped: zero stall
    assert rf.done()


def test_cross_host_put_charges_writer_egress_nic():
    fab = _fabric(2)
    key = ("kv", "remote-put")
    writer = 1 - fab.owner(key)
    fab.put(key, np.zeros(1 << 16, np.uint8), tier=Tier.FLASH,
            from_host=writer)
    assert fab.nic[writer].qstats[NIC].submitted == 1
    assert fab.remote_puts == 1
    assert fab.tier_of(key) == Tier.FLASH


def test_fabric_get_missing_key_raises():
    fab = _fabric(2)
    with pytest.raises(KeyError):
        fab.get_async(("kv", "nope"), from_host=0)


# ---------------------------------------------------------------------------
# write shielding (admission control)
# ---------------------------------------------------------------------------

def _shielded_store():
    clock = VirtualClock()
    store = TieredStore(_pinned(), specs={
        Tier.HBM: TierSpec(1 << 20, 819e9, 1e-7),
        Tier.DRAM: TierSpec(2 << 20, 45e9, 5e-7),
        Tier.FLASH: TierSpec(1 << 30, 7e9, 2e-5),
    }, clock=clock, write_shield_depth=2)
    for i in range(3):
        store.put(("cold", i), np.ones(1 << 18, np.uint8), tier=Tier.FLASH)
    store.runtime.drain()
    return store, clock


def test_write_shield_defers_demotions_under_read_burst():
    store, clock = _shielded_store()
    # a read burst: three in-flight flash fetches (depth >= threshold 2)
    burst = [store.get_async(("cold", i)) for i in range(3)]
    assert store.runtime.read_depth(Tier.FLASH) == 3
    # capacity pressure demotes DRAM residents into the burst
    store.put(("hot", 0), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    store.put(("hot", 1), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    store.put(("hot", 2), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    st = store.stats[Tier.FLASH]
    assert st.demotions > 0
    assert st.demotions_deferred > 0        # writes parked, not queued
    assert st.deferred_bytes > 0
    assert store.deferred_writes_pending == st.demotions_deferred
    # the burst drains -> the parked writes flush automatically
    for pf in burst:
        pf.wait()
    assert store.runtime.read_depth(Tier.FLASH) == 0
    assert store.deferred_writes_pending == 0


def test_fabric_drain_flushes_shielded_writes():
    """drain() must leave no parked write behind: the drain itself
    completes the read burst, so the flush happens after it."""
    fab = _fabric(1, write_shield_depth=1)
    store = fab.hosts[0]
    store.specs[Tier.DRAM] = TierSpec(1 << 20, 45e9, 5e-7)
    fab.put(("cold", 0), np.ones(1 << 18, np.uint8), tier=Tier.FLASH)
    fab.drain()
    pf = fab.get_async(("cold", 0), from_host=0)   # read in flight
    fab.put(("hot", 0), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    fab.put(("hot", 1), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    assert store.deferred_writes_pending > 0
    fab.drain()
    assert store.deferred_writes_pending == 0
    pf.wait()


def test_remote_prefetch_late_when_nic_leg_uncovered():
    """A lead that covers the remote flash read but not the NIC transfer
    is a LATE prefetch — classification sees the full composition."""
    slow_net = ShardedTieredStore(
        2, policy_factory=_pinned, clock=VirtualClock(),
        net_model=NetQueueModel(rtt=1e-3, bandwidth=1e8, sat_depth=1))
    key = ("kv", "s0")
    owner = slow_net.owner(key)
    slow_net.put(key, np.zeros(1 << 20, np.uint8), tier=Tier.FLASH,
                 from_host=owner)
    slow_net.drain()
    rf = slow_net.get_async(key, from_host=1 - owner)
    # advance past the flash leg but not the ~10ms NIC leg
    gap = rf.pf.transfer.done_t - slow_net.clock.now()
    slow_net.hosts[0].runtime.advance(gap * 1.01)
    assert rf.pf.transfer.is_done(slow_net.clock.now())
    assert not rf.done()
    t0 = slow_net.clock.now()
    rf.wait()
    assert slow_net.clock.now() > t0               # NIC residual stalled
    st = slow_net.hosts[owner].stats[Tier.FLASH]
    assert st.prefetch_late == 1 and st.prefetch_hits == 0


def test_flush_deferred_not_head_of_line_blocked():
    """A parked write for a still-shielded tier must not block parked
    writes bound for other tiers whose read bursts have drained."""
    store = TieredStore(_pinned(), specs={
        Tier.HBM: TierSpec(1 << 20, 819e9, 1e-7),
        Tier.DRAM: TierSpec(2 << 20, 45e9, 5e-7),
        Tier.FLASH: TierSpec(1 << 30, 7e9, 2e-5),
    }, clock=VirtualClock(), write_shield_depth=1)
    store.put("f", np.ones(1 << 18, np.uint8), tier=Tier.FLASH)
    store.put("d", np.ones(1 << 18, np.uint8), tier=Tier.DRAM)
    store.runtime.drain()
    pf_flash = store.get_async("f")     # shields FLASH (slow read)
    pf_dram = store.get_async("d")      # shields DRAM (fast read)
    # DRAM pressure defers FLASH-bound demotion writes...
    store.put(("x", 0), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    store.put(("x", 1), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    # ...then HBM pressure defers DRAM-bound ones behind them
    for i in range(3):
        store.put(("h", i), np.ones(1 << 19, np.uint8), tier=Tier.HBM)
    dsts = {d for d, *_ in store._deferred_writes}
    assert dsts == {Tier.FLASH, Tier.DRAM}
    # the DRAM read finishes long before the flash one: its wait flushes
    # the DRAM-bound writes even though FLASH entries head the list
    pf_dram.wait()
    dsts = {d for d, *_ in store._deferred_writes}
    assert Tier.DRAM not in dsts and Tier.FLASH in dsts
    pf_flash.wait()
    assert store.deferred_writes_pending == 0


def test_deleted_key_cancels_parked_deferred_write():
    """delete()/overwrite of a key with a parked demotion write must not
    leave a phantom flash write behind for the drained shield to submit."""
    store, clock = _shielded_store()
    burst = [store.get_async(("cold", i)) for i in range(3)]
    store.put(("hot", 0), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    store.put(("hot", 1), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    store.put(("hot", 2), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    assert store.deferred_writes_pending > 0
    parked_keys = [k for _, k, *_ in store._deferred_writes]
    for k in parked_keys:
        store.delete(k)
    assert store.deferred_writes_pending == 0
    for pf in burst:
        pf.wait()
    assert store.flush_deferred_writes() == 0   # nothing phantom to flush


def test_write_shield_off_by_default():
    clock = VirtualClock()
    store = TieredStore(_pinned(), clock=clock)
    store.put("a", np.ones(1 << 16, np.uint8), tier=Tier.FLASH)
    assert store.write_shield_depth is None
    assert store.deferred_writes_pending == 0
    with pytest.raises(ValueError):
        TieredStore(_pinned(), clock=VirtualClock(), write_shield_depth=0)


def test_fleet_bench_surfaces_deferral_stats():
    r = multi_host_session_bench("async", n_hosts=2, n_sessions=4,
                                 rounds=1, kv_bytes=1 << 18,
                                 decode_steps=4, step_time=1e-3, lead=2,
                                 write_shield_depth=2)
    assert "demotions_deferred" in r       # surfaced even when zero


# ---------------------------------------------------------------------------
# replicated expert sharding over the fabric
# ---------------------------------------------------------------------------

def test_expert_store_shards_replicated_cold_experts():
    fab = _fabric(4)
    es = ExpertStore(n_layers=1, n_experts=8, policy=_pinned(),
                     store=fab.host_view(0, replicas=2))
    w = np.arange(32 * 32, dtype=np.float32).reshape(32, 32)
    for e in range(8):
        es.store.put((0, e), w, tier=Tier.FLASH)
    fab.drain()
    # every expert lives on exactly its two ring-owner hosts
    for e in range(8):
        holders = fab.holders((0, e))
        assert holders == fab.ring_hosts((0, e))[:2]
    # streaming: prefetch all, overlap, fetch without residual stall
    assert es.prefetch_experts(0, list(range(8))) == 8
    fab.hosts[0].runtime.advance(1.0)
    t0 = es.clock.now()
    for e in range(8):
        np.testing.assert_array_equal(es.fetch_expert(0, e), w)
    assert es.clock.now() == t0            # all overlapped
    # host 0 serves co-resident replicas locally, the rest remotely
    expect_local = sum(0 in fab.ring_hosts((0, e))[:2] for e in range(8))
    assert fab.local_fetches == expect_local
    assert fab.remote_fetches == 8 - expect_local


def test_host_view_ducktypes_tiered_store():
    fab = _fabric(2)
    view = fab.host_view(0)
    assert isinstance(view, HostView)
    key = ("obj", 1)
    view.put(key, np.ones(64, np.float32), tier=Tier.FLASH)
    assert view.tier_of(key) == Tier.FLASH
    np.testing.assert_array_equal(view.get(key), np.ones(64, np.float32))
    view.delete(key)
    assert view.tier_of(key) is None
    assert view.clock is fab.clock
    assert view.runtime is fab.hosts[0].runtime


# ---------------------------------------------------------------------------
# fleet serving benchmark (tentpole acceptance)
# ---------------------------------------------------------------------------

_FLEET_KW = dict(n_hosts=4, n_sessions=8, rounds=2, kv_bytes=1 << 19,
                 decode_steps=8, step_time=2e-3, lead=6, skew=1.2)


def test_fleet_bench_async_prefetch_5x_lower_stall():
    r = compare_fleet(**_FLEET_KW)
    assert r["sync"]["remote_fetches"] > 0          # truly cross-host
    assert r["async"]["prefetch_hits"] > 0
    assert r["async"]["tokens"] == r["sync"]["tokens"]   # fair compare
    assert r["stall_speedup"] >= 5.0


def test_fleet_bench_deterministic_and_json_stable():
    a, b = compare_fleet(**_FLEET_KW), compare_fleet(**_FLEET_KW)
    assert a == b
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_fleet_cli_smoke_respects_explicit_flags():
    """--smoke sets fast defaults but an explicit flag (here --lead 0,
    the degenerate no-prefetch check) must win over them."""
    import subprocess
    import sys
    import pathlib
    script = pathlib.Path(__file__).resolve().parents[1] / \
        "benchmarks" / "serving_fleet.py"
    out = subprocess.run(
        [sys.executable, str(script), "--smoke", "--lead", "0",
         "--sessions", "2", "--rounds", "1", "--decode-steps", "2",
         "--kv-mib", "0.05", "--skew", "0.0"],
        capture_output=True, text=True, check=True)
    report = json.loads(out.stdout)
    assert report["params"]["lead"] == 0
    assert report["params"]["n_sessions"] == 2
    for rec in report["trajectory"]:
        # lead 0 never issues a prefetch: async degenerates to sync
        assert rec["async"]["prefetch_hits"] == 0
        assert rec["stall_speedup"] == pytest.approx(1.0)


def test_fleet_bench_skew_changes_schedule_not_tokens():
    flat = multi_host_session_bench("async", **{**_FLEET_KW, "skew": 0.0})
    hot = multi_host_session_bench("async", **_FLEET_KW)
    assert flat["tokens"] == hot["tokens"]
    assert flat["skew"] == 0.0 and hot["skew"] == 1.2


# ---------------------------------------------------------------------------
# cross-host DecodeEngine pause/resume (KV streamed behind decode)
# ---------------------------------------------------------------------------

def test_engine_cross_host_pause_resume_streams_kv():
    import jax
    from repro.configs import get_config
    from repro.models import model as M
    from repro.parallel.sharding import single_device_rules
    from repro.serving.engine import DecodeEngine, Request

    cfg = get_config("gemma-2b", reduced=True)
    rules = single_device_rules()
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    clock = VirtualClock()
    fab = ShardedTieredStore(2, policy_factory=_pinned, clock=clock)
    # pick a session whose KV shard-owner is host 0, then serve the
    # resume on host 1 so the restore must cross the NIC tier
    rid = next(f"s{i}" for i in range(64)
               if fab.owner(("kv", f"s{i}")) == 0)
    eng0 = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                        store=fab.host_view(0), step_time=1e-3)
    eng1 = DecodeEngine(cfg, params, rules, max_slots=2, max_len=64,
                        store=fab.host_view(1), step_time=1e-3)
    rng = np.random.default_rng(0)
    req = Request(rid=rid, prompt=rng.integers(
        1, cfg.vocab, 6).astype(np.int32), max_new=8)
    eng0.admit(req)
    for _ in range(3):
        eng0.step()
    eng0.pause(rid)
    assert fab.hosts[0].tier_of(("kv", rid)) is not None
    # hand the session to host 1: metadata moves, KV streams via fabric
    state = eng0.export_session(rid)
    eng1.import_session(rid, state)
    with pytest.raises(KeyError):
        eng1.import_session(rid, state)     # double adoption rejected
    eng1.prefetch(rid)
    clock.advance(1.0)                      # decode elsewhere overlaps
    stall_before = eng1.kv_stall_time
    eng1.resume(rid)
    assert eng1.kv_stall_time == stall_before    # prefetch covered it
    assert fab.remote_fetches >= 1
    while not req.done:
        eng1.step()
    assert len(req.generated) == 8
