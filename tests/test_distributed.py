"""Multi-device tests, run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process must keep seeing 1 device, per the assignment).

Covers: sharded-vs-single-device train-step parity (incl. shard_map EP
MoE), elastic re-mesh checkpoint restore (save on (2,4), restore on
(4,2)), and a mini dry-run lower+compile on the 8-device mesh."""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(body: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from repro.configs import get_config
        from repro.configs import shapes as shp
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import tree_shardings, model_logical
        from repro.parallel.sharding import train_rules, single_device_rules
        from repro.train.step import TrainConfig, init_state, train_step

        # MoE arch exercises the shard_map EP path end to end. Capacity is
        # per-data-shard (GShard), so raise it to no-drop for exact parity
        # across mesh shapes.
        import dataclasses
        from repro.models.config import MoeSpec
        cfg = get_config("qwen3-moe-235b-a22b", reduced=True)
        cfg = dataclasses.replace(cfg, pattern=tuple(
            tuple(dataclasses.replace(s, capacity_factor=64.0)
                  if isinstance(s, MoeSpec) else s for s in layer)
            for layer in cfg.pattern))
        tcfg = TrainConfig(compute_dtype=jnp.float32)
        state, _ = init_state(jax.random.PRNGKey(0), cfg, tcfg)
        batch = shp.concrete_batch(cfg, batch=4, seq=16)

        r1 = single_device_rules()
        s1, m1 = jax.jit(functools.partial(
            train_step, cfg=cfg, rules=r1, tcfg=tcfg))(state, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        r8 = train_rules(mesh)
        s8, m8 = jax.jit(functools.partial(
            train_step, cfg=cfg, rules=r8, tcfg=tcfg))(state, batch)

        l1, l8 = float(m1["loss"]), float(m8["loss"])
        assert abs(l1 - l8) / abs(l1) < 2e-4, (l1, l8)
        # parameters evolve identically (spot-check a leaf)
        a = np.asarray(s1["params"]["embed"])
        b = np.asarray(jax.device_get(s8["params"]["embed"]))
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-5)
        print("PARITY OK", l1, l8)
        """)
    assert "PARITY OK" in out


def test_elastic_remesh_restore():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint.manager import CheckpointConfig, \\
            CheckpointManager
        from repro.configs import get_config
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import tree_shardings, model_logical, \\
            with_shardings
        from repro.parallel.sharding import train_rules
        from repro.models import model as M

        cfg = get_config("deepseek-7b", reduced=True)
        params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
        logical = model_logical(cfg)

        mesh_a = make_mesh((2, 4), ("data", "model"))
        sh_a = tree_shardings(train_rules(mesh_a), params, logical)
        params_a = jax.tree.map(jax.device_put, params, sh_a)

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(CheckpointConfig(root=d))
            mgr.save(7, {"params": params_a})

            # restore onto a different topology: (4 data, 2 model)
            mesh_b = make_mesh((4, 2), ("data", "model"))
            sh_b = {"params": tree_shardings(train_rules(mesh_b), params,
                                             logical)}
            out, _ = mgr.restore({"params": params}, shardings=sh_b)
        for x, y in zip(jax.tree.leaves(params),
                        jax.tree.leaves(out["params"])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        # the restored arrays actually live on mesh_b
        leaf = out["params"]["embed"]
        assert leaf.sharding.mesh.shape["data"] == 4
        print("ELASTIC OK")
        """)
    assert "ELASTIC OK" in out


def test_mini_dryrun_lower_compile():
    out = _run("""
        import jax, dataclasses
        from repro.configs import get_config
        from repro.configs import shapes as shp
        from repro.launch.mesh import make_mesh
        from repro.launch import dryrun
        from repro.launch.roofline import cost_terms

        mesh = make_mesh((2, 4), ("data", "model"))
        for arch, shape_name in (("gemma-2b", "train_4k"),
                                 ("zamba2-7b", "decode_32k")):
            cfg = get_config(arch, reduced=True)
            # shrink the assigned shape to smoke scale, keep the step kind
            shape = dataclasses.replace(
                shp.SHAPES[shape_name], seq_len=64, global_batch=8)
            compiled = dryrun.lower_cell(cfg, shape, mesh,
                                         step_kind=shape.step)
            terms = cost_terms(compiled)
            assert terms.flops > 0
            ma = compiled.memory_analysis()
            assert ma.temp_size_in_bytes >= 0
            print("CELL OK", arch, shape_name, int(terms.flops))
        print("MINI DRYRUN OK")
        """)
    assert "MINI DRYRUN OK" in out


def test_decode_equivalence_under_sharding():
    """Prefill+decode == forward on an 8-device mesh (cache sharding,
    select-update, seq-sharded KV all active)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_mesh
        from repro.parallel.sharding import serve_rules
        from repro.models import model as M

        cfg = get_config("mistral-nemo-12b", reduced=True)
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = serve_rules(mesh)
        params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
        B, S, S0 = 2, 12, 6
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab)
        logits_par, _ = M.forward(params, cfg, rules, {"tokens": toks},
                                  compute_dtype=jnp.float32, remat=False)
        cache = M.init_cache(cfg, B, S, dtype=jnp.float32)
        cache, lp = M.prefill(params, cfg, rules,
                              {"tokens": toks[:, :S0]}, cache,
                              compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lp),
                                   np.asarray(logits_par[:, S0-1]),
                                   rtol=3e-4, atol=3e-4)
        for t in range(S0, S):
            cache, ld = M.decode_step(params, cfg, rules, toks[:, t:t+1],
                                      cache, jnp.asarray(t, jnp.int32),
                                      compute_dtype=jnp.float32)
            np.testing.assert_allclose(np.asarray(ld),
                                       np.asarray(logits_par[:, t]),
                                       rtol=6e-4, atol=6e-4)
        print("SHARDED DECODE OK")
        """)
    assert "SHARDED DECODE OK" in out


def test_pipeline_parallel_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.parallel.pipeline import pipeline_apply, reference_apply

        mesh = make_mesh((4,), ("stage",))
        D = 16
        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])
        key = jax.random.PRNGKey(0)
        params = {
            "w": jax.random.normal(key, (4, D, D)) * 0.3,
            "b": jnp.zeros((4, D)),
        }
        x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
        y = pipeline_apply(stage_fn, params, x, mesh, axis="stage",
                           n_micro=4)
        ref = reference_apply(stage_fn, params, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("PIPELINE OK")
        """)
    assert "PIPELINE OK" in out
