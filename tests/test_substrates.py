"""Substrate tests: checkpoint roundtrip/atomicity/tiering, data pipeline
determinism + resume, watchdog semantics, tiered store behavior, expert
store plans, serving engine generation + pause/resume."""
import json
import math
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.core.policy import Tier, TieringPolicy
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticLM
from repro.runtime.tiers import TierSpec, TieredStore
from repro.tiering.expert_store import ExpertStore
from repro.train.watchdog import RollbackSignal, Watchdog, WatchdogConfig


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.ones((3,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(root=str(tmp_path)))
    tree = _tree()
    mgr.save(10, tree, extra={"data_step": 10})
    out, extra = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert extra["data_step"] == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_corruption_detected(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(root=str(tmp_path)))
    tree = _tree()
    path = mgr.save(1, tree)
    manifest = json.loads((path / "manifest.json").read_text())
    victim = list(manifest["leaves"].values())[0]["file"]
    arr = np.load(path / victim)
    arr.ravel()[0] += 1 if arr.dtype.kind in "iu" else 1.0
    np.save(path / victim, arr)
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(tree)


def test_checkpoint_gc_and_tier_demotion(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(
        root=str(tmp_path), keep=3, fast_tier_keep=1))
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        mgr.save(s, tree)
    assert mgr.latest_step() == 5
    assert mgr.tier_of(5) == "dram"          # newest on fast tier
    assert mgr.tier_of(4) == "flash"         # demoted
    assert mgr.tier_of(1) is None            # GC'd
    out, _ = mgr.restore(tree, step=3)       # restore from flash works
    assert out is not None


def test_checkpoint_partial_write_invisible(tmp_path):
    """A .tmp dir (simulated crash mid-save) must not be restorable."""
    mgr = CheckpointManager(CheckpointConfig(root=str(tmp_path)))
    tree = _tree()
    mgr.save(1, tree)
    crash = tmp_path / "dram" / "step_00000002.tmp"
    crash.mkdir()
    (crash / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_elastic_restore_to_different_sharding(tmp_path):
    """Save unsharded, restore with explicit shardings (1-device mesh) —
    the multi-device re-mesh path is exercised in test_distributed.py."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.sharding import make_compat_mesh
    mesh = make_compat_mesh((1,), ("x",))
    mgr = CheckpointManager(CheckpointConfig(root=str(tmp_path)))
    tree = _tree()
    mgr.save(1, tree)
    sh = jax.tree.map(lambda a: NamedSharding(
        mesh, P(*( ("x",) + (None,) * (a.ndim - 1)))), tree)
    out, _ = mgr.restore(tree, shardings=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_host_sharding():
    cfg1 = DataConfig(vocab=97, seq_len=16, global_batch=8)
    ds = SyntheticLM(cfg1)
    b1, b2 = ds.batch_at(3), ds.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(ds.batch_at(4)["tokens"], b1["tokens"])
    # host sharding partitions the batch
    h0 = SyntheticLM(DataConfig(vocab=97, seq_len=16, global_batch=8,
                                n_hosts=2, host_id=0))
    h1 = SyntheticLM(DataConfig(vocab=97, seq_len=16, global_batch=8,
                                n_hosts=2, host_id=1))
    assert h0.batch_at(0)["tokens"].shape == (4, 16)
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_prefetch_resume():
    ds = SyntheticLM(DataConfig(vocab=97, seq_len=8, global_batch=2))
    it = PrefetchIterator(ds, start_step=0)
    first = next(it)
    state = it.state()
    it.close()
    it2 = PrefetchIterator(ds, start_step=state["step"])
    second = next(it2)
    it2.close()
    np.testing.assert_array_equal(second["tokens"], ds.batch_at(1)["tokens"])
    np.testing.assert_array_equal(first["tokens"], ds.batch_at(0)["tokens"])


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_nan_rollback():
    wd = Watchdog()
    wd.begin_step()
    with pytest.raises(RollbackSignal):
        wd.end_step(1, float("nan"))


def test_watchdog_spike_rollback():
    wd = Watchdog(WatchdogConfig(max_loss_spike=2.0))
    for i in range(10):
        wd.begin_step()
        wd.end_step(i, 1.0)
    wd.begin_step()
    with pytest.raises(RollbackSignal):
        wd.end_step(11, 5.0)


def test_watchdog_straggler_detection():
    wd = Watchdog(WatchdogConfig(straggler_factor=5.0))
    for i in range(5):
        wd.begin_step()
        wd._t_last -= 0.01            # simulate 10ms steps
        wd.end_step(i, 1.0)
    wd.begin_step()
    wd._t_last -= 1.0                 # simulated 1s straggler
    ev = wd.end_step(6, 1.0)
    assert "straggler" in ev


# ---------------------------------------------------------------------------
# tiered store + policy
# ---------------------------------------------------------------------------

def _clocked_store(tau_hot=1.0, tau_be=10.0, dram_cap=10 * 2**20):
    clock = {"t": 0.0}
    pol = TieringPolicy(tau_hot=tau_hot, tau_be=tau_be, hysteresis=0.0,
                        ema_alpha=1.0)
    store = TieredStore(pol, specs={
        Tier.HBM: TierSpec(2**20, 819e9, 1e-7),
        Tier.DRAM: TierSpec(dram_cap, 45e9, 5e-7),
        Tier.FLASH: TierSpec(2**40, 7e9, 2e-5),
    }, clock=lambda: clock["t"])
    return store, clock


def test_tiered_store_promotes_hot_objects():
    store, clock = _clocked_store()
    x = np.ones(1024, np.float32)
    store.put("hot", x)
    for _ in range(6):
        clock["t"] += 0.1             # reuse interval 0.1s < tau_hot
        store.get("hot")
    assert store.tier_of("hot") == Tier.HBM


def test_tiered_store_demotes_cold_objects():
    store, clock = _clocked_store()
    store.put("cold", np.ones(1024, np.float32))
    for _ in range(4):
        clock["t"] += 100.0           # reuse interval >> tau_be
        store.get("cold")
    assert store.tier_of("cold") == Tier.FLASH


def test_tiered_store_capacity_pressure_demotes():
    store, clock = _clocked_store(dram_cap=8 * 4096)
    for i in range(8):
        clock["t"] += 0.01
        store.put(f"k{i}", np.ones(1024, np.float32))   # 4KiB each
    # DRAM full: next put must displace something to flash
    store.put("k8", np.ones(1024, np.float32))
    used = store.used_bytes(Tier.DRAM)
    assert used <= 8 * 4096
    assert store.used_bytes(Tier.FLASH) > 0


def test_policy_hysteresis_prevents_thrash():
    pol = TieringPolicy(tau_hot=1.0, tau_be=10.0, hysteresis=0.5,
                        ema_alpha=1.0)
    t = 0.0
    pol.observe("x", now=t)
    # interval 11s: above tau_be but inside the hysteresis band (10*1.5)
    t += 11.0
    assert pol.observe("x", now=t) == Tier.DRAM
    # interval 30s: beyond the band -> demote
    t += 30.0
    assert pol.observe("x", now=t) == Tier.FLASH


# ---------------------------------------------------------------------------
# expert store
# ---------------------------------------------------------------------------

def test_expert_store_residency_plan():
    pol = TieringPolicy(tau_hot=0.05, tau_be=5.0)
    es = ExpertStore(n_layers=2, n_experts=8, policy=pol)
    rng = np.random.default_rng(0)
    # expert 0 is hot (picked every step), expert 7 never picked
    for step in range(50):
        ids = np.concatenate([np.zeros(64, np.int64),
                              rng.integers(1, 7, 16)])
        es.observe_step({0: ids, 1: ids}, now=step * 0.01, tokens=80)
    plan = es.residency_plan(step_time=0.01)
    tiers = plan["tiers"]
    assert tiers[0, 0] == Tier.HBM            # always-selected expert
    assert tiers[0, 7] == Tier.FLASH          # never-selected expert
    assert plan["hbm_experts"] >= 2
    assert plan["flash_experts"] >= 2
