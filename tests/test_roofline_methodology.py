"""Validates the roofline cost-extrapolation methodology and the sharding
rules' invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.launch.roofline import (CostTerms, collective_wire_bytes,
                                   extrapolate, hlo_cost_analysis, roofline)


def test_probe_extrapolation_matches_full_unroll():
    """total(G) = probe(1) + (G-1) * marginal must equal a fully unrolled
    compile of the same G-layer stack (the scan-body-once workaround)."""
    D, G = 64, 5

    def make(n, unroll):
        def step(x, ws):
            if unroll:
                for i in range(n):
                    x = jnp.tanh(x @ ws[i])
                return x.sum()
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y.sum()
        xs = jax.ShapeDtypeStruct((32, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((n, D, D), jnp.float32)
        c = jax.jit(step, static_argnames=()).lower(xs, ws).compile()
        ca = hlo_cost_analysis(c)
        return CostTerms(float(ca.get("flops", 0)),
                         float(ca.get("bytes accessed", 0)), 0.0, {})

    p1 = make(1, unroll=True)
    p2 = make(2, unroll=True)
    full = make(G, unroll=True)
    est = extrapolate(p1, p2, G)
    assert abs(est.flops - full.flops) / full.flops < 0.02, \
        (est.flops, full.flops)
    # and the scanned compile undercounts, which is WHY we extrapolate
    scanned = make(G, unroll=False)
    assert scanned.flops < 0.5 * full.flops


def test_collective_wire_parsing():
    text = """
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups=[2,4]<=[8]
  %ar = bf16[32,32]{1,0} all-reduce(%y), replica_groups=[1,8]<=[8]
  %rs = f32[8,16]{1,0} reduce-scatter(%z), replica_groups=[2,4]<=[8]
  %cp = f32[16]{0} collective-permute(%w), replica_groups=[8,1]<=[8]
  %done = f32[64,128]{1,0} all-gather-done(%t)
"""
    wires = collective_wire_bytes(text)
    assert wires["all-gather"] == pytest.approx(64 * 128 * 4 * 3 / 4)
    assert wires["all-reduce"] == pytest.approx(2 * 32 * 32 * 2 * 7 / 8)
    assert wires["reduce-scatter"] == pytest.approx(8 * 16 * 4 * 3)
    # groups of size 1 contribute nothing; -done lines are not re-counted
    assert "collective-permute" not in wires or \
        wires["collective-permute"] == 0.0


def test_roofline_terms_and_dominance():
    t = CostTerms(flops=1.97e14, bytes_accessed=819e9 * 2.0,
                  wire_bytes=50e9 * 3.0, wire_by_kind={})
    r = roofline(t, chips=256, model_flops=256 * 0.5 * 1.97e14)
    assert r["t_compute"] == pytest.approx(1.0)
    assert r["t_memory"] == pytest.approx(2.0)
    assert r["t_collective"] == pytest.approx(3.0)
    assert r["dominant"] == "collective"
    assert r["roofline_fraction"] == pytest.approx(0.5 / 3.0)


# ---------------------------------------------------------------------------
# sharding rules invariants
# ---------------------------------------------------------------------------

from repro.parallel.sharding import train_rules  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


@pytest.fixture(scope="module")
def rules():
    return train_rules(make_mesh((1, 1), ("data", "model")))


NAMES = [None, "batch", "embed", "heads", "kv_heads", "ffn", "experts",
         "vocab", "res_embed", "act_qr", "layers"]


@settings(max_examples=60, deadline=None)
@given(dims=st.lists(st.tuples(st.integers(1, 64),
                               st.sampled_from(NAMES)), min_size=1,
                     max_size=5))
def test_spec_never_reuses_axis_and_always_divides(dims):
    # AbstractMesh: Rules only reads shape/axis names, no devices needed
    from repro.parallel.sharding import make_abstract_mesh
    mesh = make_abstract_mesh((2, 4), ("data", "model"))
    r = train_rules(mesh)
    shape = [d for d, _ in dims]
    names = [n for _, n in dims]
    spec = r.spec_for_shape(shape, names)
    used = []
    for dim, part in zip(shape, spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in parts:
            assert a not in used, f"axis {a} reused in {spec}"
            used.append(a)
            size *= mesh.shape[a]
        assert dim % size == 0, (dim, part, spec)
