"""Property-test hardening of the async runtime's service models and
accounting (runs on real hypothesis when installed, else on the
deterministic shim in tests/_hypothesis_shim.py):

  * SsdQueueModel: occupancy monotone in nbytes, latency monotone in
    queue depth, interpolation bounded by the calibrated endpoints,
    `shared()` caching per SimConfig, open-loop p99 >= mean per depth,
    and the REPRO_SSDSIM_CACHE disk round-trip.
  * NetQueueModel: the fixed-RTT + bandwidth-share split of the fabric's
    cross-host transfer tier.
  * TieredStore: prefetch accounting invariants (hits + late == waited
    fetches with a compute gap; same-instant gets never count) and the
    oversized-put capacity contract (demote straight to FLASH, never
    silently overcommit; impossible objects raise).
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.policy import Tier, TieringPolicy
from repro.runtime.clock import VirtualClock
from repro.runtime.service import (CACHE_ENV, NetQueueModel, SsdQueueModel)
from repro.runtime.tiers import TierSpec, TieredStore
from repro.ssdsim.config import SimConfig


# ---------------------------------------------------------------------------
# SsdQueueModel properties (satellite: hypothesis hardening)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 24),
       st.integers(min_value=0, max_value=1 << 24),
       st.integers(min_value=1, max_value=256))
def test_occupancy_monotone_in_nbytes(nbytes, extra, depth):
    m = SsdQueueModel.shared()
    small = m.service(nbytes, depth).occupancy
    large = m.service(nbytes + extra, depth).occupancy
    assert large >= small


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=256),
       st.integers(min_value=1, max_value=256),
       st.integers(min_value=1, max_value=1 << 22))
def test_latency_monotone_in_queue_depth(d1, d2, nbytes):
    m = SsdQueueModel.shared()
    lo, hi = sorted((d1, d2))
    assert m.service(nbytes, hi).latency >= m.service(nbytes, lo).latency


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=1, max_value=512),
       st.integers(min_value=1, max_value=1 << 22))
def test_interpolation_within_calibrated_endpoints(depth, nbytes):
    m = SsdQueueModel.shared()
    cal = m.calibration()
    svc = m.service(nbytes, depth)
    lats = [cal[d][1] for d in m.DEPTHS]
    assert min(lats) - 1e-15 <= svc.latency <= max(lats) + 1e-15
    # occupancy implies an effective IOPS that must sit inside the ladder
    pages = max(1, math.ceil(nbytes / m.PAGE))
    iops = pages / svc.occupancy
    all_iops = [cal[d][0] for d in m.DEPTHS]
    assert min(all_iops) * (1 - 1e-9) <= iops <= max(all_iops) * (1 + 1e-9)
    # clipping: outside the ladder, service equals the endpoint's
    assert m.service(nbytes, m.DEPTHS[-1] * 4).latency == \
        pytest.approx(m.service(nbytes, m.DEPTHS[-1]).latency)


def test_shared_returns_cached_identical_instance_per_config():
    assert SsdQueueModel.shared() is SsdQueueModel.shared()
    cfg = SimConfig(l_blk=4096, read_frac=0.8)
    m = SsdQueueModel.shared(cfg)
    assert m is SsdQueueModel.shared(cfg)
    # value-equal configs hit the same cache slot (frozen dataclass key)
    assert m is SsdQueueModel.shared(SimConfig(l_blk=4096, read_frac=0.8))
    assert m is not SsdQueueModel.shared()


# ---------------------------------------------------------------------------
# p99 calibration (satellite: p99-aware prefetch-lead prerequisite)
# ---------------------------------------------------------------------------

def test_calibration_exposes_open_loop_p99_dominating_mean():
    cal = SsdQueueModel.shared().calibration()
    assert all(len(v) == 3 for v in cal.values())
    for d, (iops, mean, p99) in cal.items():
        assert p99 >= mean, f"depth {d}: p99 {p99} < mean {mean}"
    p99s = [cal[d][2] for d in sorted(cal)]
    assert p99s == sorted(p99s)            # tail grows with load


def test_calibration_disk_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv(CACHE_ENV, str(tmp_path))
    m1 = SsdQueueModel(n_ops=300)
    c1 = m1.calibration()
    assert list(tmp_path.glob("ssdcal-*.json"))
    # a fresh instance must serve from disk: poison the simulator entry
    # points so any recalibration would blow up
    import repro.runtime.service as service_mod

    def _boom(*a, **kw):
        raise AssertionError("calibration not served from disk cache")
    monkeypatch.setattr(service_mod, "simulate_peak_iops", _boom)
    monkeypatch.setattr(service_mod, "simulate_latency", _boom)
    m2 = SsdQueueModel(n_ops=300)
    assert m2.calibration() == c1


# ---------------------------------------------------------------------------
# NetQueueModel (fabric's cross-host transfer tier)
# ---------------------------------------------------------------------------

def test_net_model_fixed_rtt_and_bandwidth_share():
    m = NetQueueModel(rtt=1e-5, bandwidth=1e9, sat_depth=4)
    s1, s4, s8 = (m.service(1 << 20, d) for d in (1, 4, 8))
    assert s1.latency == s4.latency == s8.latency == 1e-5
    # one window-limited stream cannot saturate; four fill the pipe
    assert s1.occupancy > s4.occupancy
    assert s4.occupancy == s8.occupancy == (1 << 20) / 1e9
    with pytest.raises(ValueError):
        NetQueueModel(bandwidth=0.0)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 24),
       st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=64))
def test_net_model_occupancy_monotone(nbytes, d1, d2):
    m = NetQueueModel()
    lo, hi = sorted((d1, d2))
    assert m.service(nbytes, lo).occupancy >= m.service(nbytes, hi).occupancy


# ---------------------------------------------------------------------------
# prefetch accounting invariants (satellite: _finish_fetch contract)
# ---------------------------------------------------------------------------

def _flash_store():
    clock = VirtualClock()
    pol = TieringPolicy(tau_hot=1e-12, tau_be=1e-9, ema_alpha=1.0)
    store = TieredStore(pol, clock=clock)
    for i in range(4):
        store.put(("k", i), np.ones(1 << 14, np.float32), tier=Tier.FLASH)
    store.runtime.drain()
    return store, clock


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=0.02),
                min_size=1, max_size=6))
def test_prefetch_counters_equal_waited_fetches_with_gap(gaps):
    store, clock = _flash_store()
    waited_with_gap = 0
    for i, gap in enumerate(gaps):
        pf = store.get_async(("k", i % 4))
        if gap > 0:
            store.runtime.advance(gap)
            waited_with_gap += 1
        pf.wait()
    st_ = store.stats[Tier.FLASH]
    assert st_.prefetch_hits + st_.prefetch_late == waited_with_gap
    # a same-instant synchronous get never pollutes the prefetch counters
    before = (st_.prefetch_hits, st_.prefetch_late)
    store.get(("k", 0))
    assert (st_.prefetch_hits, st_.prefetch_late) == before


def test_prefetch_counters_batched_fetches():
    """All handles waited after one shared compute gap: every one is a
    prefetch (hit or late), nothing double-counts."""
    store, _ = _flash_store()
    handles = [store.get_async(("k", i)) for i in range(4)]
    store.runtime.advance(1e-3)
    for pf in handles:
        pf.wait()
    st_ = store.stats[Tier.FLASH]
    assert st_.prefetch_hits + st_.prefetch_late == 4


# ---------------------------------------------------------------------------
# oversized-put capacity contract (satellite: _ensure_room fix)
# ---------------------------------------------------------------------------

def _small_store():
    pol = TieringPolicy(tau_hot=1.0, tau_be=10.0, hysteresis=0.0,
                        ema_alpha=1.0)
    return TieredStore(pol, specs={
        Tier.HBM: TierSpec(1 << 20, 819e9, 1e-7),
        Tier.DRAM: TierSpec(4 << 20, 45e9, 5e-7),
        Tier.FLASH: TierSpec(64 << 20, 7e9, 2e-5),
    }, clock=VirtualClock())


def test_oversized_put_demotes_straight_to_flash():
    store = _small_store()
    big = np.ones(2 << 20, np.uint8)         # 2MiB > HBM, fits DRAM
    store.put("big", big, tier=Tier.HBM)
    assert store.tier_of("big") == Tier.DRAM  # first tier that fits
    assert store.used_bytes(Tier.HBM) == 0
    huge = np.ones(8 << 20, np.uint8)        # 8MiB > DRAM too
    store.put("huge", huge, tier=Tier.DRAM)
    assert store.tier_of("huge") == Tier.FLASH
    # no tier is overcommitted
    for t in store.tiers:
        assert store.used_bytes(t) <= store.specs[t].capacity_bytes


def test_put_larger_than_every_tier_raises():
    store = _small_store()
    with pytest.raises(ValueError):
        store.put("impossible", np.ones(128 << 20, np.uint8),
                  tier=Tier.DRAM)
    assert store.tier_of("impossible") is None


def test_capacity_pressure_never_overcommits():
    store = _small_store()
    for i in range(12):                      # 12MiB through a 4MiB DRAM
        store.put(("o", i), np.ones(1 << 20, np.uint8), tier=Tier.DRAM)
    for t in store.tiers:
        assert store.used_bytes(t) <= store.specs[t].capacity_bytes
    assert store.stats[Tier.FLASH].demotions > 0
    assert all(store.tier_of(("o", i)) is not None for i in range(12))
