"""Fourth-tier subsystem tests: the gpu_flash + pool Eq. 1 columns, the
gate's four-way admission, the `PooledStore` runtime (readability,
eviction spill, fate-sharing), spec plumbing (write_bw, PoolDecl, JSON
purity under hypothesis), the advisor's four-arm comparison, and the
serving bench's headline wins with the stall-ledger conservation law."""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.economics import (GPU_GDDR, break_even,
                                  break_even_components,
                                  break_even_components_gpu_direct,
                                  break_even_components_pool,
                                  break_even_gpu_direct, break_even_pool,
                                  pool_flash_crossover)
from repro.core.policy import Tier
from repro.core.ssd_model import NAND_TYPES, storage_next_ssd
from repro.autopilot.gate import EconomicGate
from repro.obs.ledger import COMPONENTS, StallLedger
from repro.platform import (HierarchySpec, HostDecl, Platform, PolicyDecl,
                            PoolDecl, TierDecl, gpu_flash_tier)
from repro.runtime.clock import VirtualClock
from repro.runtime.fabric import ShardedTieredStore
from repro.runtime.pool import PooledStore

SSD = storage_next_ssd(NAND_TYPES["slc"])
L_BLK = 32768


# ---------------------------------------------------------------------------
# Eq. 1 columns: the gpu_direct path drops host rent; the pool band
# ---------------------------------------------------------------------------

def test_gpu_direct_column_drops_host_terms():
    classic = break_even_components(GPU_GDDR, L_BLK, SSD.cost, 2e5)
    gpu = break_even_components_gpu_direct(GPU_GDDR, L_BLK, SSD.cost, 2e5)
    assert set(gpu) == {"submit", "ssd"}          # no host, no dram_bw
    # same NAND term (the media did not change, the path did)
    assert float(gpu["ssd"]) == pytest.approx(float(classic["ssd"]))
    # the submission engine undercuts the host CPU by >10x per IO
    assert float(gpu["submit"]) < 0.1 * float(classic["host"])
    assert float(break_even_gpu_direct(GPU_GDDR, L_BLK, SSD.cost, 2e5)) \
        < float(break_even(GPU_GDDR, L_BLK, SSD.cost, 2e5))


def test_pool_column_band_and_validation():
    comp = break_even_components_pool(GPU_GDDR, L_BLK)
    assert set(comp) == {"pool_wire", "pool_rtt"}
    assert float(break_even_pool(GPU_GDDR, L_BLK)) > 0
    with pytest.raises(ValueError, match="rent_factor"):
        break_even_components_pool(GPU_GDDR, L_BLK, rent_factor=1.0)
    with pytest.raises(ValueError, match="rent_factor"):
        pool_flash_crossover(GPU_GDDR, L_BLK, 2.0, rent_factor=0.0)


def test_pool_flash_crossover_brackets_the_band():
    tau_be = float(break_even(GPU_GDDR, L_BLK, SSD.cost, 2e5))
    # CXL-class geometry: a real band opens above tau_be ...
    wide = float(pool_flash_crossover(GPU_GDDR, L_BLK, tau_be,
                                      pool_bw=40e9, pool_rtt=2e-6,
                                      rent_factor=0.25))
    assert wide > tau_be
    # ... and a slow, barely-discounted pool closes it (crossover at or
    # below tau_be means no reuse interval prefers pooled residency)
    narrow = float(pool_flash_crossover(GPU_GDDR, L_BLK, tau_be,
                                        pool_bw=2e8, pool_rtt=5e-3,
                                        rent_factor=0.95))
    assert narrow <= tau_be


# ---------------------------------------------------------------------------
# the gate's four-way admission
# ---------------------------------------------------------------------------

def _gate(**kw):
    return EconomicGate(tau_hot=0.05, tau_be=2.0,
                        **{**dict(tau_pool=8.0, gpu_direct=True), **kw})


def _teach(gate, key, interval, *, reps=3, t0=0.0):
    t = t0
    for _ in range(reps):
        gate.observe(key, now=t)
        t += interval
    return t


def test_gate_four_way_decisions():
    g = _gate()
    now = _teach(g, "hot", 0.5)
    assert g.admit_tier("hot", Tier.DRAM, now) == Tier.DRAM
    now = _teach(g, "band", 4.0)
    # inside [tau_be, tau_pool): pooled, not locally placed
    assert g.pool_admit("band", Tier.DRAM, now)
    now = _teach(g, "cold", 30.0)
    assert not g.pool_admit("cold", Tier.DRAM, now)
    # cold + gpu_direct: the flash decision rides the BaM path
    assert g.admit_tier("cold", Tier.DRAM, now) == Tier.GPU_FLASH
    # an explicit flash ask (pin/spill) is honored verbatim
    assert g.admit_tier("cold", Tier.FLASH, now) == Tier.FLASH
    st_ = g.gate_stats
    assert st_.admits_pool == 1 and st_.admits_gpu_flash == 1


def test_gate_without_fourth_tier_is_unchanged():
    g = EconomicGate(tau_hot=0.05, tau_be=2.0)
    now = _teach(g, "cold", 30.0)
    assert g.admit_tier("cold", Tier.DRAM, now) == Tier.FLASH
    assert not g.pool_admit("cold", Tier.DRAM, now)   # no tau_pool
    assert g.gate_stats.admits_pool == 0
    assert g.gate_stats.admits_gpu_flash == 0


def test_gate_rejects_inverted_pool_band():
    with pytest.raises(ValueError, match="tau_pool must exceed"):
        EconomicGate(tau_hot=0.05, tau_be=2.0, tau_pool=1.0)


def test_gpu_flash_decision_is_not_priced_out():
    """GPU_FLASH is the *cheap* cold path, not a gate miss: its later
    restores bill gpu_direct_service, never gate_miss_restore."""
    g = _gate()
    now = _teach(g, "cold", 30.0)
    g.admit_tier("cold", Tier.DRAM, now)
    assert not g.priced_out("cold")


# ---------------------------------------------------------------------------
# PooledStore runtime: readability, LRU spill, fate-sharing
# ---------------------------------------------------------------------------

def _pool(clock, cap_blobs=4, **kw):
    pool = PooledStore(cap_blobs * 1024.0, clock=clock,
                       **{**dict(read_bw=1e6, write_bw=1e6, rtt=1e-3),
                          **kw})
    pool.attach_host(0)
    pool.attach_host(1)
    return pool


def test_pool_readability_gates_read_behind_ingest():
    clock = VirtualClock()
    pool = _pool(clock)
    blob = np.zeros(1024, np.uint8)
    tr = pool.put("k", blob, from_host=0)
    assert tr.done_t > clock.now()
    got = pool.get("k", from_host=1)       # issued before arrival
    assert clock.now() >= tr.done_t - 1e-12
    np.testing.assert_array_equal(got, blob)
    assert pool.stats.stall_time > 0


def test_pool_lru_eviction_spills_to_owner():
    clock = VirtualClock()
    pool = _pool(clock, cap_blobs=2)
    spilled = []
    pool.on_evict = lambda k, v, owner: spilled.append((k, owner))
    pool.put("a", np.zeros(1024, np.uint8), from_host=0)
    clock.advance(1.0)
    pool.put("b", np.zeros(1024, np.uint8), from_host=1)
    clock.advance(1.0)
    pool.get("a", from_host=0)             # refresh a; b is now LRU
    pool.put("c", np.zeros(1024, np.uint8), from_host=0)
    assert spilled == [("b", 1)]
    assert pool.has("a") and pool.has("c") and not pool.has("b")
    assert pool.stats.evictions == 1


def test_pool_oversized_object_rejected():
    pool = _pool(VirtualClock(), cap_blobs=1)
    with pytest.raises(ValueError, match="exceeds the pool capacity"):
        pool.put("big", np.zeros(4096, np.uint8), from_host=0)


def test_pool_byte_seconds_integral():
    clock = VirtualClock()
    pool = _pool(clock)
    pool.put("k", np.zeros(1024, np.uint8), from_host=0)
    bs0 = pool.byte_seconds()
    clock.advance(2.0)
    assert pool.byte_seconds() - bs0 == pytest.approx(1024 * 2.0)
    pool.delete("k")
    before = pool.byte_seconds()
    clock.advance(10.0)                    # nothing resident: no accrual
    assert pool.byte_seconds() == pytest.approx(before, rel=1e-12)


def test_pool_lane_fate_sharing():
    clock = VirtualClock()
    pool = _pool(clock)
    pool.put("k", np.zeros(1024, np.uint8), from_host=0)
    pool.detach_host(0)
    assert pool.has("k")                   # residency survives the host
    with pytest.raises(KeyError, match="no pool lane"):
        pool.get("k", from_host=0)
    assert pool.get("k", from_host=1).nbytes == 1024


# ---------------------------------------------------------------------------
# fabric integration: gate-driven pooling, promotion, host failure
# ---------------------------------------------------------------------------

def _fabric_with_pool(n_hosts=3, tau_pool=8.0, dram_blobs=4):
    from repro.runtime.tiers import TierSpec
    clock = VirtualClock()
    pool = PooledStore(64 * 1024.0, read_bw=1e9, rtt=1e-5, clock=clock)
    specs = {
        Tier.DRAM: TierSpec(dram_blobs * 1024.0, 45e9, 5e-7),
        Tier.FLASH: TierSpec(float(1 << 30), 7e9, 2e-5),
    }
    fab = ShardedTieredStore(
        n_hosts,
        policy_factory=lambda h: EconomicGate(
            tau_hot=0.05, tau_be=2.0, tau_pool=tau_pool),
        specs=specs, clock=clock, pool=pool)
    return fab, clock


def _teach_fabric(fab, key, interval, *, reps=3, host=0):
    for _ in range(reps):
        fab.hosts[host].policy.observe(key, now=fab.clock.now())
        fab.clock.advance(interval)


def test_fabric_pools_band_keys_and_promotes_on_reuse():
    fab, clock = _fabric_with_pool()
    blob = np.zeros(1024, np.uint8)
    _teach_fabric(fab, "band", 4.0)
    fab.put("band", blob, tier=Tier.DRAM, from_host=0)
    assert fab.tier_of("band") == Tier.POOL
    assert fab.pool_puts == 1
    # reuse at a DRAM-worthy cadence: the fetch observes, the policy
    # now wants it warm, and the fabric promotes it out of the pool
    for _ in range(4):
        clock.advance(0.5)
        got = fab.get("band", from_host=1)
    np.testing.assert_array_equal(got, blob)
    assert fab.pool.stats.promotions >= 1
    assert not fab.pool.has("band")
    assert fab.hosts[1].tier_of("band") is not None


def test_fabric_pool_survives_host_failure():
    fab, clock = _fabric_with_pool()
    _teach_fabric(fab, "band", 4.0)
    fab.put("band", np.ones(1024, np.uint8), tier=Tier.DRAM, from_host=0)
    assert fab.tier_of("band") == Tier.POOL
    fab.fail_host(0)
    # fleet infrastructure: residency survives; the dead host's lane
    # does not, but any surviving host still reaches the bytes
    assert fab.pool.has("band")
    assert 0 not in fab.pool.lanes
    got = fab.get("band", from_host=1)
    assert int(got[0]) == 1


def test_fabric_without_pool_never_calls_hook():
    """A 3-tier fleet (pool=None) with a four-tier-capable gate behaves
    exactly as before: no pooling, no pool counters."""
    clock = VirtualClock()
    fab = ShardedTieredStore(
        2, policy_factory=lambda h: EconomicGate(
            tau_hot=0.05, tau_be=2.0, tau_pool=8.0),
        clock=clock)
    _teach_fabric(fab, "band", 4.0)
    fab.put("band", np.zeros(1024, np.uint8), tier=Tier.DRAM, from_host=0)
    assert fab.tier_of("band") in (Tier.DRAM, Tier.FLASH)
    assert fab.pool_puts == 0 and fab.pool_fetches == 0


# ---------------------------------------------------------------------------
# stall ledger: new components under the conservation invariant
# ---------------------------------------------------------------------------

def test_ledger_components_include_fourth_tier():
    assert "pool_rtt" in COMPONENTS
    assert "gpu_direct_service" in COMPONENTS
    led = StallLedger()
    led.add("pool_rtt", 0.25, "day")
    led.add("gpu_direct_service", 0.5, "scan")
    assert led.tenant_totals("day")["pool_rtt"] == 0.25
    assert led.tenant_totals("scan")["gpu_direct_service"] == 0.5
    d = led.as_dict()
    assert d["pool_rtt"] == 0.25 and d["gpu_direct_service"] == 0.5


def test_pool_stall_lands_in_pool_rtt():
    clock = VirtualClock()
    pool = _pool(clock)
    pool.put("k", np.zeros(4096, np.uint8), from_host=0)
    pool.get("k", from_host=1)
    led = pool.ledger.as_dict()
    assert led["pool_rtt"] > 0
    others = {c: led[c] for c in COMPONENTS
              if c not in ("pool_rtt", "interference")}
    assert all(v == 0.0 for v in others.values()), others


# ---------------------------------------------------------------------------
# spec plumbing: write_bw, PoolDecl, gpu_flash tier, JSON purity
# ---------------------------------------------------------------------------

def test_tier_decl_write_bw_defaults_to_read_bw():
    spec = HierarchySpec(hosts=(HostDecl(
        tiers={"dram": TierDecl(1 << 20, 45e9, 5e-7)}),))
    specs = spec.hosts[0].tier_specs()
    assert specs[Tier.DRAM].write_bw is None          # None = inherit
    assert specs[Tier.DRAM].effective_write_bw \
        == specs[Tier.DRAM].read_bw
    asym = HierarchySpec(hosts=(HostDecl(
        tiers={"flash": TierDecl(1 << 30, 7e9, 2e-5, write_bw=2e9)}),))
    fspecs = asym.hosts[0].tier_specs()
    assert fspecs[Tier.FLASH].write_bw == 2e9
    assert fspecs[Tier.FLASH].read_bw == 7e9
    with pytest.raises(ValueError, match="write_bw"):
        TierDecl(1 << 20, 45e9, 5e-7, write_bw=-1.0).validate("t")


def test_unknown_tier_error_lists_gpu_flash():
    bad = HierarchySpec(hosts=(HostDecl(
        tiers={"l2": TierDecl(1e9, 1e9, 1e-7)}),))
    with pytest.raises(ValueError, match="gpu_flash"):
        bad.validate()


def test_three_tier_json_has_no_new_keys():
    """A spec that never mentions the fourth tier serializes without
    `pool` or `write_bw` keys — byte-compatible with pre-PR-10 JSON."""
    js = HierarchySpec(hosts=(HostDecl(count=2),)).to_json()
    assert '"pool"' not in js and '"write_bw"' not in js


def test_pool_decl_validation():
    with pytest.raises(ValueError, match="rent_factor"):
        HierarchySpec(pool=PoolDecl(capacity_bytes=1e9,
                                    rent_factor=1.0)).validate()
    with pytest.raises(ValueError, match="capacity"):
        HierarchySpec(pool=PoolDecl(capacity_bytes=0.0)).validate()


def _four_tier_spec(pool_cap=1 << 22, rent_factor=0.25, rtt=2e-6,
                    gpu_cap=4e12):
    return HierarchySpec(
        hosts=(HostDecl(count=2, tiers={
            "dram": TierDecl(1 << 20, 45e9, 5e-7),
            "gpu_flash": dataclasses.replace(
                gpu_flash_tier(), capacity_bytes=float(gpu_cap)),
        }),),
        policy=PolicyDecl.economic(l_blk=L_BLK),
        pool=PoolDecl(capacity_bytes=float(pool_cap),
                      rent_factor=rent_factor, rtt=rtt),
        step_time=0.25)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1 << 20, max_value=1 << 28),
       st.floats(min_value=0.05, max_value=0.9),
       st.floats(min_value=1e-7, max_value=1e-4))
def test_four_tier_spec_json_purity(pool_cap, rent_factor, rtt):
    """Property (hypothesis): any pool+gpu_flash spec survives
    to_json -> from_json equal, re-serializes byte-identically, and
    compiles to the same gate thresholds and tier geometry."""
    spec = _four_tier_spec(pool_cap=pool_cap, rent_factor=rent_factor,
                           rtt=rtt)
    spec.validate()
    again = HierarchySpec.from_json(spec.to_json())
    assert again == spec
    assert again.to_json() == spec.to_json()
    p1, p2 = Platform.compile(spec), Platform.compile(again)
    g1, g2 = p1.policy(0), p2.policy(0)
    assert g1.tau_be == g2.tau_be and g1.tau_pool == g2.tau_pool
    assert g1.gpu_direct and g2.gpu_direct
    s1 = p1.fabric.hosts[0].specs
    s2 = p2.fabric.hosts[0].specs
    assert sorted(s1) == sorted(s2)
    assert Tier.GPU_FLASH in s1
    for t in s1:
        assert (s1[t].capacity_bytes, s1[t].read_bw, s1[t].write_bw) \
            == (s2[t].capacity_bytes, s2[t].read_bw, s2[t].write_bw)
    if p1.fabric.pool is not None:
        assert p1.fabric.pool.capacity_bytes \
            == p2.fabric.pool.capacity_bytes


def test_compiled_four_tier_platform_wires_everything():
    spec = _four_tier_spec()
    platform = Platform.compile(spec)
    gate = platform.policy(0)
    assert gate.gpu_direct
    assert gate.tau_pool is not None and gate.tau_pool > gate.tau_be
    assert platform.fabric.pool is not None
    assert set(platform.fabric.pool.lanes) == {0, 1}
    assert Tier.GPU_FLASH in platform.fabric.hosts[0].specs


def test_narrow_band_compiles_pool_without_gate_band():
    """A pool whose crossover falls at/below tau_be still compiles (the
    slab exists) but the gate gets no band: nothing is pooled."""
    spec = dataclasses.replace(
        _four_tier_spec(),
        pool=PoolDecl(capacity_bytes=float(1 << 22), read_bw=2e8,
                      rtt=5e-3, rent_factor=0.95))
    platform = Platform.compile(spec)
    assert platform.fabric.pool is not None
    assert platform.policy(0).tau_pool is None


# ---------------------------------------------------------------------------
# the advisor's four-arm comparison
# ---------------------------------------------------------------------------

def test_advise_tiers_recommends_pool_for_band_heavy_reuse():
    from repro.autopilot.advisor import ProvisionAdvisor
    adv = ProvisionAdvisor(host=GPU_GDDR, ssd=SSD, l_blk=L_BLK)
    tau_be = adv.tau_be
    advice = adv.advise_tiers(
        interval_samples=[tau_be * 1.5] * 64,   # all reuse in the band
        access_rate=100.0, resident_bytes=64 * L_BLK,
        pool_bw=40e9, pool_rtt=2e-6, rent_factor=0.25)
    assert advice.tau_pool > advice.tau_be
    assert advice.pool_band_fraction == pytest.approx(1.0)
    assert advice.recommended_arm in ("pool", "both")
    assert set(advice.arms) == {"baseline", "gpu_flash", "pool", "both"}
    d = advice.as_dict()
    assert json.loads(json.dumps(d)) == d


def test_advise_tiers_recommends_gpu_flash_for_cold_reuse():
    from repro.autopilot.advisor import ProvisionAdvisor
    adv = ProvisionAdvisor(host=GPU_GDDR, ssd=SSD, l_blk=L_BLK)
    advice = adv.advise_tiers(
        interval_samples=[adv.tau_be * 50] * 64,  # far beyond the band
        access_rate=100.0, resident_bytes=1 << 30,
        pool_bw=40e9, pool_rtt=2e-6, rent_factor=0.25)
    assert advice.pool_band_fraction == pytest.approx(0.0)
    assert advice.recommended_arm == "gpu_flash"
    assert advice.arms["gpu_flash"]["total"] \
        < advice.arms["baseline"]["total"]


def test_advise_tiers_validates_inputs():
    from repro.autopilot.advisor import ProvisionAdvisor
    adv = ProvisionAdvisor(host=GPU_GDDR, ssd=SSD, l_blk=L_BLK)
    with pytest.raises(ValueError, match="exactly one"):
        adv.advise_tiers(access_rate=1.0, resident_bytes=1.0)


# ---------------------------------------------------------------------------
# the serving bench: headline wins + conservation (one heavy test)
# ---------------------------------------------------------------------------

def test_tiers_bench_headline_and_conservation():
    """PR 10's acceptance bar, asserted end to end on the smoke packs:
    gpu_flash strictly beats the 3-tier baseline on modeled $/token at
    equal-or-lower stall somewhere, the pool does too, the baseline
    advisor recommends a measured winner, and every arm of every
    scenario obeys the stall-ledger conservation law with the two new
    components present."""
    from repro.serving.tiers import run_tiers_bench
    out = run_tiers_bench(smoke=True)
    assert out["gpu_flash_wins_somewhere"]
    assert out["pool_wins_somewhere"]
    for scen in ("moe_scan", "diurnal"):
        cell = out[scen]
        assert cell["advice_agreement"], cell["advice"]
        for arm in ("baseline", "gpu_flash", "pool", "both"):
            m = cell[arm]["report"]
            led = m["stall_ledger"]
            for comp in COMPONENTS:
                assert comp in led
            # conservation: the ledger total is exactly the scheduler's
            # stalled seconds (kv stall + idle rent == per-token stall
            # integrated back over tokens)
            rhs = m["per_token_stall"] * max(m["tokens"], 1)
            assert abs(led["total"] - rhs) <= 1e-9 * max(rhs, 1e-30), \
                (scen, arm)
        # mechanism, not just outcome: the gpu arms route cold blobs
        # over the BaM path, the pool arms pool the band
        assert cell["gpu_flash"]["gate"]["admits_gpu_flash"] > 0
        assert cell["gpu_flash"]["report"]["stall_ledger"][
            "gpu_direct_service"] >= 0.0
    d = out["diurnal"]
    assert d["pool"]["gate"]["admits_pool"] > 0
    assert d["pool"]["pool_stats"]["puts"] > 0
    assert d["advice"]["recommended_arm"] in ("pool", "both")
    m = out["moe_scan"]
    assert m["advice"]["recommended_arm"] in ("gpu_flash", "both")
    # JSON-stable for the CI double-run diff
    blob = json.dumps(out, sort_keys=True)
    assert json.loads(blob) == json.loads(
        json.dumps(json.loads(blob), sort_keys=True))
